"""Bit-exact reference port of the Rust golden-vector pipeline.

The container building these PRs has no Rust toolchain, so the checked-in
``rust/tests/golden/*.json`` digests are produced (and re-verified) by this
numpy port instead of the ignored ``regen_golden_vectors`` cargo test. The
port replicates, bit for bit:

* ``util/rng.rs``        — SplitMix64-seeded xoshiro256++,
* ``tests/golden_vectors.rs::golden_input`` — the dyadic input stream,
* ``hadamard/scalar.rs`` — the scalar FWHT association order (base stage
  in ``c``-order, then the in-block butterfly, then one scale multiply;
  all three kernels are bitwise-equal f32 butterfly networks, so matching
  the oracle order matches every kernel),
* ``hadamard/matrices.rs`` — the Paley-II base tables H12/H20/H28,
* ``util/f16.rs``        — RNE narrowing to f16 (numpy's cast) and bf16
  (the ``bits + 0x7fff + lsb`` trick, replicated on uint32),
* ``hadamard/mod.rs::sign_vector`` — the seeded ±1 rotation prologue.

Every elementwise numpy float32 op is a correctly-rounded IEEE single op,
and the butterfly pairs within one level are independent, so vectorising
a level preserves the scalar kernel's bit pattern exactly.

Usage::

    python3 python/goldens.py verify   # recompute + diff all entries
    python3 python/goldens.py regen    # rewrite rust/tests/golden/*.json

``regen`` refuses to run unless ``verify`` of the non-rotated entries
passes first — if the port and the Rust tree ever disagree, that is a
divergence to investigate, not overwrite.
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

MASK64 = (1 << 64) - 1

GOLDEN_SCHEMA = "hadacore-golden-v1"
GOLDEN_SIZES = [256, 1024, 768, 5120, 14336]
GOLDEN_SEED = 0x601D
PREFIX_LEN = 16
KERNELS = ["scalar", "dao", "hadacore"]
# rotated (sign-flip prologue) golden entries: same sizes, fixed seed —
# must match rust/tests/golden_vectors.rs::ROTATED_SEED
ROTATED_SEED = 0x5EED_0006


# -- util/rng.rs ------------------------------------------------------------

def _splitmix64(state: int) -> tuple[int, int]:
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return state, z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    """xoshiro256++ matching rust/src/util/rng.rs bit for bit."""

    def __init__(self, seed: int):
        sm = seed & MASK64
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK64, 23) + s[0]) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result


# -- hadamard/matrices.rs ---------------------------------------------------

def _gf_sub(q: int, a: int, b: int) -> int:
    if q == 9:
        a0, a1 = a % 3, a // 3
        b0, b1 = b % 3, b // 3
        return (a0 + 3 - b0) % 3 + 3 * ((a1 + 3 - b1) % 3)
    return (a + q - b) % q


def _gf_mul(q: int, a: int, b: int) -> int:
    if q == 9:
        a0, a1 = a % 3, a // 3
        b0, b1 = b % 3, b // 3
        return (a0 * b0 + 2 * a1 * b1) % 3 + 3 * ((a0 * b1 + a1 * b0) % 3)
    return (a * b) % q


def paley2_hadamard(q: int) -> np.ndarray:
    assert q % 4 == 1
    squares = {_gf_mul(q, x, x) for x in range(1, q)}

    def chi(z: int) -> int:
        return 0 if z == 0 else (1 if z in squares else -1)

    n0 = q + 1
    c = np.zeros((n0, n0), dtype=np.int64)
    c[0, 1:] = 1
    c[1:, 0] = 1
    for i in range(q):
        for j in range(q):
            c[i + 1, j + 1] = chi(_gf_sub(q, i, j))

    m = np.array([[1, 1], [1, -1]], dtype=np.int64)
    nmat = np.array([[1, -1], [-1, -1]], dtype=np.int64)
    n = 2 * n0
    h = np.kron(c, m) + np.kron(np.eye(n0, dtype=np.int64), nmat)
    assert h.shape == (n, n)
    assert np.array_equal(h, h.T)
    assert np.array_equal(h @ h.T, n * np.eye(n, dtype=np.int64))
    return h.astype(np.float32)


_BASES: dict[int, np.ndarray] = {}


def hadamard_base(b: int) -> np.ndarray:
    if b not in _BASES:
        _BASES[b] = paley2_hadamard({12: 5, 20: 9, 28: 13}[b])
    return _BASES[b]


def split_base(n: int) -> tuple[int, int]:
    tz = (n & -n).bit_length() - 1
    odd = n >> tz
    if odd == 1:
        return 1, n
    if odd in (3, 5, 7) and tz >= 2:
        return {3: 12, 5: 20, 7: 28}[odd], n // {3: 12, 5: 20, 7: 28}[odd]
    raise ValueError(f"unsupported size {n}")


# -- hadamard/scalar.rs (f32, exact association order) ----------------------

def fwht_row_f32(row: np.ndarray, n: int, scale: np.float32) -> np.ndarray:
    """One row, in the scalar kernel's exact order, float32 throughout."""
    row = row.astype(np.float32, copy=True)
    base, m = split_base(n)
    if base > 1:
        hb = hadamard_base(base)
        # y[b*m+t] = sum_c hb[b][c] * x[c*m+t], accumulated in c-order
        blocks = row.reshape(base, m)
        out = np.zeros((base, m), dtype=np.float32)
        for b in range(base):
            acc = np.zeros(m, dtype=np.float32)
            for c in range(base):
                acc = acc + hb[b, c] * blocks[c]
            out[b] = acc
        row = out.reshape(-1)
    # butterfly on each contiguous m-block; pairs within a level are
    # independent, so the vectorised adds keep the scalar bit pattern
    blk = row.reshape(base, m)
    h = 1
    while h < m:
        v = blk.reshape(base, m // (2 * h), 2, h)
        x = v[:, :, 0, :].copy()
        y = v[:, :, 1, :].copy()
        v[:, :, 0, :] = x + y
        v[:, :, 1, :] = x - y
        h *= 2
    row = blk.reshape(-1)
    if scale != np.float32(1.0):
        row = row * scale
    return row


def normalized_scale(n: int) -> np.float32:
    return np.float32(1.0) / np.sqrt(np.float32(n))


# -- util/f16.rs ------------------------------------------------------------

def f32_to_bf16_bits(v: np.ndarray) -> np.ndarray:
    bits = v.view(np.uint32)
    nan = np.isnan(v)
    lsb = (bits >> np.uint32(16)) & np.uint32(1)
    rounded = bits + np.uint32(0x7FFF) + lsb  # uint32 wraps like Rust
    out = (rounded >> np.uint32(16)).astype(np.uint16)
    out[nan] = ((bits[nan] >> np.uint32(16)).astype(np.uint16)) | np.uint16(0x0040)
    return out


def bf16_bits_to_f32(h: np.ndarray) -> np.ndarray:
    return (h.astype(np.uint32) << np.uint32(16)).view(np.float32)


# -- tests/golden_vectors.rs ------------------------------------------------

def golden_rows(n: int) -> int:
    return 3 if n <= 1024 else 2


def golden_input(n: int) -> np.ndarray:
    rng = Rng(GOLDEN_SEED ^ n)
    rows = golden_rows(n)
    vals = [((rng.next_u64() >> 40) - (1 << 23)) / 65536.0 for _ in range(rows * n)]
    return np.array(vals, dtype=np.float32)


def sign_vector(seed: int, n: int) -> np.ndarray:
    """Port of hadamard/mod.rs::sign_vector: ±1 from the top bit of each
    draw of an Rng seeded with ``seed ^ n·0x9E3779B97F4A7C15``."""
    rng = Rng(seed ^ ((n * 0x9E3779B97F4A7C15) & MASK64))
    return np.array(
        [1.0 if (rng.next_u64() >> 63) == 0 else -1.0 for _ in range(n)],
        dtype=np.float32,
    )


def transform_bits(n: int, dtype: str, prologue_seed: int | None) -> np.ndarray:
    """Output bit patterns of one (n, dtype, prologue) golden case."""
    x = golden_input(n)
    rows = golden_rows(n)
    scale = normalized_scale(n)

    if dtype == "float16":
        x = x.astype(np.float16)
        wide = x.astype(np.float32)
    elif dtype == "bfloat16":
        b = f32_to_bf16_bits(x)
        wide = bf16_bits_to_f32(b)
    else:
        wide = x

    if prologue_seed is not None:
        signs = sign_vector(prologue_seed, n)
        wide = (wide.reshape(rows, n) * signs).reshape(-1)

    out = np.concatenate(
        [fwht_row_f32(wide[r * n:(r + 1) * n], n, scale) for r in range(rows)]
    )

    if dtype == "float32":
        return out.view(np.uint32)
    if dtype == "float16":
        return out.astype(np.float16).view(np.uint16).astype(np.uint32)
    return f32_to_bf16_bits(out).astype(np.uint32)


def fnv64(bits: np.ndarray, dtype: str) -> str:
    if dtype == "float32":
        data = bits.astype("<u4").tobytes()
    else:
        data = bits.astype("<u2").tobytes()
    h = 0xCBF29CE484222325
    for byte in data:
        h = ((h ^ byte) * 0x100000001B3) & MASK64
    return f"{h:#018x}"


def entry(kernel: str, n: int, dtype: str, prologue_seed: int | None) -> dict:
    bits = transform_bits(n, dtype, prologue_seed)
    e = {
        "kernel": kernel,
        "n": n,
        "rows": golden_rows(n),
        "seed": GOLDEN_SEED ^ n,
        "prefix_bits": [int(b) for b in bits[:PREFIX_LEN]],
        "fnv64": fnv64(bits, dtype),
    }
    if prologue_seed is not None:
        e["prologue_seed"] = prologue_seed
    return e


def golden_path(dtype: str) -> str:
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust", "tests", "golden", f"{dtype}.json",
    )


def verify(require_rotated: bool) -> int:
    """Recompute every checked-in entry; return the mismatch count."""
    bad = 0
    for dtype in ["float32", "float16", "bfloat16"]:
        with open(golden_path(dtype)) as f:
            doc = json.load(f)
        assert doc["schema"] == GOLDEN_SCHEMA
        cache: dict[tuple, tuple] = {}
        n_rotated = 0
        for e in doc["entries"]:
            seed = e.get("prologue_seed")
            if seed is not None:
                n_rotated += 1
            key = (e["n"], seed)
            if key not in cache:
                bits = transform_bits(e["n"], dtype, seed)
                cache[key] = ([int(b) for b in bits[:PREFIX_LEN]], fnv64(bits, dtype))
            prefix, digest = cache[key]
            tag = f"{dtype} {e['kernel']} n={e['n']} prologue={seed}"
            if e["prefix_bits"] != prefix:
                print(f"MISMATCH (prefix) {tag}")
                bad += 1
            elif e["fnv64"] != digest:
                print(f"MISMATCH (digest) {tag}")
                bad += 1
            else:
                print(f"ok {tag}  {digest}")
        if require_rotated and n_rotated != len(GOLDEN_SIZES) * len(KERNELS):
            print(f"{dtype}: expected rotated entries, found {n_rotated}")
            bad += 1
    return bad


def regen() -> None:
    for dtype in ["float32", "float16", "bfloat16"]:
        entries = []
        for n in GOLDEN_SIZES:
            for kernel in KERNELS:
                entries.append(entry(kernel, n, dtype, None))
        for n in GOLDEN_SIZES:
            for kernel in KERNELS:
                entries.append(entry(kernel, n, dtype, ROTATED_SEED))
        doc = {
            "schema": GOLDEN_SCHEMA,
            "dtype": dtype,
            "prefix_len": PREFIX_LEN,
            "entries": entries,
        }
        path = golden_path(dtype)
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"regenerated {path} ({len(entries)} entries)")


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "verify"
    if mode == "verify":
        sys.exit(1 if verify(require_rotated=True) else 0)
    elif mode == "verify-plain":
        sys.exit(1 if verify(require_rotated=False) else 0)
    elif mode == "regen":
        if verify(require_rotated=False):
            sys.exit("refusing to regen: existing entries do not reproduce")
        regen()
    else:
        sys.exit(f"unknown mode {mode}")
