"""HadaCore: matrix-unit-accelerated Fast Walsh-Hadamard Transform (Pallas).

This is the paper's Layer-1 contribution adapted from CUDA Tensor Cores to
the TPU MXU model (see DESIGN.md §Hardware-Adaptation):

* The CUDA kernel performs each FWHT round as a pair of Tensor Core
  ``mma.m16n8k16`` ops, i.e. a dense 16x16 matmul against the constant
  ``H_16``.  Here each round is a ``jnp.matmul`` with a 16-sized contracted
  axis — exactly the shape the MXU systolic array consumes.  Under
  ``interpret=True`` (required for CPU PJRT) the same HLO-level structure
  is produced, so numerics and op structure are validated even though the
  Mosaic TPU lowering is not exercised.
* The CUDA kernel's shared-memory transposes between 256-element fragments
  become in-VMEM ``reshape``/``moveaxis`` on the row tile — the BlockSpec
  already staged the whole tile from HBM to VMEM, so "transpose through
  shared memory" degenerates to a layout change of the VMEM block.
* The threadblock grid over rows becomes the Pallas ``grid`` over row
  blocks, with ``block_rows`` chosen to keep a tile within a VMEM budget.

Mathematics (paper §3.4): for ``n = 2**m * 16**r`` (``0 <= m < 4``),

    ``H_n = H_16^{(x r)} (Kron) H_{2^m}``

because Kronecker products of Sylvester factors associate.  Viewing each
row as an ``r+1``-dimensional tensor of shape ``(16,)*r + (2**m,)`` and
contracting each axis with the corresponding Hadamard factor performs the
full transform in ``ceil(log16 n)`` matmul rounds.

The paper's §3.3 block-diagonal trick (the final ``2^m`` factor applied as
a 16x16 matrix ``I kron H_{2^m}`` so the Tensor Core path is uniform) is
implemented literally by :func:`block_diagonal_hadamard` and used when
``use_block_diagonal=True`` (the default, matching the paper); the plain
small-matrix contraction is kept as an equivalent alternative and the test
suite asserts both paths agree bit-for-bit in f32.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import factor_16, hadamard_matrix, is_pow2

__all__ = [
    "hadacore",
    "hadacore_rounds",
    "block_diagonal_hadamard",
    "MAX_HADAMARD_SIZE",
    "default_block_rows",
]

# The paper supports up to 2^15 = 256 * 128 (one threadblock's shared
# memory / sync budget).  We keep the same ceiling so configuration space
# matches the evaluation grid.
MAX_HADAMARD_SIZE = 1 << 15

# VMEM budget per row tile, in bytes (f32 working precision).  Real TPU
# cores have ~16 MiB VMEM; a 2 MiB input tile leaves room for the output
# tile, the H16 constant and intermediates with double-buffering margin.
_VMEM_TILE_BYTES = 2 << 20


def default_block_rows(rows: int, n: int) -> int:
    """Rows per grid step such that a f32 tile stays within the VMEM budget."""
    cap = max(1, _VMEM_TILE_BYTES // (4 * n))
    return max(1, min(rows, cap))


def block_diagonal_hadamard(m: int, dtype=jnp.float32):
    """The paper's §3.3 matrix: ``H_{2^m}`` tiled along the diagonal of 16x16.

    Equals ``I_{16/2^m} kron H_{2^m}`` (unnormalised, entries in {-1,0,1}).
    For ``m == 0`` this is the identity (no residual factor).
    """
    if not 0 <= m < 4:
        raise ValueError(f"block-diagonal exponent must be in [0,4), got {m}")
    sub = 1 << m
    h = hadamard_matrix(sub, dtype=jnp.float32)
    eye = jnp.eye(16 // sub, dtype=jnp.float32)
    return jnp.kron(eye, h).astype(dtype)


def _traced_hadamard(size: int, sub: int, dtype):
    """Hadamard factor built from traced ops (no captured constants).

    Pallas kernels may not close over constant arrays, and — like the CUDA
    kernel, which synthesises H16 fragments in registers — we never want the
    factor resident in HBM anyway.  Uses the closed form
    ``H[i, j] = (-1)^popcount(i & j)`` for the Sylvester/Walsh-Hadamard
    matrix, restricted to diagonal blocks of size ``sub`` (``sub == size``
    gives the plain Hadamard; ``sub < size`` gives the paper's §3.3
    block-diagonal tiling ``I kron H_sub``).
    """
    i = jax.lax.broadcasted_iota(jnp.int32, (size, size), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (size, size), 1)
    same_block = (i // sub) == (j // sub)
    bits = jax.lax.population_count((i % sub) & (j % sub))
    sign = (1 - 2 * (bits & 1)).astype(dtype)
    return jnp.where(same_block, sign, jnp.zeros((), dtype))


def _apply_last(t, h):
    """Contract the last axis of ``t`` with (symmetric) Hadamard factor ``h``."""
    return jnp.matmul(t, h, preferred_element_type=t.dtype)


def hadacore_rounds(x, n: int, *, use_block_diagonal: bool = True):
    """The HadaCore round structure on a ``(R, n)`` f32 block (unnormalised).

    This is the kernel body shared by the Pallas kernel and the pure-jnp
    fallback: ``ceil(log16 n)`` rounds, each a matmul with a 16x16 factor
    (or the residual ``H_{2^m}``), with reshape/moveaxis standing in for
    the CUDA kernel's register/shared-memory transposes.
    """
    if not is_pow2(n) or n < 2:
        raise ValueError(f"Hadamard size must be a power of 2 >= 2, got {n}")
    if n > MAX_HADAMARD_SIZE:
        raise ValueError(
            f"Hadamard size {n} exceeds supported maximum {MAX_HADAMARD_SIZE}"
        )
    rows = x.shape[0]
    m, r = factor_16(n)
    h16 = _traced_hadamard(16, 16, x.dtype)

    t = x
    if m and use_block_diagonal and n >= 16:
        # Paper §3.3: fold the residual 2^m factor into one uniform 16x16
        # round using the block-diagonal tiling.  Viewing the fastest 16
        # elements as (16/2^m, 2^m), ``I kron H_{2^m}`` transforms the
        # fastest 2^m-axis.
        bd = _traced_hadamard(16, 1 << m, x.dtype)
        t = t.reshape(rows * (n // 16), 16)
        t = _apply_last(t, bd)
        t = t.reshape(rows, n)
        m_left = 0
    else:
        m_left = m

    # Tensor view: (rows, a_{r-1}..a_0 of 16, [fastest 2^m]) — the 2^m axis
    # was already handled above when folded into the block-diagonal round.
    axes = [16] * r + ([1 << m_left] if m_left else [])
    if m and not m_left:
        axes = [16] * r + [1 << m]  # keep the axis in the view, untouched
    if not axes:  # n < 16 handled by the caller via direct small matmul
        axes = [n]
    t = t.reshape((rows, *axes))
    for i, sz in enumerate(axes):
        if sz == 16:
            h = h16
        elif m_left and sz == (1 << m_left):
            h = _traced_hadamard(sz, sz, x.dtype)
        else:
            continue  # residual axis already transformed block-diagonally
        ax = 1 + i
        t = jnp.moveaxis(t, ax, -1)
        t = _apply_last(t, h)
        t = jnp.moveaxis(t, -1, ax)
    if r == 0 and not m_left and n < 16:
        # n in {2,4,8}: single small round (no 16-axis exists to fold into)
        t = _apply_last(t.reshape(rows, n), _traced_hadamard(n, n, x.dtype))
    return t.reshape(rows, n)


def _kernel(x_ref, o_ref, *, n: int, scale: float, use_block_diagonal: bool,
            accum_dtype):
    """Pallas kernel body: one row tile, full transform, scaled write-back."""
    x = x_ref[...].astype(accum_dtype)
    y = hadacore_rounds(x, n, use_block_diagonal=use_block_diagonal)
    o_ref[...] = (y * jnp.asarray(scale, accum_dtype)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "block_rows", "use_block_diagonal", "accum_dtype", "interpret",
    ),
)
def hadacore(
    x,
    scale: float | None = None,
    *,
    block_rows: int | None = None,
    use_block_diagonal: bool = True,
    accum_dtype=jnp.float32,
    interpret: bool = True,
):
    """Right Walsh-Hadamard transform of the last axis: ``x @ H_n * scale``.

    Args:
      x: ``(..., n)`` array, ``n`` a power of two, ``n <= 2**15``.  f32,
        bf16 and f16 inputs are supported; compute runs in ``accum_dtype``
        (f32 by default — the paper's BF16 path accumulates in FP32 and
        converts back, which is exactly what happens here for 16-bit
        inputs).
      scale: output scaling; defaults to ``1/sqrt(n)`` (orthonormal).
      block_rows: rows per Pallas grid step; default fits the VMEM budget.
      use_block_diagonal: apply the residual non-power-of-16 factor as the
        paper's block-diagonal 16x16 round (True) or as a direct small
        contraction (False).  Numerically identical.
      interpret: run the Pallas kernel in interpret mode (required on CPU;
        set False only when lowering for a real TPU).

    Returns an array of the same shape/dtype as ``x``.
    """
    if x.ndim == 0:
        raise ValueError("input must have at least one dimension")
    n = x.shape[-1]
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    if n > MAX_HADAMARD_SIZE:
        raise ValueError(
            f"Hadamard size {n} exceeds supported maximum {MAX_HADAMARD_SIZE}"
        )
    if scale is None:
        scale = 1.0 / math.sqrt(n)

    lead = x.shape[:-1]
    rows = 1
    for d in lead:
        rows *= int(d)
    x2 = x.reshape(rows, n)

    br = block_rows or default_block_rows(rows, n)
    br = min(br, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, n), x2.dtype)], axis=0)
    padded_rows = rows + pad

    kernel = functools.partial(
        _kernel,
        n=n,
        scale=float(scale),
        use_block_diagonal=use_block_diagonal,
        accum_dtype=accum_dtype,
    )
    y = pl.pallas_call(
        kernel,
        grid=(padded_rows // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, n), x.dtype),
        interpret=interpret,
    )(x2)
    if pad:
        y = y[:rows]
    return y.reshape(*lead, n)
