"""Pure-jnp correctness oracles for the Hadamard transform kernels.

Two independent references:

* :func:`fwht_matmul` — materialises the Walsh-Hadamard matrix via
  Sylvester's construction and performs an explicit matmul.  This is the
  ground truth the paper's own unit tests use ("basic unit tests that check
  the output of HadaCore against the output of an explicit Hadamard matrix
  multiplication").
* :func:`fwht_butterfly` — the textbook in-place Fast Walsh-Hadamard
  Transform loop (the algorithm the Dao AI Lab CUDA kernel implements),
  expressed with vectorised jnp ops, one butterfly stage per level.

Both operate on the last axis of an ``(rows, n)`` array, matching the
right-Hadamard-transform convention of the fast-hadamard-transform library
(``out = x @ H_n * scale``; Walsh-Hadamard matrices are symmetric).
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax.numpy as jnp
import numpy as np

__all__ = [
    "hadamard_matrix",
    "fwht_matmul",
    "fwht_butterfly",
    "is_pow2",
    "factor_16",
]


def is_pow2(n: int) -> bool:
    """True iff ``n`` is a positive power of two."""
    return n > 0 and (n & (n - 1)) == 0


def factor_16(n: int) -> tuple[int, int]:
    """Factor ``n = 2**m * 16**r`` with ``0 <= m < 4``.

    This is the decomposition HadaCore §3.3 uses: ``r`` full 16-size
    Hadamard rounds plus one final round with a block-diagonal tiling of
    ``H_{2^m}`` when ``m > 0``.
    """
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    k = n.bit_length() - 1
    return k % 4, k // 4


@lru_cache(maxsize=None)
def _hadamard_np(n: int) -> np.ndarray:
    """Unnormalised Walsh-Hadamard matrix (entries ±1) as float64 numpy."""
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def hadamard_matrix(n: int, dtype=jnp.float32, normalized: bool = False):
    """Walsh-Hadamard matrix ``H_n`` (Sylvester construction).

    ``normalized=True`` scales by ``1/sqrt(n)`` so the matrix is orthogonal.
    """
    h = _hadamard_np(n)
    if normalized:
        h = h / math.sqrt(n)
    return jnp.asarray(h, dtype=dtype)


def fwht_matmul(x, scale: float | None = None):
    """Reference right-Hadamard transform via explicit matmul.

    ``x``: (..., n).  ``scale`` defaults to ``1/sqrt(n)`` (the orthogonal /
    norm-preserving convention used throughout the paper).
    """
    n = x.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(n)
    h = _hadamard_np(n)
    y = np.asarray(x, dtype=np.float64) @ h * scale
    return jnp.asarray(y, dtype=x.dtype)


def fwht_butterfly(x, scale: float | None = None):
    """Reference FWHT via the classic butterfly recursion (vectorised).

    Matches the inner loop of the Dao AI Lab kernel / the Wikipedia
    pseudocode in the paper §2.2: ``log2(n)`` stages of pairwise
    add/subtract on elements ``h`` apart.
    """
    n = x.shape[-1]
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    if scale is None:
        scale = 1.0 / math.sqrt(n)
    orig_dtype = x.dtype
    lead = x.shape[:-1]
    y = jnp.asarray(x, dtype=jnp.float32)
    h = 1
    while h < n:
        # view the last axis as (n // (2h), 2, h): pairs are h apart
        y = y.reshape(*lead, n // (2 * h), 2, h)
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    y = y.reshape(*lead, n) * scale
    return y.astype(orig_dtype)
