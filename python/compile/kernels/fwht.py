"""Baseline butterfly FWHT as a Pallas kernel (the Dao-kernel algorithm).

The Dao AI Lab ``fast-hadamard-transform`` CUDA kernel executes the classic
2-point-butterfly recursion with a carefully staged data exchange
(8 elements per thread -> warp shuffles -> two threadblock syncs through
shared memory, paper §2.4).  On the Pallas/TPU side all of that staging
collapses into VMEM-resident reshapes, so the faithful analogue is the
butterfly recursion itself applied to a row tile: ``log2(n)`` vector
add/sub stages — vector-unit (VPU) work, no matrix unit involvement.

This kernel exists as the *measured baseline* for the paper's comparisons:
HadaCore (``hadacore.py``, matrix-unit rounds) vs the original algorithm
(this file, butterfly stages).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hadacore import MAX_HADAMARD_SIZE, default_block_rows
from .ref import is_pow2

__all__ = ["fwht_baseline", "butterfly_rounds"]


def butterfly_rounds(x, n: int):
    """``log2(n)`` butterfly stages on a ``(R, n)`` block (unnormalised)."""
    rows = x.shape[0]
    t = x
    h = 1
    while h < n:
        t = t.reshape(rows, n // (2 * h), 2, h)
        a = t[:, :, 0, :]
        b = t[:, :, 1, :]
        t = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return t.reshape(rows, n)


def _kernel(x_ref, o_ref, *, n: int, scale: float, accum_dtype):
    x = x_ref[...].astype(accum_dtype)
    y = butterfly_rounds(x, n)
    o_ref[...] = (y * jnp.asarray(scale, accum_dtype)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_rows", "accum_dtype", "interpret"),
)
def fwht_baseline(
    x,
    scale: float | None = None,
    *,
    block_rows: int | None = None,
    accum_dtype=jnp.float32,
    interpret: bool = True,
):
    """Right Walsh-Hadamard transform via the butterfly algorithm.

    Same contract as :func:`hadacore.hadacore`; used as the measured
    baseline ("Dao AI Lab kernel" analogue) in benchmarks and tests.
    """
    n = x.shape[-1]
    if not is_pow2(n):
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    if n > MAX_HADAMARD_SIZE:
        raise ValueError(
            f"Hadamard size {n} exceeds supported maximum {MAX_HADAMARD_SIZE}"
        )
    if scale is None:
        scale = 1.0 / math.sqrt(n)

    lead = x.shape[:-1]
    rows = 1
    for d in lead:
        rows *= int(d)
    x2 = x.reshape(rows, n)

    br = block_rows or default_block_rows(rows, n)
    br = min(br, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, n), x2.dtype)], axis=0)
    padded_rows = rows + pad

    kernel = functools.partial(
        _kernel, n=n, scale=float(scale), accum_dtype=accum_dtype
    )
    y = pl.pallas_call(
        kernel,
        grid=(padded_rows // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, n), x.dtype),
        interpret=interpret,
    )(x2)
    if pad:
        y = y[:rows]
    return y.reshape(*lead, n)
