"""AOT pipeline: lower every Layer-1/Layer-2 entry point to HLO *text*.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits ``HloModuleProto``s with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written to ``--out-dir`` (default ``../artifacts``):

* ``fwht_{kernel}_{n}x{rows}.hlo.txt`` — the transform kernels at every
  serving bucket shape (HadaCore for the full size grid, butterfly for the
  baseline comparison points).
* ``attn_{variant}.hlo.txt`` — the standalone QuaRot attention block per
  numerics variant.
* ``lm_{variant}.hlo.txt`` — the full LM forward per variant (the
  MMLU-analog accuracy study scores these).
* ``weights.bin`` / ``train_log.json`` / ``eval.json`` — build-time
  training outputs (see ``train.py``).
* ``manifest.json`` — machine-readable index the Rust registry loads.

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train as train_mod
from .model import (
    VARIANTS,
    ModelConfig,
    default_config,
    flatten_params,
    init_params,
    make_attn_fn,
    make_fwht_fn,
    make_lm_fn,
)

# serving bucket shapes: (n, rows) — rows chosen so a bucket is one
# "batch" the coordinator pads to. Grid covers the paper's size axis.
FWHT_BUCKETS = [
    (128, 256),
    (256, 128),
    (512, 64),
    (1024, 32),
    (2048, 16),
    (4096, 8),
    (8192, 4),
    (16384, 2),
    (32768, 1),
]
BASELINE_BUCKETS = [(1024, 32), (4096, 8)]

ATTN_BATCH = 4
LM_BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_to_file(fn, args, path: str) -> int:
    """Lower ``fn(*args)`` and write HLO text; returns byte count."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def dtype_name(d) -> str:
    return jnp.dtype(d).name


def build_fwht_artifacts(out_dir: str) -> list[dict]:
    entries = []
    jobs = [("hadacore", n, r) for n, r in FWHT_BUCKETS] + [
        ("butterfly", n, r) for n, r in BASELINE_BUCKETS
    ]
    for kernel, n, rows in jobs:
        name = f"fwht_{kernel}_{n}x{rows}"
        path = f"{out_dir}/{name}.hlo.txt"
        size = lower_to_file(
            make_fwht_fn(n, rows, kernel), (spec((rows, n)),), path
        )
        print(f"[aot] {name}: {size} bytes")
        entries.append(
            {
                "name": name,
                "op": "fwht",
                "kernel": kernel,
                "file": f"{name}.hlo.txt",
                "inputs": [{"shape": [rows, n], "dtype": "float32"}],
                "outputs": [{"shape": [rows, n], "dtype": "float32"}],
                "n": n,
                "rows": rows,
            }
        )
    return entries


def build_attn_artifacts(out_dir: str, cfg: ModelConfig) -> list[dict]:
    entries = []
    d = cfg.dim
    x = spec((ATTN_BATCH, cfg.seq_len, d))
    w = spec((d, d))
    for variant in VARIANTS:
        name = f"attn_{variant.name}"
        path = f"{out_dir}/{name}.hlo.txt"
        size = lower_to_file(make_attn_fn(cfg, variant), (x, w, w, w, w), path)
        print(f"[aot] {name}: {size} bytes")
        entries.append(
            {
                "name": name,
                "op": "attention",
                "variant": variant.name,
                "file": f"{name}.hlo.txt",
                "inputs": [
                    {"shape": [ATTN_BATCH, cfg.seq_len, d], "dtype": "float32"},
                    {"shape": [d, d], "dtype": "float32"},
                    {"shape": [d, d], "dtype": "float32"},
                    {"shape": [d, d], "dtype": "float32"},
                    {"shape": [d, d], "dtype": "float32"},
                ],
                "outputs": [
                    {"shape": [ATTN_BATCH, cfg.seq_len, d], "dtype": "float32"}
                ],
            }
        )
    return entries


def build_lm_artifacts(out_dir: str, cfg: ModelConfig) -> list[dict]:
    entries = []
    # weight input specs in flatten order (shapes from a throwaway init)
    shapes = [
        tuple(a.shape) for _, a in flatten_params(
            init_params(jax.random.PRNGKey(0), cfg), cfg
        )
    ]
    names = [
        n for n, _ in flatten_params(init_params(jax.random.PRNGKey(0), cfg), cfg)
    ]
    tokens = spec((LM_BATCH, cfg.seq_len), jnp.int32)
    weight_specs = [spec(s) for s in shapes]
    for variant in VARIANTS:
        name = f"lm_{variant.name}"
        path = f"{out_dir}/{name}.hlo.txt"
        size = lower_to_file(
            make_lm_fn(cfg, variant), (tokens, *weight_specs), path
        )
        print(f"[aot] {name}: {size} bytes")
        entries.append(
            {
                "name": name,
                "op": "lm_forward",
                "variant": variant.name,
                "file": f"{name}.hlo.txt",
                "inputs": [
                    {"shape": [LM_BATCH, cfg.seq_len], "dtype": "int32"},
                    *[
                        {"shape": list(s), "dtype": "float32", "weight": n}
                        for s, n in zip(shapes, names)
                    ],
                ],
                "outputs": [
                    {
                        "shape": [LM_BATCH, cfg.seq_len, cfg.vocab],
                        "dtype": "float32",
                    }
                ],
            }
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse existing weights.bin if present")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    cfg = default_config()
    manifest: dict = {
        "version": 1,
        "model": {
            "vocab": cfg.vocab,
            "dim": cfg.dim,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "seq_len": cfg.seq_len,
            "lm_batch": LM_BATCH,
            "attn_batch": ATTN_BATCH,
        },
        "artifacts": [],
    }

    manifest["artifacts"] += build_fwht_artifacts(out_dir)
    manifest["artifacts"] += build_attn_artifacts(out_dir, cfg)
    manifest["artifacts"] += build_lm_artifacts(out_dir, cfg)

    weights_path = f"{out_dir}/weights.bin"
    if args.skip_train and os.path.exists(weights_path):
        print("[aot] --skip-train: reusing existing weights.bin")
        with open(f"{out_dir}/manifest.json") as f:
            manifest["weights"] = json.load(f)["weights"]
    else:
        result = train_mod.run(cfg, out_dir, steps=args.train_steps)
        manifest["weights"] = result["weights"]
        manifest["final_train_loss"] = result["final_loss"]

    with open(f"{out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest")


if __name__ == "__main__":
    main()
