"""L1 kernel structure analysis (perf-pass instrumentation).

interpret=True Pallas gives CPU-numpy timings only — not a TPU proxy — so
the L1 performance story is *structural* (DESIGN.md §Perf): per-tile VMEM
footprint implied by the BlockSpec, the matmul-round count, and the op mix
of the lowered HLO (matrix-unit work vs data movement). This script
derives those numbers for every fwht artifact and for a sweep of
block_rows choices; its output is recorded in EXPERIMENTS.md §Perf.

Usage: cd python && python -m compile.analyze [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import re

from .kernels.hadacore import default_block_rows
from .kernels.ref import factor_16

VMEM_BYTES = 16 << 20  # per-core VMEM on current TPU generations


def hlo_op_histogram(text: str) -> dict[str, int]:
    """Count HLO instruction kinds in an HLO text module (all
    computations, including called subcomputations)."""
    ops: dict[str, int] = {}
    for m in re.finditer(r"\b([a-z][a-z-]*[a-z])\(", text):
        op = m.group(1)
        ops[op] = ops.get(op, 0) + 1
    return ops


def kernel_structure(n: int, rows: int) -> dict:
    """Static structure of one (rows, n) hadacore tile."""
    m, r = factor_16(n)
    rounds = r + (1 if m else 0)
    br = default_block_rows(rows, n)
    tile_bytes = br * n * 4
    # per round: (tile elements / 16) 16x16(x16) MAC tiles on the MXU
    mxu_tiles_per_round = br * n // 16
    # matmul flops per tile vs bytes staged HBM->VMEM per tile
    flops = 2 * 16 * br * n * rounds
    bytes_moved = 2 * br * n * 4
    return {
        "n": n,
        "rows": rows,
        "block_rows": br,
        "rounds": rounds,
        "tile_vmem_bytes": tile_bytes,
        "tile_vmem_frac": tile_bytes / VMEM_BYTES,
        "mxu_tiles_per_round": mxu_tiles_per_round,
        "arith_intensity_flops_per_byte": flops / bytes_moved,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    with open(f"{args.artifacts}/manifest.json") as f:
        manifest = json.load(f)

    print(f"{'artifact':<28} {'rounds':>6} {'blk':>5} {'VMEM/tile':>10} "
          f"{'%VMEM':>6} {'dots':>5} {'transp':>6} {'reshape':>8} {'total_ops':>9}")
    for a in manifest["artifacts"]:
        if a["op"] != "fwht" or a.get("kernel") != "hadacore":
            continue
        n, rows = a["n"], a["rows"]
        s = kernel_structure(n, rows)
        text = open(f"{args.artifacts}/{a['file']}").read()
        ops = hlo_op_histogram(text)
        print(
            f"{a['name']:<28} {s['rounds']:>6} {s['block_rows']:>5} "
            f"{s['tile_vmem_bytes']:>10} {s['tile_vmem_frac']:>6.1%} "
            f"{ops.get('dot', 0):>5} {ops.get('transpose', 0):>6} "
            f"{ops.get('reshape', 0):>8} {sum(ops.values()):>9}"
        )

    print("\nblock_rows sweep (n=4096): VMEM fraction vs MXU tiles in flight")
    for br in [1, 4, 16, 64, 128]:
        tile = br * 4096 * 4
        print(f"  block_rows={br:>4}: tile {tile/1e6:6.2f} MB "
              f"({tile/VMEM_BYTES:5.1%} of VMEM), "
              f"{br*4096//16:>6} MXU tiles/round")


if __name__ == "__main__":
    main()
