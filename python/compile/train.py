"""Build-time training of the small LM on a synthetic corpus.

The paper's §4.2 needs a *trained* model whose attention numerics can be
perturbed (FP8, rotations) and measured on a multiple-choice benchmark.
No pretrained weights or MMLU data exist in this environment, so this
module (run once by ``make artifacts``):

1. builds a synthetic corpus from a seeded sparse Markov chain over the
   vocabulary (low-entropy structure a 2-layer model can learn well);
2. trains the fp16 (clean-numerics) variant with hand-rolled Adam for a
   few hundred steps, logging the loss curve;
3. emits an MMLU-analog multiple-choice evaluation set: prompt prefix from
   the chain, the true continuation plus 3 distractor continuations;
4. serialises trained weights to ``weights.bin`` (little-endian f32 in
   ``flatten_params`` order) for the Rust runtime.

Everything is deterministic given the seed.
"""

from __future__ import annotations

import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import (
    AttnVariant,
    ModelConfig,
    flatten_params,
    init_params,
    lm_loss,
    param_count,
)

CORPUS_SEED = 20240707
BRANCH = 4  # likely next-states per state


def markov_table(vocab: int, seed: int = CORPUS_SEED) -> np.ndarray:
    """Sparse stochastic transition table: each state has BRANCH likely
    successors (90% mass) and a uniform 10% exploration floor."""
    rng = np.random.default_rng(seed)
    table = np.full((vocab, vocab), 0.1 / vocab, dtype=np.float64)
    for s in range(vocab):
        nxt = rng.choice(vocab, size=BRANCH, replace=False)
        w = rng.dirichlet(np.ones(BRANCH)) * 0.9
        table[s, nxt] += w
    table /= table.sum(axis=1, keepdims=True)
    return table


def sample_chain(table: np.ndarray, length: int, rng: np.random.Generator):
    """One token sequence from the chain."""
    vocab = table.shape[0]
    seq = np.empty(length, dtype=np.int32)
    s = rng.integers(vocab)
    for t in range(length):
        seq[t] = s
        s = rng.choice(vocab, p=table[s])
    return seq


def make_batches(cfg: ModelConfig, steps: int, batch: int, seed: int):
    """Iterator of (batch, seq_len+1) token arrays."""
    table = markov_table(cfg.vocab)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield np.stack(
            [sample_chain(table, cfg.seq_len + 1, rng) for _ in range(batch)]
        )


def make_eval_set(cfg: ModelConfig, n_questions: int, seed: int, k_choices: int = 4,
                  cont_len: int = 8):
    """MMLU-analog multiple choice: which continuation follows the prefix?

    The correct answer is a genuine sample of the chain continuing the
    prefix; each distractor is the true continuation with only the FINAL
    token replaced by a *plausible* alternative drawn from the chain's
    transition distribution at that point. The decision margin is then a
    single token's log-probability difference between two plausible
    continuations — deliberately tight, so the benchmark is sensitive to
    small attention-numerics changes (the regime where the paper's
    ~1-point MMLU deltas live). The clean model scores well above chance
    but below 100%.
    """
    table = markov_table(cfg.vocab)
    rng = np.random.default_rng(seed)
    prefix_len = cfg.seq_len - cont_len
    questions = []
    for _ in range(n_questions):
        full = sample_chain(table, cfg.seq_len, rng)
        prefix = full[:prefix_len]
        correct = full[prefix_len:]
        prev = int(correct[-2]) if cont_len >= 2 else int(prefix[-1])
        true_last = int(correct[-1])
        choices = []
        answer = int(rng.integers(k_choices))
        for c in range(k_choices):
            if c == answer:
                choices.append(correct.tolist())
            else:
                corrupted = correct.copy()
                # plausible alternative final token (never the true one)
                alt = true_last
                while alt == true_last:
                    alt = int(rng.choice(cfg.vocab, p=table[prev]))
                corrupted[-1] = alt
                choices.append(corrupted.tolist())
        questions.append(
            {
                "prefix": prefix.tolist(),
                "choices": choices,
                "answer": answer,
            }
        )
    return {
        "prefix_len": prefix_len,
        "cont_len": cont_len,
        "k_choices": k_choices,
        "questions": questions,
    }


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale)
        / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps: int = 400, batch: int = 16, seed: int = 0,
          log_every: int = 20):
    """Train the clean-numerics variant; returns (params, loss_log)."""
    variant = AttnVariant(quant="none", rotate="none")
    params = init_params(jax.random.PRNGKey(seed), cfg)

    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, tokens, cfg, variant)
        )(params)
        params, opt = adam_update(params, grads, opt)
        return params, opt, loss

    opt = adam_init(params)
    log = []
    t0 = time.time()
    for i, tokens in enumerate(make_batches(cfg, steps, batch, seed + 1)):
        params, opt, loss = step(params, opt, jnp.asarray(tokens))
        if i % log_every == 0 or i == steps - 1:
            log.append({"step": i, "loss": float(loss),
                        "elapsed_s": round(time.time() - t0, 2)})
            print(f"  step {i:4d}  loss {float(loss):.4f}")
    return params, log


def save_weights(params, cfg: ModelConfig, bin_path: str):
    """weights.bin layout: concatenated little-endian f32 tensors in
    flatten_params order. Returns the manifest entries."""
    flat = flatten_params(params, cfg)
    entries = []
    offset = 0
    with open(bin_path, "wb") as f:
        for name, arr in flat:
            a = np.asarray(arr, dtype="<f4")
            f.write(a.tobytes())
            entries.append(
                {"name": name, "shape": list(a.shape), "offset": offset,
                 "numel": int(a.size)}
            )
            offset += a.size
    return entries


def run(cfg: ModelConfig, out_dir: str, steps: int, n_eval: int = 200):
    """Full build-time pipeline; returns manifest fragments."""
    print(f"[train] model params: {param_count(init_params(jax.random.PRNGKey(0), cfg)):,}")
    params, log = train(cfg, steps=steps)
    weight_entries = save_weights(params, cfg, f"{out_dir}/weights.bin")
    with open(f"{out_dir}/train_log.json", "w") as f:
        json.dump({"steps": steps, "log": log}, f, indent=1)
    eval_set = make_eval_set(cfg, n_eval, seed=CORPUS_SEED + 1)
    with open(f"{out_dir}/eval.json", "w") as f:
        json.dump(eval_set, f)
    # naive-chance sanity: k choices -> 1/k
    print(f"[train] final loss {log[-1]['loss']:.4f} "
          f"(uniform would be {math.log(cfg.vocab):.4f})")
    return {"weights": weight_entries, "final_loss": log[-1]["loss"]}
