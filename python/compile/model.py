"""Layer 2: QuaRot-style transformer with online Hadamard rotations.

The paper's end-to-end evaluation (§4.2) runs Llama-3.1-8B with FP8
attention, comparing no-rotation against online Hadamard rotation performed
by either the Dao AI Lab kernel or HadaCore. This module is the analogous
compute graph at a scale this environment can train and serve:

* a small causal transformer LM (RMSNorm / MHA / SwiGLU-ish MLP, tied
  embeddings) whose attention can run in three variants:
  - ``fp16`` (clean baseline — f32 here, "full precision"),
  - ``fp8`` (fake-quantised e4m3 Q/K/V, no rotation),
  - ``fp8 + rotation`` (Q/K rotated along head_dim before quantisation,
    V rotated with the inverse applied after the attention-weighted sum —
    mathematically identity transforms, numerically outlier-flattening),
  where the rotation kernel is either HadaCore (L1 Pallas, 16x16 matmul
  rounds) or the butterfly baseline — mirroring the paper's two columns.
* FP8 (e4m3) fake-quantisation implemented arithmetically (exp/floor/round)
  so the lowered HLO uses only ops the xla_extension 0.5.1 text parser
  accepts (no f8 dtypes on the wire).

Everything here is build-time only: ``aot.py`` lowers the functions to HLO
text artifacts and the Rust runtime executes them; ``train.py`` fits the
weights on a synthetic corpus at artifact-build time.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.fwht import fwht_baseline
from .kernels.hadacore import hadacore

# --------------------------------------------------------------------------
# configuration


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Small-LM hyperparameters (defaults sized to train on CPU minutes)."""

    vocab: int = 256
    dim: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq_len: int = 64
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.dim % self.n_heads == 0
        return self.dim // self.n_heads


@dataclasses.dataclass(frozen=True)
class AttnVariant:
    """Which attention numerics to run (the paper's §4.2 comparison set,
    plus INT8 — the QuaRot setting the paper's §1 motivates).

    A measured note recorded in EXPERIMENTS.md: per-tensor-scaled FP8
    (e4m3) is a *floating-point* format, hence scale-free and to first
    order rotation-neutral; the outlier-flattening benefit of Hadamard
    rotations accrues to *uniform* quantisers (INT8/INT4). We therefore
    carry both: FP8 variants reproduce the paper's numerical-parity claim
    (HadaCore == exact kernel), INT8 variants reproduce the accuracy-
    recovery mechanism.
    """

    quant: str = "none"  # none | fp8 | int8
    rotate: str = "none"  # none | hadacore | butterfly

    @property
    def name(self) -> str:
        if self.quant == "none":
            return "fp16"
        if self.rotate == "none":
            return f"{self.quant}_norot"
        return f"{self.quant}_rot_{self.rotate}"


VARIANTS = (
    AttnVariant(quant="none", rotate="none"),
    AttnVariant(quant="fp8", rotate="none"),
    AttnVariant(quant="fp8", rotate="hadacore"),
    AttnVariant(quant="fp8", rotate="butterfly"),
    AttnVariant(quant="int8", rotate="none"),
    AttnVariant(quant="int8", rotate="hadacore"),
    AttnVariant(quant="int8", rotate="butterfly"),
)

# --------------------------------------------------------------------------
# numerics


def fake_quant_fp8(x, max_finite: float = 448.0, mant_bits: int = 3,
                   min_exp: float = -6.0):
    """Arithmetic e4m3 fake-quantisation with per-tensor max-abs scaling.

    Matches the Rust `quant::fp8` emulation: symmetric scale to the format
    maximum, round-to-nearest-even at 3 mantissa bits, saturating. Uses only
    basic HLO ops (abs/log2/floor/round) so artifacts parse under
    xla_extension 0.5.1.
    """
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / max_finite
    v = x / scale
    mag = jnp.abs(v)
    e = jnp.floor(jnp.log2(jnp.maximum(mag, 1e-30)))
    e = jnp.clip(e, min_exp, None)
    quantum = jnp.exp2(e - mant_bits)
    r = jnp.round(mag / quantum)  # jnp.round = round-half-to-even
    out = jnp.sign(v) * jnp.minimum(r * quantum, max_finite)
    out = jnp.where(mag < 1e-30, jnp.zeros_like(out), out)
    return out * scale


def fake_quant_int8(x, qmax: float = 127.0):
    """Symmetric per-tensor INT8 fake-quantisation (round-half-even)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / qmax
    return jnp.round(x / scale) * scale


def fake_quant(x, scheme: str):
    """Dispatch by scheme name ('none' passes through)."""
    if scheme == "none":
        return x
    if scheme == "fp8":
        return fake_quant_fp8(x)
    if scheme == "int8":
        return fake_quant_int8(x)
    raise ValueError(f"unknown quant scheme {scheme!r}")


def rotate_last(x, kind: str):
    """Normalised Hadamard rotation of the last axis by the chosen kernel."""
    if kind == "none":
        return x
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    if kind == "hadacore":
        y = hadacore(flat)
    elif kind == "butterfly":
        y = fwht_baseline(flat)
    else:
        raise ValueError(f"unknown rotation kernel {kind!r}")
    return y.reshape(shape)


def rmsnorm(x, gamma, eps: float = 1e-5):
    """RMSNorm over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


# --------------------------------------------------------------------------
# attention


def attention(params: dict, x, cfg: ModelConfig, variant: AttnVariant):
    """Causal multi-head attention with optional FP8 + Hadamard rotation.

    The rotation placement follows QuaRot's online scheme restricted to the
    attention path (paper Fig. 1 red blocks): Q and K are rotated along
    head_dim before quantisation (softmax(QK^T) is invariant because H is
    orthogonal), and V is rotated with the inverse rotation folded into the
    attention output (H symmetric orthogonal => inverse == itself).
    """
    b, t, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    q = (x @ params["wq"]).reshape(b, t, h, hd)
    k = (x @ params["wk"]).reshape(b, t, h, hd)
    v = (x @ params["wv"]).reshape(b, t, h, hd)

    if variant.rotate != "none":
        q = rotate_last(q, variant.rotate)
        k = rotate_last(k, variant.rotate)
        v = rotate_last(v, variant.rotate)

    if variant.quant != "none":
        q = fake_quant(q, variant.quant)
        k = fake_quant(k, variant.quant)
        v = fake_quant(v, variant.quant)

    q = q.transpose(0, 2, 1, 3)  # (b, h, t, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)

    if variant.rotate != "none":
        # undo the V rotation (H is its own inverse when normalised)
        out = rotate_last(out, variant.rotate)

    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.dim)
    return out @ params["wo"]


def mlp(params: dict, x):
    """Gated MLP (SwiGLU-style with silu gate)."""
    gate = jax.nn.silu(x @ params["wg"])
    up = x @ params["wu"]
    return (gate * up) @ params["wd"]


def block(params: dict, x, cfg: ModelConfig, variant: AttnVariant):
    """One pre-norm transformer block."""
    x = x + attention(params["attn"], rmsnorm(x, params["ln1"]), cfg, variant)
    x = x + mlp(params["mlp"], rmsnorm(x, params["ln2"]))
    return x


def lm_forward(params: dict, tokens, cfg: ModelConfig, variant: AttnVariant):
    """Token ids ``(b, t)`` -> logits ``(b, t, vocab)``. Tied embeddings."""
    x = params["embed"][tokens]
    for layer in params["layers"]:
        x = block(layer, x, cfg, variant)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T


def lm_loss(params: dict, tokens, cfg: ModelConfig, variant: AttnVariant):
    """Mean next-token cross-entropy over the sequence."""
    logits = lm_forward(params, tokens[:, :-1], cfg, variant)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# parameters


def init_params(key, cfg: ModelConfig) -> dict:
    """Scaled-normal initialisation."""
    def dense(key, fan_in, fan_out):
        return jax.random.normal(key, (fan_in, fan_out), jnp.float32) / math.sqrt(
            fan_in
        )

    keys = jax.random.split(key, 2 + cfg.n_layers)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.dim), jnp.float32)
        * 0.02,
        "ln_f": jnp.ones((cfg.dim,), jnp.float32),
        "layers": [],
    }
    d, m = cfg.dim, cfg.dim * cfg.mlp_mult
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + i], 7)
        params["layers"].append(
            {
                "attn": {
                    "wq": dense(lk[0], d, d),
                    "wk": dense(lk[1], d, d),
                    "wv": dense(lk[2], d, d),
                    "wo": dense(lk[3], d, d),
                },
                "mlp": {
                    "wg": dense(lk[4], d, m),
                    "wu": dense(lk[5], d, m),
                    "wd": dense(lk[6], m, d),
                },
                "ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
            }
        )
    return params


def param_count(params) -> int:
    """Total number of scalar parameters."""
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# flat (name, array) list in a deterministic order — the layout contract
# shared with the Rust weight loader (artifacts/weights.bin).


def flatten_params(params: dict, cfg: ModelConfig) -> list[tuple[str, Any]]:
    """Deterministic (name, tensor) list. Order defines weights.bin layout."""
    out = [("embed", params["embed"]), ("ln_f", params["ln_f"])]
    for i, layer in enumerate(params["layers"]):
        for k in ("wq", "wk", "wv", "wo"):
            out.append((f"layers.{i}.attn.{k}", layer["attn"][k]))
        for k in ("wg", "wu", "wd"):
            out.append((f"layers.{i}.mlp.{k}", layer["mlp"][k]))
        out.append((f"layers.{i}.ln1", layer["ln1"]))
        out.append((f"layers.{i}.ln2", layer["ln2"]))
    assert len(out) == 2 + 9 * cfg.n_layers
    return out


def unflatten_params(flat: list, cfg: ModelConfig) -> dict:
    """Inverse of :func:`flatten_params` given tensors in the same order."""
    it = iter(flat)
    params = {"embed": next(it), "ln_f": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        attn = {k: next(it) for k in ("wq", "wk", "wv", "wo")}
        mlp_p = {k: next(it) for k in ("wg", "wu", "wd")}
        params["layers"].append(
            {"attn": attn, "mlp": mlp_p, "ln1": next(it), "ln2": next(it)}
        )
    return params


# --------------------------------------------------------------------------
# standalone attention entry point (per-variant AOT artifact)


def make_attn_fn(cfg: ModelConfig, variant: AttnVariant):
    """A jit-able ``(x, wq, wk, wv, wo) -> out`` closure for AOT lowering."""

    def fn(x, wq, wk, wv, wo):
        params = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
        return (attention(params, x, cfg, variant),)

    return fn


def make_lm_fn(cfg: ModelConfig, variant: AttnVariant):
    """A jit-able ``(tokens, *flat_weights) -> logits`` closure for AOT."""

    def fn(tokens, *flat):
        params = unflatten_params(list(flat), cfg)
        return (lm_forward(params, tokens, cfg, variant),)

    return fn


def make_fwht_fn(n: int, rows: int, kernel: str):
    """A jit-able ``(x,) -> y`` transform closure for AOT (fixed shape)."""

    def fn(x):
        if kernel == "hadacore":
            return (hadacore(x),)
        if kernel == "butterfly":
            return (fwht_baseline(x),)
        raise ValueError(f"unknown kernel {kernel!r}")

    _ = (n, rows)
    return fn


@functools.lru_cache(maxsize=None)
def default_config() -> ModelConfig:
    """The configuration used by artifacts + the accuracy study."""
    return ModelConfig()
