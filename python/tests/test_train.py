"""Build-time training pipeline tests: corpus statistics, eval-set
construction, Adam, weight serialisation layout."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelConfig, flatten_params, init_params
from compile.train import (
    adam_init,
    adam_update,
    make_batches,
    make_eval_set,
    markov_table,
    sample_chain,
    save_weights,
    train,
)

CFG = ModelConfig()


def test_markov_table_is_stochastic():
    t = markov_table(CFG.vocab)
    assert t.shape == (CFG.vocab, CFG.vocab)
    np.testing.assert_allclose(t.sum(axis=1), 1.0, atol=1e-12)
    assert (t >= 0).all()
    # sparse structure: each row has a few dominant successors
    top4 = np.sort(t, axis=1)[:, -4:].sum(axis=1)
    assert (top4 > 0.85).all()


def test_markov_table_deterministic():
    np.testing.assert_array_equal(markov_table(64, seed=1), markov_table(64, seed=1))
    assert not np.array_equal(markov_table(64, seed=1), markov_table(64, seed=2))


def test_sample_chain_tokens_in_range():
    t = markov_table(CFG.vocab)
    rng = np.random.default_rng(0)
    seq = sample_chain(t, 100, rng)
    assert seq.shape == (100,)
    assert seq.dtype == np.int32
    assert (seq >= 0).all() and (seq < CFG.vocab).all()


def test_make_batches_shapes():
    batches = list(make_batches(CFG, steps=3, batch=4, seed=0))
    assert len(batches) == 3
    for b in batches:
        assert b.shape == (4, CFG.seq_len + 1)


def test_eval_set_structure():
    es = make_eval_set(CFG, n_questions=12, seed=5)
    assert es["prefix_len"] + es["cont_len"] == CFG.seq_len
    assert len(es["questions"]) == 12
    for q in es["questions"]:
        assert len(q["prefix"]) == es["prefix_len"]
        assert len(q["choices"]) == es["k_choices"]
        assert 0 <= q["answer"] < es["k_choices"]
        correct = q["choices"][q["answer"]]
        for i, ch in enumerate(q["choices"]):
            assert len(ch) == es["cont_len"]
            if i != q["answer"]:
                # distractor differs from the correct one only at the end
                assert ch[:-1] == correct[:-1]
                assert ch[-1] != correct[-1]


def test_eval_answers_are_distributed():
    es = make_eval_set(CFG, n_questions=100, seed=6)
    answers = [q["answer"] for q in es["questions"]]
    # all four positions used
    assert len(set(answers)) == es["k_choices"]


def test_adam_decreases_quadratic_loss():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adam_init(params)
    for _ in range(400):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, opt = adam_update(params, grads, opt, lr=3e-2)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_short_training_reduces_loss():
    params, log = train(CFG, steps=12, batch=8, seed=1, log_every=11)
    assert log[0]["loss"] > log[-1]["loss"]
    assert np.isfinite(log[-1]["loss"])


def test_save_weights_layout():
    params = init_params(jax.random.PRNGKey(0), CFG)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "weights.bin")
        entries = save_weights(params, CFG, path)
        flat = flatten_params(params, CFG)
        assert len(entries) == len(flat)
        raw = np.fromfile(path, dtype="<f4")
        total = sum(e["numel"] for e in entries)
        assert raw.size == total
        # offsets are contiguous and data round-trips
        off = 0
        for e, (name, arr) in zip(entries, flat):
            assert e["name"] == name
            assert e["offset"] == off
            got = raw[off:off + e["numel"]].reshape(e["shape"])
            np.testing.assert_array_equal(got, np.asarray(arr))
            off += e["numel"]


def test_manifest_contract_with_rust():
    """The artifact manifest (if built) matches the weight file."""
    man_path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(man_path):
        return
    man = json.load(open(man_path))
    raw = np.fromfile(
        os.path.join(os.path.dirname(man_path), "weights.bin"), dtype="<f4"
    )
    total = sum(w["numel"] for w in man["weights"])
    assert raw.size == total
    assert man["model"]["dim"] == 128
    lm = [a for a in man["artifacts"] if a["op"] == "lm_forward"]
    assert len(lm) == 7
    # each lm artifact takes tokens + one input per weight tensor
    for a in lm:
        assert len(a["inputs"]) == 1 + len(man["weights"])
