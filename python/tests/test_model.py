"""L2 model tests: quantisation numerics, rotation invariances, attention
variants, training step, and the flatten/unflatten weight contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    VARIANTS,
    AttnVariant,
    ModelConfig,
    attention,
    fake_quant,
    fake_quant_fp8,
    fake_quant_int8,
    flatten_params,
    init_params,
    lm_forward,
    lm_loss,
    make_attn_fn,
    param_count,
    rmsnorm,
    rotate_last,
    unflatten_params,
)

CFG = ModelConfig()


def _params(seed=0):
    return init_params(jax.random.PRNGKey(seed), CFG)


# ------------------------------------------------------------- quantisation


def test_fp8_exact_small_integers():
    x = jnp.asarray([0.0, 1.0, -2.0, 8.0, 448.0])
    np.testing.assert_allclose(np.asarray(fake_quant_fp8(x)), np.asarray(x), rtol=1e-6)


def test_fp8_saturates_not_overflows():
    x = jnp.asarray([1e9, -1e9, 1.0])
    q = np.asarray(fake_quant_fp8(x))
    assert np.isfinite(q).all()


def test_fp8_relative_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32)) * 10
    q = np.asarray(fake_quant_fp8(x))
    rel = np.abs(q - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-3)
    # e4m3: 3 mantissa bits -> rel err <= 2^-4 in the normal range
    assert np.quantile(rel, 0.99) < 0.07


def test_int8_error_bounded_by_half_step():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q = np.asarray(fake_quant_int8(x))
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert np.abs(q - np.asarray(x)).max() <= step * 0.5 + 1e-6


def test_fake_quant_dispatch():
    x = jnp.ones((4,))
    np.testing.assert_array_equal(np.asarray(fake_quant(x, "none")), np.ones(4))
    with pytest.raises(ValueError):
        fake_quant(x, "fp4")


def test_rotation_reduces_int8_error_on_outlier_channels():
    """The QuaRot mechanism, measured at the tensor level."""
    rng = np.random.default_rng(2)
    v = rng.standard_normal((256, 32)).astype(np.float32)
    v[:, 5] *= 40.0  # outlier channel
    x = jnp.asarray(v)
    direct = np.asarray(fake_quant_int8(x))
    rot = rotate_last(x, "hadacore")
    rotated = np.asarray(rotate_last(fake_quant_int8(rot), "hadacore"))
    e_direct = np.linalg.norm(direct - v) / np.linalg.norm(v)
    e_rot = np.linalg.norm(rotated - v) / np.linalg.norm(v)
    assert e_rot < e_direct * 0.5, f"{e_rot} vs {e_direct}"


# ---------------------------------------------------------------- rotations


def test_rotate_last_is_involution():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 4, 32)).astype(np.float32))
    y = rotate_last(rotate_last(x, "hadacore"), "hadacore")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_rotation_kernels_agree():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    a = np.asarray(rotate_last(x, "hadacore"))
    b = np.asarray(rotate_last(x, "butterfly"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_rotate_rejects_unknown_kernel():
    with pytest.raises(ValueError):
        rotate_last(jnp.ones((2, 16)), "fft")


def test_rmsnorm_unit_scale():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((3, 8)).astype(np.float32)) * 7
    y = np.asarray(rmsnorm(x, jnp.ones(8)))
    ms = (y**2).mean(axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)


# ---------------------------------------------------------------- attention


def test_attention_shapes():
    p = _params()["layers"][0]["attn"]
    x = jnp.zeros((2, CFG.seq_len, CFG.dim))
    for v in VARIANTS:
        out = attention(p, x, CFG, v)
        assert out.shape == (2, CFG.seq_len, CFG.dim)


def test_rotation_is_function_preserving_without_quant():
    """Rotations are identity transforms when nothing is quantised."""
    p = _params()["layers"][0]["attn"]
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 16, CFG.dim)).astype(np.float32))
    clean = attention(p, x, CFG, AttnVariant("none", "none"))
    rotated = attention(p, x, CFG, AttnVariant("none", "hadacore"))
    np.testing.assert_allclose(
        np.asarray(rotated), np.asarray(clean), rtol=2e-3, atol=2e-3
    )


def test_attention_is_causal():
    """Future tokens must not influence earlier outputs."""
    p = _params()["layers"][0]["attn"]
    rng = np.random.default_rng(7)
    x1 = rng.standard_normal((1, CFG.seq_len, CFG.dim)).astype(np.float32)
    x2 = x1.copy()
    x2[0, -1, :] = rng.standard_normal(CFG.dim)  # change only the last token
    v = AttnVariant("none", "none")
    o1 = np.asarray(attention(p, jnp.asarray(x1), CFG, v))
    o2 = np.asarray(attention(p, jnp.asarray(x2), CFG, v))
    np.testing.assert_allclose(o1[0, :-1], o2[0, :-1], atol=1e-5)
    assert np.abs(o1[0, -1] - o2[0, -1]).max() > 1e-4


def test_variant_names():
    assert AttnVariant("none", "none").name == "fp16"
    assert AttnVariant("fp8", "none").name == "fp8_norot"
    assert AttnVariant("int8", "hadacore").name == "int8_rot_hadacore"
    assert len({v.name for v in VARIANTS}) == 7


# --------------------------------------------------------------- LM + train


def test_lm_forward_shapes_and_finite():
    params = _params()
    tokens = jnp.zeros((2, CFG.seq_len), jnp.int32)
    logits = lm_forward(params, tokens, CFG, VARIANTS[0])
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_lm_loss_near_uniform_at_init():
    params = _params()
    rng = np.random.default_rng(8)
    tokens = jnp.asarray(
        rng.integers(0, CFG.vocab, (4, CFG.seq_len + 1)), jnp.int32
    )
    loss = float(lm_loss(params, tokens, CFG, VARIANTS[0]))
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_grads_flow_to_all_params():
    params = _params()
    rng = np.random.default_rng(9)
    tokens = jnp.asarray(
        rng.integers(0, CFG.vocab, (2, CFG.seq_len + 1)), jnp.int32
    )
    grads = jax.grad(lambda p: lm_loss(p, tokens, CFG, VARIANTS[0]))(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())
        assert float(jnp.abs(leaf).max()) > 0.0


def test_param_count_formula():
    params = _params()
    d, m, v = CFG.dim, CFG.dim * CFG.mlp_mult, CFG.vocab
    expected = v * d + d + CFG.n_layers * (4 * d * d + 2 * d * m + m * d + 2 * d)
    assert param_count(params) == expected


def test_flatten_unflatten_roundtrip():
    params = _params(3)
    flat = flatten_params(params, CFG)
    rebuilt = unflatten_params([a for _, a in flat], CFG)
    tokens = jnp.zeros((1, CFG.seq_len), jnp.int32)
    a = lm_forward(params, tokens, CFG, VARIANTS[0])
    b = lm_forward(rebuilt, tokens, CFG, VARIANTS[0])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # names are unique and ordered deterministically
    names = [n for n, _ in flat]
    assert len(set(names)) == len(names)
    assert names[0] == "embed"


def test_make_attn_fn_lowers():
    fn = make_attn_fn(CFG, VARIANTS[2])
    spec = jax.ShapeDtypeStruct((2, CFG.seq_len, CFG.dim), jnp.float32)
    w = jax.ShapeDtypeStruct((CFG.dim, CFG.dim), jnp.float32)
    lowered = jax.jit(fn).lower(spec, w, w, w, w)
    assert "func" in str(lowered.compiler_ir("stablehlo"))


# ---------------------------------------------------------------- hypothesis


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_fp8_idempotent(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32)) * 30
    q1 = fake_quant_fp8(x)
    q2 = fake_quant_fp8(q1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    hd=st.sampled_from([16, 32, 64]),
)
def test_hypothesis_qk_rotation_preserves_scores(seed, hd):
    """softmax(QK^T) is invariant under joint Q/K rotation (no quant)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((6, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((6, hd)).astype(np.float32))
    s0 = np.asarray(q @ k.T)
    qr = rotate_last(q, "hadacore")
    kr = rotate_last(k, "hadacore")
    s1 = np.asarray(qr @ kr.T)
    np.testing.assert_allclose(s1, s0, rtol=1e-3, atol=1e-3)
