"""Oracle self-consistency: the two references must agree with each other
and with first-principles Hadamard properties before they may judge the
kernels."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref

SIZES = [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]


def test_hadamard_matrix_entries():
    for n in [2, 4, 16, 64]:
        h = np.asarray(ref.hadamard_matrix(n))
        assert set(np.unique(h)) <= {-1.0, 1.0}
        assert h.shape == (n, n)


@pytest.mark.parametrize("n", SIZES)
def test_hadamard_matrix_orthogonal(n):
    h = np.asarray(ref.hadamard_matrix(n), dtype=np.float64)
    np.testing.assert_allclose(h @ h.T, n * np.eye(n), atol=1e-9)


@pytest.mark.parametrize("n", SIZES)
def test_hadamard_matrix_symmetric(n):
    h = np.asarray(ref.hadamard_matrix(n))
    np.testing.assert_array_equal(h, h.T)


def test_hadamard_sylvester_recursion():
    for n in [4, 8, 16, 32]:
        h = np.asarray(ref.hadamard_matrix(n))
        half = np.asarray(ref.hadamard_matrix(n // 2))
        top = np.hstack([half, half])
        bot = np.hstack([half, -half])
        np.testing.assert_array_equal(h, np.vstack([top, bot]))


@pytest.mark.parametrize("n", SIZES)
def test_butterfly_matches_matmul(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((5, n)), dtype=jnp.float32)
    a = ref.fwht_matmul(x)
    b = ref.fwht_butterfly(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [16, 128, 256])
def test_butterfly_scale_override(n):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, n)), dtype=jnp.float32)
    raw = ref.fwht_butterfly(x, scale=1.0)
    normed = ref.fwht_butterfly(x)
    np.testing.assert_allclose(
        np.asarray(raw) / math.sqrt(n), np.asarray(normed), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("n", [64, 256])
def test_normalized_transform_is_involution(n):
    """H/sqrt(n) is orthogonal and symmetric => applying twice = identity."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((4, n)), dtype=jnp.float32)
    y = ref.fwht_matmul(ref.fwht_matmul(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [64, 256])
def test_normalized_transform_preserves_norm(n):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((4, n)), dtype=jnp.float32)
    y = ref.fwht_butterfly(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-4,
    )


def test_factor_16():
    assert ref.factor_16(16) == (0, 1)
    assert ref.factor_16(256) == (0, 2)
    assert ref.factor_16(128) == (3, 1)
    assert ref.factor_16(512) == (1, 2)
    assert ref.factor_16(2048) == (3, 2)
    assert ref.factor_16(32768) == (3, 3)
    assert ref.factor_16(2) == (1, 0)
    with pytest.raises(ValueError):
        ref.factor_16(48)


def test_is_pow2():
    assert ref.is_pow2(1) and ref.is_pow2(2) and ref.is_pow2(32768)
    assert not ref.is_pow2(0) and not ref.is_pow2(12) and not ref.is_pow2(-4)
