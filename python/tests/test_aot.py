"""AOT pipeline tests: HLO-text lowering invariants (the interchange
contract with the Rust runtime) and manifest construction."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import (
    ATTN_BATCH,
    BASELINE_BUCKETS,
    FWHT_BUCKETS,
    LM_BATCH,
    dtype_name,
    spec,
    to_hlo_text,
)
from compile.model import default_config, make_attn_fn, make_fwht_fn, VARIANTS


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        spec((4, 4)), spec((4, 4))
    )
    text = to_hlo_text(lowered)
    # HLO text essentials the Rust-side parser relies on
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "f32[4,4]" in text
    # return_tuple=True => tupled root
    assert "tuple(" in text or "(f32[4,4])" in text


def test_fwht_lowering_has_one_dot_per_round():
    """The kernel's HLO must contain exactly ceil(log16 n) dot ops —
    the matrix-unit round structure the paper defines."""
    import math

    for n, rows in [(256, 8), (1024, 4), (8192, 2)]:
        fn = make_fwht_fn(n, rows, "hadacore")
        text = to_hlo_text(jax.jit(fn).lower(spec((rows, n))))
        dots = text.count(" dot(")
        want = math.ceil(math.log(n, 16))
        assert dots == want, f"n={n}: {dots} dots, want {want}"


def test_butterfly_lowering_has_no_dots():
    fn = make_fwht_fn(1024, 4, "butterfly")
    text = to_hlo_text(jax.jit(fn).lower(spec((4, 1024))))
    assert text.count(" dot(") == 0  # pure add/sub data flow


def test_no_f8_dtypes_on_the_wire():
    """xla_extension 0.5.1 cannot parse f8 types; fake-quant must lower
    to basic ops only (design constraint)."""
    cfg = default_config()
    for variant in VARIANTS:
        fn = make_attn_fn(cfg, variant)
        x = spec((ATTN_BATCH, cfg.seq_len, cfg.dim))
        w = spec((cfg.dim, cfg.dim))
        text = to_hlo_text(jax.jit(fn).lower(x, w, w, w, w))
        assert "f8e" not in text, f"{variant.name} leaked an f8 dtype"


def test_bucket_tables_cover_paper_sizes():
    sizes = [n for n, _ in FWHT_BUCKETS]
    assert sizes == [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]
    # element budget per bucket is constant (rows * n), keeping batch
    # execution cost uniform across sizes
    budgets = {n * r for n, r in FWHT_BUCKETS}
    assert len(budgets) == 1
    for n, r in BASELINE_BUCKETS:
        assert (n, r) in FWHT_BUCKETS


def test_dtype_name():
    assert dtype_name(jnp.float32) == "float32"
    assert dtype_name(jnp.int32) == "int32"


def test_built_manifest_is_wellformed():
    man_path = os.path.join(
        os.path.dirname(__file__), "../../artifacts/manifest.json"
    )
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    man = json.load(open(man_path))
    names = [a["name"] for a in man["artifacts"]]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in man["artifacts"]:
        path = os.path.join(os.path.dirname(man_path), a["file"])
        assert os.path.exists(path), f"missing artifact file {a['file']}"
        head = open(path).read(64)
        assert head.startswith("HloModule"), f"{a['file']} is not HLO text"
        assert a["op"] in ("fwht", "attention", "lm_forward")
        for t in a["inputs"] + a["outputs"]:
            assert all(d > 0 for d in t["shape"])
            assert t["dtype"] in ("float32", "int32")
