"""Kernel-vs-oracle correctness: the CORE numerical signal of the repo.

``hadacore`` (matrix-unit rounds) and ``fwht_baseline`` (butterfly rounds)
must both match the explicit-Hadamard-matmul oracle across every supported
size, dtype, batch shape and configuration — plus hypothesis sweeps over
random shapes/seeds/scales.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fwht import fwht_baseline
from compile.kernels.hadacore import (
    MAX_HADAMARD_SIZE,
    block_diagonal_hadamard,
    default_block_rows,
    hadacore,
)

ALL_SIZES = [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]
PAPER_SIZES = [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]


def _rand(rows, n, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, n)), dtype=dtype)


@pytest.mark.parametrize("n", ALL_SIZES)
def test_hadacore_matches_oracle_f32(n):
    rows = 4 if n >= 8192 else 16
    x = _rand(rows, n, seed=n)
    got = hadacore(x)
    want = ref.fwht_matmul(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", ALL_SIZES)
def test_baseline_matches_oracle_f32(n):
    rows = 4 if n >= 8192 else 16
    x = _rand(rows, n, seed=n + 1)
    got = fwht_baseline(x)
    want = ref.fwht_matmul(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", PAPER_SIZES)
def test_hadacore_matches_baseline(n):
    """The paper's kernel and the Dao-style kernel compute the same transform."""
    x = _rand(8, n, seed=n + 2)
    np.testing.assert_allclose(
        np.asarray(hadacore(x)),
        np.asarray(fwht_baseline(x)),
        rtol=2e-4,
        atol=2e-4,
    )


@pytest.mark.parametrize("n", [128, 512, 2048, 8192])
def test_block_diagonal_path_equals_direct(n):
    """Paper §3.3 block-diagonal final round == direct small contraction."""
    x = _rand(8, n, seed=n)
    a = hadacore(x, use_block_diagonal=True)
    b = hadacore(x, use_block_diagonal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m", [0, 1, 2, 3])
def test_block_diagonal_matrix_structure(m):
    bd = np.asarray(block_diagonal_hadamard(m))
    assert bd.shape == (16, 16)
    sub = 1 << m
    h = np.asarray(ref.hadamard_matrix(sub))
    for b in range(16 // sub):
        blk = bd[b * sub:(b + 1) * sub, b * sub:(b + 1) * sub]
        np.testing.assert_array_equal(blk, h)
    # off-diagonal blocks are zero
    mask = np.kron(np.eye(16 // sub), np.ones((sub, sub)))
    np.testing.assert_array_equal(bd * (1 - mask), np.zeros((16, 16)))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [128, 256, 1024, 4096])
def test_hadacore_16bit_dtypes(n, dtype):
    """Paper appendix C: BF16 (FP32 accumulate + convert) stays accurate."""
    x = _rand(8, n, seed=n, dtype=dtype)
    got = np.asarray(hadacore(x), dtype=np.float32)
    want = np.asarray(ref.fwht_matmul(x), dtype=np.float32)
    # 16-bit storage: tolerance scaled to the format's epsilon
    eps = 0.008 if dtype == jnp.bfloat16 else 0.001
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got, want, atol=eps * scale * 4, rtol=0.05)


@pytest.mark.parametrize("n", [256, 1024])
def test_hadacore_fp16_accumulation_mode(n):
    """Paper FP16 path accumulates in FP16; we expose accum_dtype for parity."""
    x = _rand(8, n, seed=n, dtype=jnp.float16)
    got = np.asarray(hadacore(x, accum_dtype=jnp.float32), dtype=np.float32)
    want = np.asarray(ref.fwht_matmul(x), dtype=np.float32)
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got, want, atol=0.004 * scale, rtol=0.05)


def test_scale_semantics():
    x = _rand(4, 256, seed=3)
    raw = hadacore(x, scale=1.0)
    normed = hadacore(x)
    np.testing.assert_allclose(
        np.asarray(raw) / math.sqrt(256), np.asarray(normed), rtol=1e-5, atol=1e-5
    )
    doubled = hadacore(x, scale=2.0)
    np.testing.assert_allclose(
        np.asarray(doubled), 2 * np.asarray(raw) / 1.0, rtol=1e-5, atol=1e-5
    )


def test_batch_shapes():
    """Leading axes of any rank are flattened and restored."""
    x = _rand(24, 128, seed=5).reshape(2, 3, 4, 128)
    got = hadacore(x)
    assert got.shape == (2, 3, 4, 128)
    want = ref.fwht_matmul(x.reshape(24, 128)).reshape(2, 3, 4, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_single_row():
    x = _rand(1, 512, seed=9)
    np.testing.assert_allclose(
        np.asarray(hadacore(x)), np.asarray(ref.fwht_matmul(x)), rtol=2e-4, atol=2e-4
    )


def test_block_rows_padding():
    """rows not divisible by block_rows exercises the pad/slice path."""
    x = _rand(7, 256, seed=13)
    got = hadacore(x, block_rows=4)
    want = ref.fwht_matmul(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_default_block_rows_vmem_budget():
    # A f32 tile must stay within the 2 MiB budget
    for n in [128, 4096, 32768]:
        br = default_block_rows(10_000, n)
        assert br * n * 4 <= (2 << 20) or br == 1
        assert br >= 1


def test_rejects_non_pow2():
    x = jnp.zeros((2, 48), jnp.float32)
    with pytest.raises(ValueError):
        hadacore(x)
    with pytest.raises(ValueError):
        fwht_baseline(x)


def test_rejects_oversize():
    x = jnp.zeros((1, MAX_HADAMARD_SIZE * 2), jnp.float32)
    with pytest.raises(ValueError):
        hadacore(x)


# ---------------------------------------------------------------- hypothesis

pow2 = st.integers(min_value=1, max_value=12).map(lambda k: 1 << k)


@settings(max_examples=40, deadline=None)
@given(
    n=pow2,
    rows=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_hadacore_vs_oracle(n, rows, seed):
    x = _rand(rows, n, seed=seed)
    got = hadacore(x)
    want = ref.fwht_matmul(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=11).map(lambda k: 1 << k),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_involution(n, seed):
    """Normalised transform applied twice is the identity (orthogonality)."""
    x = _rand(4, n, seed=seed)
    y = hadacore(hadacore(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=11).map(lambda k: 1 << k),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    alpha=st.floats(min_value=-3, max_value=3, allow_nan=False),
)
def test_hypothesis_linearity(n, seed, alpha):
    x = _rand(3, n, seed=seed)
    y = _rand(3, n, seed=seed + 1)
    lhs = hadacore(x + alpha * y)
    rhs = hadacore(x) + alpha * hadacore(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=11).map(lambda k: 1 << k),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_norm_preservation(n, seed):
    x = _rand(4, n, seed=seed)
    y = hadacore(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-3,
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=10).map(lambda k: 1 << k),
    rows=st.integers(min_value=1, max_value=8),
    br=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_block_rows_invariance(n, rows, br, seed):
    """Result must not depend on the grid decomposition."""
    x = _rand(rows, n, seed=seed)
    a = hadacore(x, block_rows=br)
    b = hadacore(x, block_rows=rows)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
