#!/usr/bin/env python3
"""Join the measured fusion sweep against the roofline model's picks.

Usage:
    python3 bench/roofline_report.py BENCH_PR4.json [--tolerance PCT]
                                     [--strict]

Reads a ``hadacore-bench-v1`` document whose ``fusion_sweep`` /
``hadacore`` entries carry the ``model_depth`` extra (the fusion depth
``gpu_model::roofline::recommend_fusion_depth_for_lanes`` recommended
for that size and the active SIMD table — recorded by
``cargo bench --bench exec_engine`` alongside each measured depth).
For every (n, rows) sweep group it finds the empirically best depth
(max ``melems_per_s``), looks up the throughput at the model's pick,
and reports how much the model's choice costs relative to the best
measured depth.

Agreement means the model's depth is within the tolerance (default
10%) of the best measured throughput — the model does not have to name
the exact argmax depth, it has to land on the flat part of the curve.

By default the report only *warns* (exit 0): fusion-depth curves are
shallow near the optimum and CI runners are noisy, so the roofline
check rides along as an artifact rather than a gate. Pass ``--strict``
to exit non-zero when any sweep group disagrees beyond tolerance.

Zero dependencies beyond the Python 3 standard library, mirroring the
repo's no-deps policy.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "hadacore-bench-v1"


def load(path: Path) -> list[dict]:
    doc = json.loads(path.read_text())
    if doc.get("schema") != SCHEMA:
        sys.exit(f"error: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        sys.exit(f"error: {path}: no entries")
    return entries


def main(argv: list[str]) -> int:
    strict = "--strict" in argv
    argv = [a for a in argv if a != "--strict"]
    tolerance = 10.0
    if "--tolerance" in argv:
        i = argv.index("--tolerance")
        tolerance = float(argv[i + 1])
        del argv[i : i + 2]
    if len(argv) != 1:
        sys.exit(__doc__)

    entries = load(Path(argv[0]))
    sweep = [
        e
        for e in entries
        if e.get("bench") == "fusion_sweep"
        and e.get("kernel") == "hadacore"
        and isinstance(e.get("model_depth"), (int, float))
        and isinstance(e.get("melems_per_s"), (int, float))
    ]
    if not sweep:
        # older records (pre model_depth) are not an error: the report
        # becomes meaningful once the bench re-runs with the extra
        print(
            "roofline_report: no fusion_sweep/hadacore entries with a "
            "model_depth extra — nothing to join"
        )
        return 0

    groups: dict[tuple, list[dict]] = {}
    for e in sweep:
        groups.setdefault((e.get("n"), e.get("rows")), []).append(e)

    print(
        f"{'n':>8} {'rows':>5} {'best':>5} {'model':>6} "
        f"{'best ME/s':>10} {'model ME/s':>10} {'cost':>7}  verdict"
    )
    disagreements = []
    for (n, rows), grp in sorted(groups.items(), key=repr):
        best = max(grp, key=lambda e: e["melems_per_s"])
        model_depth = int(grp[0]["model_depth"])
        at_model = next(
            (e for e in grp if e.get("fusion_depth") == model_depth), None
        )
        if at_model is None:
            # the model recommended a depth the sweep did not measure
            # (clamped sweeps); count it as a disagreement with the
            # whole best throughput as the cost
            disagreements.append((n, rows))
            print(
                f"{n:>8} {rows:>5} {best['fusion_depth']:>5} {model_depth:>6} "
                f"{best['melems_per_s']:>10.1f} {'-':>10} {'-':>7}  DISAGREE "
                "(depth not in sweep)"
            )
            continue
        cost_pct = (
            (best["melems_per_s"] - at_model["melems_per_s"])
            / best["melems_per_s"]
            * 100.0
        )
        agree = cost_pct <= tolerance
        if not agree:
            disagreements.append((n, rows))
        print(
            f"{n:>8} {rows:>5} {best['fusion_depth']:>5} {model_depth:>6} "
            f"{best['melems_per_s']:>10.1f} {at_model['melems_per_s']:>10.1f} "
            f"{cost_pct:>6.1f}%  {'ok' if agree else 'DISAGREE'}"
        )

    total = len(groups)
    print(
        f"roofline_report: {total - len(disagreements)}/{total} sweep "
        f"group(s) within {tolerance:.0f}% of the measured best at the "
        "model's pick"
    )
    if disagreements and strict:
        return 1
    if disagreements:
        print(
            "roofline_report: warning only (pass --strict to fail the "
            "build on disagreements)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
