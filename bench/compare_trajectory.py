#!/usr/bin/env python3
"""Compare two hadacore-bench-v1 JSON documents at fixed workload keys.

Usage:
    python3 bench/compare_trajectory.py NEW.json [BASELINE.json] [--strict]
                                        [--threshold PCT]

Joins entries of NEW against BASELINE at the fixed key
(bench, kernel, n, rows, dtype, fusion_depth, threads) and reports the
relative change in throughput (``melems_per_s``, plus ``qps_achieved``
where both sides carry it). Entries whose key appears several times in
one document (e.g. two traffic mixes sharing a shape envelope) are
paired positionally within the key group.

A drop larger than the threshold (default 15%) on any matched entry is
reported as a REGRESSION. By default the script only *warns* (exit 0)
so a noisy CI runner can't hard-fail the pipeline; pass ``--strict`` to
exit non-zero on regressions instead.

If BASELINE is omitted it defaults to the newest ``BENCH_PR*.json``
under ``bench/trajectory/`` that is not the NEW file itself; when no
baseline exists yet (first recorded run) the script prints a note and
exits 0 — the comparison becomes meaningful from the second record on.

Zero dependencies beyond the Python 3 standard library, mirroring the
repo's no-deps policy.
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

SCHEMA = "hadacore-bench-v1"
KEY_FIELDS = ("bench", "kernel", "n", "rows", "dtype", "fusion_depth", "threads")
THROUGHPUT_FIELDS = ("melems_per_s", "qps_achieved")


def load(path: Path) -> list[dict]:
    doc = json.loads(path.read_text())
    if doc.get("schema") != SCHEMA:
        sys.exit(f"error: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        sys.exit(f"error: {path}: no entries")
    return entries


def key_of(entry: dict) -> tuple:
    return tuple(entry.get(f) for f in KEY_FIELDS)


def group(entries: list[dict]) -> dict[tuple, list[dict]]:
    groups: dict[tuple, list[dict]] = {}
    for e in entries:
        groups.setdefault(key_of(e), []).append(e)
    return groups


def pr_number(path: Path) -> int:
    m = re.search(r"BENCH_PR(\d+)\.json$", path.name)
    return int(m.group(1)) if m else -1


def default_baseline(new_path: Path) -> Path | None:
    trajectory = Path(__file__).resolve().parent / "trajectory"
    candidates = [
        p
        for p in sorted(trajectory.glob("BENCH_PR*.json"), key=pr_number)
        if p.resolve() != new_path.resolve()
    ]
    return candidates[-1] if candidates else None


def main(argv: list[str]) -> int:
    strict = "--strict" in argv
    argv = [a for a in argv if a != "--strict"]
    threshold = 15.0
    if "--threshold" in argv:
        i = argv.index("--threshold")
        threshold = float(argv[i + 1])
        del argv[i : i + 2]
    if not 1 <= len(argv) <= 2:
        sys.exit(__doc__)

    new_path = Path(argv[0])
    base_path = Path(argv[1]) if len(argv) == 2 else default_baseline(new_path)
    if base_path is None or not base_path.exists():
        print(
            f"compare_trajectory: no baseline for {new_path.name} — "
            "first recorded run, nothing to compare against"
        )
        return 0

    new_groups = group(load(new_path))
    base_groups = group(load(base_path))

    matched = 0
    regressions = []
    for key, new_entries in sorted(new_groups.items(), key=repr):
        base_entries = base_groups.get(key)
        if not base_entries:
            continue
        for new_e, base_e in zip(new_entries, base_entries):
            matched += 1
            label = "/".join(str(k) for k in key)
            for field in THROUGHPUT_FIELDS:
                new_v, base_v = new_e.get(field), base_e.get(field)
                if not isinstance(new_v, (int, float)) or not isinstance(
                    base_v, (int, float)
                ):
                    continue
                if base_v <= 0:
                    continue
                delta_pct = (new_v - base_v) / base_v * 100.0
                line = (
                    f"  {label} {field}: {base_v:.3f} -> {new_v:.3f} "
                    f"({delta_pct:+.1f}%)"
                )
                if delta_pct < -threshold:
                    regressions.append(line)
                    print(f"REGRESSION{line}")
                else:
                    print(f"ok{line}")

    print(
        f"compare_trajectory: {new_path.name} vs {base_path.name}: "
        f"{matched} matched entr{'y' if matched == 1 else 'ies'}, "
        f"{len(regressions)} regression(s) beyond {threshold:.0f}%"
    )
    if matched == 0:
        print(
            "compare_trajectory: note: no shared keys — benches measure "
            "disjoint workloads, comparison is vacuous"
        )
    if regressions and strict:
        return 1
    if regressions:
        print(
            "compare_trajectory: warning only (pass --strict to fail the "
            "build on regressions)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
