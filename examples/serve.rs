//! Serving demo: the full TCP stack end to end in one process.
//!
//! Starts a coordinator + the `serve/` TCP front-end on an ephemeral
//! loopback port, drives it with the open-loop load generator
//! (concurrent pipelining client connections over a named traffic mix,
//! wire protocol v1), then fetches the server's own `Stats` frame — the
//! same counters and percentile report a remote operator would see —
//! and tears everything down gracefully (`ServeHandle::shutdown` +
//! `Coordinator::drain`).
//!
//! Run: `cargo run --release --example serve -- --requests 2000`

use std::sync::Arc;

use hadacore::coordinator::{Coordinator, CoordinatorConfig};
use hadacore::exec::ExecConfig;
use hadacore::harness::workload::traffic_mix;
use hadacore::hadamard::KernelKind;
use hadacore::serve::{loadgen, serve, Client, LoadgenConfig, ServeConfig};
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::f16::DType;

fn main() -> anyhow::Result<()> {
    let args = Args::new("serve", "TCP serving demo (loopback, in-process server)")
        .opt("requests", "2000", "total requests")
        .opt("clients", "4", "concurrent pipelining client connections")
        .opt("qps", "0", "offered load (0 = unpaced)")
        .opt("mix", "mixed", "traffic mix: interactive|batch|llama-ffn|quantized|mixed")
        .opt("workers", "4", "batcher worker threads")
        .opt("exec-threads", "0", "engine compute lanes (0 = default: per-core, capped at 16)")
        .opt("kernel", "hadacore", "kernel: hadacore|dao|scalar")
        .parse();
    let kernel = KernelKind::parse(&args.get("kernel")).unwrap_or(KernelKind::HadaCore);
    let mut workload = traffic_mix(&args.get("mix"))
        .ok_or_else(|| anyhow::anyhow!("unknown --mix"))?;
    workload.kernel = kernel;

    let coord = Arc::new(Coordinator::start(
        None,
        CoordinatorConfig {
            workers: args.get_as("workers"),
            exec: ExecConfig::with_lanes(args.get_as("exec-threads")),
            ..Default::default()
        },
    )?);
    let handle = serve(Arc::clone(&coord), ServeConfig::default())?;
    let addr = handle.addr().to_string();

    let cfg = LoadgenConfig {
        addr: addr.clone(),
        mix: args.get("mix"),
        workload,
        qps: args.get_as("qps"),
        requests: args.get_as("requests"),
        clients: args.get_as("clients"),
        dtype: DType::F32,
        ..Default::default()
    };
    println!(
        "server on {addr} — {} clients x {} requests ({} mix)",
        cfg.clients, cfg.requests, cfg.mix
    );
    let report = loadgen::run(&cfg)?;
    println!("{}", report.line());
    println!(
        "throughput: {:.1} M elem/s over {:?}",
        report.elems as f64 / report.wall.as_secs_f64().max(1e-9) / 1e6,
        report.wall
    );

    // the server's own view, over the wire
    let probe = Client::connect(&addr)?;
    println!("\nping rtt: {:?}", probe.ping()?);
    println!("\n{}", probe.stats()?.report);
    drop(probe);

    handle.shutdown();
    coord.drain();
    Ok(())
}
