//! Serving demo: the coordinator under a mixed-size transform workload.
//!
//! Drives the dynamic batcher with open-loop request arrivals across a mix
//! of Hadamard sizes and both backends (PJRT artifacts where available,
//! native kernels elsewhere), then prints the full metrics report —
//! batching efficiency, padding overhead, and queue/exec/e2e percentiles.
//!
//! Run: `cargo run --release --example serve -- --requests 5000`

use std::path::Path;
use std::time::Instant;

use hadacore::coordinator::{Coordinator, CoordinatorConfig};
use hadacore::exec::ExecConfig;
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::hadamard::KernelKind;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;

fn main() -> anyhow::Result<()> {
    let args = Args::new("serve", "mixed workload serving demo")
        .opt("requests", "5000", "total requests")
        .opt("artifacts", "artifacts", "artifact directory ('' = native only)")
        .opt("workers", "4", "batcher worker threads")
        .opt("exec-threads", "0", "engine compute lanes (0 = default: per-core, capped at 16)")
        .opt("kernel", "hadacore", "kernel: hadacore|dao|scalar")
        .switch("native", "force native backend for all requests")
        .parse();
    let total: usize = args.get_as("requests");
    let force_native = args.flag("native");
    let dirs = args.get("artifacts");
    let artifact_dir = if dirs.is_empty() || force_native {
        None
    } else {
        let p = Path::new(&dirs);
        p.join("manifest.json").exists().then(|| p.to_path_buf())
    };
    println!(
        "backend: {}",
        if artifact_dir.is_some() { "pjrt + native" } else { "native only" }
    );

    let lanes: usize = args.get_as("exec-threads");
    let exec = if lanes == 0 {
        ExecConfig::default()
    } else {
        ExecConfig { threads: lanes, ..ExecConfig::default() }
    };
    let coord = Coordinator::start(
        artifact_dir,
        CoordinatorConfig {
            workers: args.get_as("workers"),
            exec,
            ..Default::default()
        },
    )?;
    let mut wl = ServingWorkload::new(WorkloadConfig {
        sizes: vec![128, 256, 512, 1024, 4096],
        kernel: KernelKind::parse(&args.get("kernel")).unwrap_or(KernelKind::HadaCore),
        ..Default::default()
    });

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(total);
    for _ in 0..total {
        let mut req = wl.next_request();
        req.force_native = force_native;
        pending.push(coord.submit(req).map_err(|e| anyhow::anyhow!(e))?);
    }
    let submit_dt = t0.elapsed();
    let mut elems = 0usize;
    for rx in pending {
        elems += rx.recv()??.data.len();
    }
    let dt = t0.elapsed();

    println!(
        "{total} requests ({:.1} M elements) in {dt:?} (submit {submit_dt:?})",
        elems as f64 / 1e6
    );
    println!(
        "throughput: {:.0} req/s, {:.1} M elem/s",
        total as f64 / dt.as_secs_f64(),
        elems as f64 / dt.as_secs_f64() / 1e6
    );
    println!("\n{}", coord.metrics().snapshot().report());
    let es = coord.exec_engine().stats();
    println!(
        "engine:   {} lanes, {} sharded jobs ({} chunks), {} inline runs, {} scratch grows",
        coord.exec_engine().threads(),
        es.jobs,
        es.chunks,
        es.inline_runs,
        es.scratch_grows
    );
    coord.shutdown();
    Ok(())
}
