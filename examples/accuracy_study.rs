//! MMLU-analog accuracy study (paper §4.2, experiment E7).
//!
//! The paper compares average 5-shot MMLU accuracy of Llama-3.1-8B under
//! FP16, FP8 attention without rotation, and FP8 attention with Hadamard
//! rotation performed by the Dao kernel vs HadaCore. This environment has
//! neither the model nor MMLU (DESIGN.md §Substitutions), so the analogous
//! experiment is run end-to-end through the three-layer stack:
//!
//! * the small LM trained at artifact-build time on a synthetic Markov
//!   corpus (python/compile/train.py — build-time only),
//! * a synthetic multiple-choice evaluation (which continuation follows
//!   the prefix?), scored by total continuation log-likelihood,
//! * every attention-numerics variant executed as a compiled PJRT
//!   artifact by the Rust runtime: fp16, {fp8, int8} x {no rotation,
//!   HadaCore rotation, butterfly (exact/Dao-equivalent) rotation}.
//!
//! Run: `cargo run --release --example accuracy_study` (needs artifacts)

use std::path::Path;

use hadacore::runtime::{literal_f32, literal_i32, literal_to_f32, Runtime, Tensor};
use hadacore::runtime::xla;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::json::Json;

/// Scale-invariant outlier injection (DESIGN.md §Substitutions).
///
/// Real LLMs develop outlier channels because scale can migrate between
/// adjacent linear maps without changing the function. A ~500k-parameter
/// model trained for minutes does not — so we perform that migration
/// explicitly: for a few channels j, scale column j of `wv` by `c` and row
/// j of `wo` by `1/c` (and likewise `wq` x c / `wk` / c, which leaves
/// QK^T unchanged). In exact arithmetic the model is identical; under
/// quantised attention the activations now carry genuine outlier
/// channels. This reproduces the paper's evaluation regime rather than
/// its parameter count.
fn inject_outliers(tensors: &mut [(String, Tensor)], dim: usize, scale: f32) {
    let channels = [3usize, 17, 40, 77];
    for (name, t) in tensors.iter_mut() {
        let col = |data: &mut [f32], j: usize, f: f32| {
            for r in 0..dim {
                data[r * dim + j] *= f;
            }
        };
        let row = |data: &mut [f32], j: usize, f: f32| {
            for c in 0..dim {
                data[j * dim + c] *= f;
            }
        };
        for &j in &channels {
            if j >= dim {
                continue;
            }
            if name.ends_with(".wv") || name.ends_with(".wq") {
                col(&mut t.data, j, scale);
            } else if name.ends_with(".wk") {
                col(&mut t.data, j, 1.0 / scale);
            } else if name.ends_with(".wo") {
                row(&mut t.data, j, 1.0 / scale);
            }
        }
    }
}

struct Question {
    prefix: Vec<i32>,
    choices: Vec<Vec<i32>>,
    answer: usize,
}

fn load_eval(path: &Path) -> anyhow::Result<(usize, usize, Vec<Question>)> {
    let text = std::fs::read_to_string(path)?;
    let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("eval.json: {e}"))?;
    let prefix_len = root.get("prefix_len").and_then(Json::as_usize).unwrap_or(0);
    let cont_len = root.get("cont_len").and_then(Json::as_usize).unwrap_or(0);
    let mut questions = Vec::new();
    for q in root.get("questions").and_then(Json::as_arr).unwrap_or(&[]) {
        let ints = |v: &Json| -> Vec<i32> {
            v.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_f64)
                .map(|x| x as i32)
                .collect()
        };
        questions.push(Question {
            prefix: q.get("prefix").map(&ints).unwrap_or_default(),
            choices: q
                .get("choices")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(&ints)
                .collect(),
            answer: q.get("answer").and_then(Json::as_usize).unwrap_or(0),
        });
    }
    Ok((prefix_len, cont_len, questions))
}

/// Score a batch of sequences: per-sequence total log-probability of the
/// tokens in positions [prefix_len, seq_len) under the model.
fn continuation_scores(
    logits: &[f32],
    tokens: &[i32],
    batch: usize,
    seq: usize,
    vocab: usize,
    prefix_len: usize,
) -> Vec<f64> {
    let mut scores = vec![0.0f64; batch];
    for s in 0..batch {
        for t in prefix_len..seq {
            // predictor position t-1 predicts token at t
            let row = &logits[(s * seq + (t - 1)) * vocab..(s * seq + t) * vocab];
            let target = tokens[s * seq + t] as usize;
            // log-softmax at the target index
            let maxv = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let lse: f64 = row.iter().map(|&v| ((v as f64) - maxv).exp()).sum();
            scores[s] += (row[target] as f64 - maxv) - lse.ln();
        }
    }
    scores
}

fn main() -> anyhow::Result<()> {
    let args = Args::new("accuracy_study", "MMLU-analog accuracy comparison")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("questions", "200", "max questions to score")
        .opt(
            "outlier-scale",
            "96",
            "scale-invariant outlier-channel injection factor (0 = off)",
        )
        .parse();
    let dir = Path::new(&args.get("artifacts")).to_path_buf();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    let rt = Runtime::open(&dir)?;
    let meta = rt.manifest().model.clone();
    let weights = rt.weights()?;
    let mut tensors: Vec<(String, Tensor)> = weights.ordered().to_vec();
    let outlier_scale: f32 = args.get_as("outlier-scale");
    if outlier_scale > 0.0 {
        inject_outliers(&mut tensors, meta.dim, outlier_scale);
        println!(
            "outlier channels injected (scale-invariant reparameterisation, c={outlier_scale})"
        );
    }
    let weight_lits: Vec<xla::Literal> = tensors
        .iter()
        .map(|(_, t)| literal_f32(&t.data, &t.shape))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let (prefix_len, cont_len, questions) = load_eval(&dir.join("eval.json"))?;
    let max_q: usize = args.get_as("questions");
    let questions = &questions[..max_q.min(questions.len())];
    let k = questions.first().map(|q| q.choices.len()).unwrap_or(4);
    let per_batch = meta.lm_batch / k; // questions per executed batch

    println!(
        "model: {} params | eval: {} questions x {k} choices (prefix {prefix_len}, cont {cont_len})",
        weights.param_count(),
        questions.len()
    );

    let variants = [
        ("fp16 baseline", "lm_fp16"),
        ("fp8 attention (no rotation)", "lm_fp8_norot"),
        ("fp8 attention + HadaCore rotation", "lm_fp8_rot_hadacore"),
        ("fp8 attention + exact-FWHT rotation", "lm_fp8_rot_butterfly"),
        ("int8 attention (no rotation)", "lm_int8_norot"),
        ("int8 attention + HadaCore rotation", "lm_int8_rot_hadacore"),
        ("int8 attention + exact-FWHT rotation", "lm_int8_rot_butterfly"),
    ];

    println!(
        "\n{:<38} {:>9} {:>13} {:>7}",
        "variant", "accuracy", "avg logprob", "flips"
    );
    println!("{}", "-".repeat(72));
    let mut fp16_decisions: Vec<usize> = Vec::new();
    for (label, artifact) in variants {
        let art = rt.load(artifact)?;
        let mut correct = 0usize;
        let mut total_lp = 0.0f64;
        let mut decisions: Vec<usize> = Vec::with_capacity(questions.len());
        let mut qi = 0;
        while qi < questions.len() {
            let group = &questions[qi..(qi + per_batch).min(questions.len())];
            // pack k sequences per question into one (lm_batch, seq) batch
            let mut tokens = vec![0i32; meta.lm_batch * meta.seq_len];
            for (g, q) in group.iter().enumerate() {
                for (c, choice) in q.choices.iter().enumerate() {
                    let s = g * k + c;
                    let row = &mut tokens[s * meta.seq_len..(s + 1) * meta.seq_len];
                    row[..prefix_len].copy_from_slice(&q.prefix);
                    row[prefix_len..prefix_len + cont_len].copy_from_slice(choice);
                }
            }
            let tokens_lit = literal_i32(&tokens, &[meta.lm_batch, meta.seq_len])?;
            let mut lits: Vec<&xla::Literal> = vec![&tokens_lit];
            lits.extend(weight_lits.iter());
            let outs = art.execute_refs(&lits)?;
            let logits = literal_to_f32(&outs[0])?;
            let scores = continuation_scores(
                &logits,
                &tokens,
                meta.lm_batch,
                meta.seq_len,
                meta.vocab,
                prefix_len,
            );
            for (g, q) in group.iter().enumerate() {
                let qs = &scores[g * k..(g + 1) * k];
                let best = qs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                if best == q.answer {
                    correct += 1;
                }
                decisions.push(best);
                total_lp += qs[q.answer];
            }
            qi += group.len();
        }
        let acc = 100.0 * correct as f64 / questions.len() as f64;
        let flips = if fp16_decisions.is_empty() {
            0
        } else {
            decisions
                .iter()
                .zip(fp16_decisions.iter())
                .filter(|(a, b)| a != b)
                .count()
        };
        println!(
            "{:<38} {:>8.2}% {:>13.4} {:>7}",
            label,
            acc,
            total_lp / questions.len() as f64,
            flips
        );
        if fp16_decisions.is_empty() {
            fp16_decisions = decisions;
        }
    }
    println!(
        "\npaper §4.2 reference (Llama-3.1-8B MMLU): fp16 65.38, fp8-norot 64.40,\n\
         fp8+Dao 65.45, fp8+HadaCore 65.09 — the claims reproduced here are\n\
         (a) HadaCore rotation == exact-FWHT rotation numerically, and\n\
         (b) rotation recovers uniform-quantiser (int8) accuracy loss;\n\
         per-tensor fp8 (a float format) is rotation-neutral — see EXPERIMENTS.md."
    );
    Ok(())
}
