//! Quantised-pipeline accuracy study (paper §4 accuracy reproduction).
//!
//! The paper's accuracy experiment compares Llama-3.1-8B under FP16,
//! FP8 attention without rotation, and FP8 attention with a Hadamard
//! rotation. This environment has neither the model nor MMLU, so the
//! claim is reproduced at the tensor level through the native stack:
//! a multi-layer **rotate → quantize → matmul-proxy → dequantize →
//! unrotate** pipeline over synthetic outlier-channel activations (the
//! scale-invariant outlier-injection idiom: a few channels carry
//! migrated scale, see `hadacore::harness::accuracy::OUTLIER_CHANNELS`),
//! swept over kernels × dtypes × quantisation schemes × sizes including
//! the Llama dims (4096 hidden, 14336 FFN, 28672 = 2×FFN), with and
//! without the randomized rotation.
//!
//! The rotation runs through the engine's **fused sign-flip prologue**
//! (`Prologue::SignFlip`) — the production code path — and each cell
//! reports quantisation SNR (dB) and max-error-relative-to-amax against
//! an exact (unquantised) twin of the same pipeline.
//!
//! Output: a table on stdout plus a validated `hadacore-tables-v1`
//! JSON document (`TABLES_PR6.json` by default; `--out` or
//! `HADACORE_TABLES_JSON` override). CI runs `--smoke` and archives
//! the artifact.
//!
//! Run: `cargo run --release --example accuracy_study -- [--smoke]`

use hadacore::exec::ExecEngine;
use hadacore::harness::accuracy::{run_study, StudyConfig};
use hadacore::util::bench::TablesJson;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;

fn main() -> anyhow::Result<()> {
    let args = Args::new("accuracy_study", "quantised-pipeline accuracy tables")
        .switch("smoke", "reduced CI grid (one kernel, 2 dtypes, 3 sizes)")
        .opt(
            "out",
            "TABLES_PR6.json",
            "output path for the hadacore-tables-v1 JSON document",
        )
        .opt("layers", "0", "override pipeline depth (0 = grid default)")
        .opt("rows", "0", "override rows per batch (0 = grid default)")
        .parse();

    let mut cfg = if args.flag("smoke") {
        StudyConfig::smoke()
    } else {
        StudyConfig::paper()
    };
    let layers: usize = args.get_as("layers");
    if layers > 0 {
        cfg.layers = layers;
    }
    let rows: usize = args.get_as("rows");
    if rows > 0 {
        cfg.rows = rows;
    }

    println!(
        "quantised-pipeline accuracy study: {} kernels x {} dtypes x {} schemes x {} sizes, \
         {} layers, {} rows, outlier scale {}",
        cfg.kernels.len(),
        cfg.dtypes.len(),
        cfg.schemes.len(),
        cfg.sizes.len(),
        cfg.layers,
        cfg.rows,
        cfg.outlier_scale,
    );

    let engine = ExecEngine::default();
    let records = run_study(&engine, &cfg);

    let mut out = TablesJson::new();
    println!();
    for r in &records {
        println!("{}", r.line());
        out.push(r.clone());
    }

    // with/without-rotation summary: records arrive in (plain, rotated)
    // pairs over the same cell
    let mut gains: Vec<f64> = Vec::new();
    let mut losses = 0usize;
    for pair in records.chunks_exact(2) {
        let gain = pair[1].snr_db - pair[0].snr_db;
        gains.push(gain);
        if gain <= 0.0 {
            losses += 1;
        }
    }
    gains.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = gains.len() / 2;
    println!(
        "\nrotation SNR gain over {} cells: median {:+.2} dB, min {:+.2} dB, max {:+.2} dB \
         ({losses} cells where rotation did not help)",
        gains.len(),
        gains[mid],
        gains[0],
        gains[gains.len() - 1],
    );
    println!(
        "paper §4.2 reference (Llama-3.1-8B MMLU): fp16 65.38, fp8-norot 64.40, fp8+rot 65.45 —\n\
         the tensor-level claim reproduced here is that the randomized rotation raises the\n\
         quantised pipeline's SNR on outlier-heavy activations at every Llama dim."
    );

    let path = TablesJson::output_path(&args.get("out"));
    let count = out.write(&path).map_err(|e| anyhow::anyhow!(e))?;
    println!("\nwrote {count} entries to {path} (schema hadacore-tables-v1, validated on re-read)");
    Ok(())
}
