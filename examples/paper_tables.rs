//! Regenerate every table and figure of the paper's evaluation from the
//! analytical GPU model (DESIGN.md experiments E1-E6, E8).
//!
//! * Fig 4 + Fig 6a/6b — A100 FP16 runtime + speedup grids
//! * Fig 5 + Fig 7a/7b — H100 FP16 grids
//! * Fig 8 / Fig 9     — in-place ablation grids (Appendix B)
//! * Fig 10 / Fig 11   — BF16 grids (Appendix C)
//! * §3.4 roofline     — FLOP ratios + bound classification
//! * §4 accuracy       — quantised-pipeline SNR with vs without rotation
//!                       (smoke grid; the full sweep + TABLES_PR6.json
//!                       lives in `examples/accuracy_study.rs`)
//!
//! Run: `cargo run --release --example paper_tables -- --figure all --csv out/`
//!
//! Measured-on-this-CPU analogues of the same comparisons live in
//! `cargo bench` (rust/benches/paper_figures.rs).

use hadacore::gpu_model::roofline::{hadacore_bound, hadacore_intensity, FlopReport};
use hadacore::gpu_model::{
    grid::inplace_ablation_grid, speedup_grid, DeviceSpec, GpuDType, GridConfig,
    A100_PCIE, H100_PCIE, PAPER_SIZES,
};
use hadacore::harness::tables::{format_runtime_table, format_speedup_table, to_csv};
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;

fn main() -> anyhow::Result<()> {
    let args = Args::new("paper_tables", "regenerate the paper's evaluation tables")
        .opt(
            "figure",
            "all",
            "a100-fp16|h100-fp16|a100-bf16|h100-bf16|a100-inplace|h100-inplace|roofline|accuracy|all",
        )
        .opt("csv", "", "directory to also write CSV files into")
        .parse();
    let which = args.get("figure");
    let csv_dir = args.get("csv");
    if !csv_dir.is_empty() {
        std::fs::create_dir_all(&csv_dir)?;
    }

    let all = which == "all";
    if all || which == "a100-fp16" {
        fp16_grids(&A100_PCIE, "Fig 4 + 6", &csv_dir)?;
    }
    if all || which == "h100-fp16" {
        fp16_grids(&H100_PCIE, "Fig 5 + 7", &csv_dir)?;
    }
    if all || which == "a100-bf16" {
        bf16_grid(&A100_PCIE, "Fig 10", &csv_dir)?;
    }
    if all || which == "h100-bf16" {
        bf16_grid(&H100_PCIE, "Fig 11", &csv_dir)?;
    }
    if all || which == "a100-inplace" {
        inplace_grid(&A100_PCIE, "Fig 8", &csv_dir)?;
    }
    if all || which == "h100-inplace" {
        inplace_grid(&H100_PCIE, "Fig 9", &csv_dir)?;
    }
    if all || which == "roofline" {
        roofline_report();
    }
    if all || which == "accuracy" {
        accuracy_report();
    }
    Ok(())
}

fn maybe_csv(dir: &str, name: &str, header: &str, cells: &[(usize, usize, f64)]) -> anyhow::Result<()> {
    if !dir.is_empty() {
        std::fs::write(format!("{dir}/{name}.csv"), to_csv(header, cells))?;
    }
    Ok(())
}

fn fp16_grids(dev: &DeviceSpec, figure: &str, csv: &str) -> anyhow::Result<()> {
    let grid = speedup_grid(dev, GridConfig::default());
    let dao: Vec<_> = grid.iter().map(|c| (c.n, c.elems, c.dao_us)).collect();
    let hc: Vec<_> = grid.iter().map(|c| (c.n, c.elems, c.hadacore_us)).collect();
    let sp: Vec<_> = grid.iter().map(|c| (c.n, c.elems, c.speedup())).collect();

    println!(
        "{}",
        format_runtime_table(
            &format!("{figure}a [{}] baseline (Dao) runtime µs, FP16, modelled", dev.name),
            dao.clone()
        )
    );
    println!(
        "{}",
        format_runtime_table(
            &format!("{figure}a [{}] HadaCore runtime µs, FP16, modelled", dev.name),
            hc.clone()
        )
    );
    println!(
        "{}",
        format_speedup_table(
            &format!("{figure}b [{}] HadaCore speedup, FP16, modelled", dev.name),
            sp.clone()
        )
    );
    let tag = dev.name.split('-').next().unwrap_or("gpu").to_lowercase();
    maybe_csv(csv, &format!("{tag}_fp16_dao_us"), "us", &dao)?;
    maybe_csv(csv, &format!("{tag}_fp16_hadacore_us"), "us", &hc)?;
    maybe_csv(csv, &format!("{tag}_fp16_speedup"), "speedup", &sp)?;
    Ok(())
}

fn bf16_grid(dev: &DeviceSpec, figure: &str, csv: &str) -> anyhow::Result<()> {
    let grid = speedup_grid(
        dev,
        GridConfig { dtype: GpuDType::BF16, ..Default::default() },
    );
    let sp: Vec<_> = grid.iter().map(|c| (c.n, c.elems, c.speedup())).collect();
    println!(
        "{}",
        format_speedup_table(
            &format!("{figure} [{}] HadaCore speedup, BF16, modelled", dev.name),
            sp.clone()
        )
    );
    let tag = dev.name.split('-').next().unwrap_or("gpu").to_lowercase();
    maybe_csv(csv, &format!("{tag}_bf16_speedup"), "speedup", &sp)?;
    Ok(())
}

fn inplace_grid(dev: &DeviceSpec, figure: &str, csv: &str) -> anyhow::Result<()> {
    let cells = inplace_ablation_grid(dev, GpuDType::F16);
    println!(
        "{}",
        format_speedup_table(
            &format!(
                "{figure} [{}] in-place vs out-of-place baseline, FP16, modelled",
                dev.name
            ),
            cells.clone()
        )
    );
    let tag = dev.name.split('-').next().unwrap_or("gpu").to_lowercase();
    maybe_csv(csv, &format!("{tag}_inplace_speedup"), "speedup", &cells)?;
    Ok(())
}

fn roofline_report() {
    println!("## §3.4 FLOP accounting + roofline (A100)");
    println!(
        "{:>8} {:>16} {:>16} {:>8} {:>10} {:>10}",
        "size", "butterfly flops", "hadacore flops", "ratio", "intensity", "bound"
    );
    for &n in &PAPER_SIZES {
        let r = FlopReport::new(n, 1 << 22);
        let bound = hadacore_bound(&A100_PCIE, n, 1 << 22);
        println!(
            "{:>8} {:>16.3e} {:>16.3e} {:>8.2} {:>10.2} {:>10}",
            n,
            r.butterfly_flops,
            r.hadacore_flops,
            r.flop_ratio(),
            hadacore_intensity(n),
            format!("{bound:?}")
        );
    }
    println!(
        "\npaper §3.4: HadaCore spends >=2x the flops but wins on the ~8x\n\
         throughput of the matrix units and the removal of shuffle ALU work;\n\
         every paper size is memory-bound on A100, so the win shows up as\n\
         bandwidth efficiency (occupancy + L2 residency), not peak flops."
    );
}

fn accuracy_report() {
    use hadacore::exec::ExecEngine;
    use hadacore::harness::accuracy::{run_study, StudyConfig};
    println!("## §4 accuracy: quantised-pipeline SNR with vs without rotation (smoke grid)");
    let records = run_study(&ExecEngine::default(), &StudyConfig::smoke());
    for r in &records {
        println!("{}", r.line());
    }
    println!(
        "\nfull kernel x dtype x scheme sweep + TABLES_PR6.json:\n\
         cargo run --release --example accuracy_study"
    );
}
