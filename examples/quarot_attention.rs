//! End-to-end QuaRot-style quantised-attention serving (experiment E9).
//!
//! The full three-layer path on a realistic workload: the Rust runtime
//! loads the AOT-compiled attention artifacts (whose graphs embed the L1
//! Pallas HadaCore rotation), serves a stream of batched attention
//! requests per numerics variant, and reports latency/throughput plus the
//! numerical-fidelity comparison the paper's §4.2 makes.
//!
//! Run: `cargo run --release --example quarot_attention` (needs artifacts)

use std::path::Path;
use std::time::Instant;

use hadacore::runtime::xla;
use hadacore::runtime::{literal_f32, literal_to_f32, Runtime};
use hadacore::util::bench::percentile;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::prop::rel_l2;
use hadacore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::new("quarot_attention", "serve quantised attention end-to-end")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("requests", "64", "attention batches to serve per variant")
        .parse();
    let dir = Path::new(&args.get("artifacts")).to_path_buf();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    let requests: usize = args.get_as("requests");
    let rt = Runtime::open(&dir)?;
    let meta = rt.manifest().model.clone();
    let (b, t, d) = (meta.attn_batch, meta.seq_len, meta.dim);
    println!(
        "serving attention batches of shape ({b}, {t}, {d}) on {}",
        rt.platform()
    );

    // projection weights with channel-structured outliers (the LLM
    // activation regime rotations target — see DESIGN.md)
    let mut rng = Rng::new(42);
    let weights: Vec<Vec<f32>> = (0..4)
        .map(|wi| {
            let mut m: Vec<f32> = (0..d * d)
                .map(|_| rng.normal_f32() / (d as f32).sqrt())
                .collect();
            if wi < 3 {
                for c in [5usize, 21, 77] {
                    for r in 0..d {
                        m[r * d + c] *= 25.0;
                    }
                }
            }
            m
        })
        .collect();
    let weight_lits: Vec<xla::Literal> = weights
        .iter()
        .map(|w| literal_f32(w, &[d, d]).unwrap())
        .collect::<Vec<_>>();

    let variants = [
        ("fp16", "attn_fp16"),
        ("fp8 no-rot", "attn_fp8_norot"),
        ("fp8 + hadacore", "attn_fp8_rot_hadacore"),
        ("fp8 + exact", "attn_fp8_rot_butterfly"),
        ("int8 no-rot", "attn_int8_norot"),
        ("int8 + hadacore", "attn_int8_rot_hadacore"),
        ("int8 + exact", "attn_int8_rot_butterfly"),
    ];

    // one shared request stream so fidelity is comparable across variants
    let inputs: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..b * t * d).map(|_| rng.normal_f32()).collect())
        .collect();

    println!(
        "\n{:<16} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "variant", "p50 ms", "p95 ms", "req/s", "tok/s", "err vs fp16"
    );
    println!("{}", "-".repeat(76));

    let mut clean_outputs: Vec<Vec<f32>> = Vec::new();
    for (label, artifact) in variants {
        let art = rt.load(artifact)?;
        let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
        let mut outputs: Vec<Vec<f32>> = Vec::with_capacity(requests);
        let t_all = Instant::now();
        for x in &inputs {
            let x_lit = literal_f32(x, &[b, t, d])?;
            let mut lits: Vec<&xla::Literal> = vec![&x_lit];
            lits.extend(weight_lits.iter());
            let t0 = Instant::now();
            let outs = art.execute_refs(&lits)?;
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            outputs.push(literal_to_f32(&outs[0])?);
        }
        let wall = t_all.elapsed().as_secs_f64();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let err = if clean_outputs.is_empty() {
            0.0
        } else {
            let num: f64 = outputs
                .iter()
                .zip(clean_outputs.iter())
                .map(|(a, c)| rel_l2(a, c))
                .sum();
            num / outputs.len() as f64
        };
        println!(
            "{:<16} {:>10.2} {:>10.2} {:>10.1} {:>12.0} {:>12.5}",
            label,
            percentile(&lat_ms, 50.0),
            percentile(&lat_ms, 95.0),
            requests as f64 / wall,
            (requests * b * t) as f64 / wall,
            err
        );
        if clean_outputs.is_empty() {
            clean_outputs = outputs; // fp16 is the reference
        }
    }

    println!(
        "\nclaims checked: rotation kernels (hadacore vs exact) agree; int8\n\
         error drops with rotation; fp8 is rotation-neutral (float format).\n\
         Latency differences between variants show the rotation's serving\n\
         cost — the L1 kernel inside the compiled graph."
    );
    Ok(())
}
