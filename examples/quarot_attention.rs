//! End-to-end QuaRot-style quantised-attention serving (experiment E9).
//!
//! Two modes, picked by whether AOT artifacts exist:
//!
//! * **Artifact mode** — the full three-layer path: the Rust runtime
//!   loads the AOT-compiled attention artifacts (whose graphs embed the
//!   L1 Pallas HadaCore rotation), serves a stream of batched attention
//!   requests per numerics variant, and reports latency/throughput plus
//!   the numerical-fidelity comparison the paper's §4.2 makes.
//! * **Native fused mode** (no artifacts needed) — the paper's
//!   rotate→FP8 pipeline driven at a real Llama dim (14336 = 28·512)
//!   through the coordinator's **fused epilogue**:
//!   the server rotates each request and fp8-quantises it in the same
//!   pass over the data, returning the per-request scale. Compared
//!   against the two-pass pattern it replaces (plain rotation served,
//!   then a second client-side traversal to quantise) — bit-identical
//!   outputs, one fewer pass over every tensor.
//!
//! Run: `cargo run --release --example quarot_attention`
//! (add `-- --artifacts <dir>` for artifact mode)

use std::path::Path;
use std::time::Instant;

use hadacore::coordinator::{Coordinator, CoordinatorConfig};
use hadacore::hadamard::Prologue;
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::quant::{fp8_quantize_slice, Epilogue, Fp8Format};
use hadacore::runtime::xla;
use hadacore::runtime::{literal_f32, literal_to_f32, Runtime};
use hadacore::util::bench::percentile;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::prop::rel_l2;
use hadacore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::new("quarot_attention", "serve quantised attention end-to-end")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("requests", "64", "attention batches to serve per variant")
        .parse();
    let dir = Path::new(&args.get("artifacts")).to_path_buf();
    let requests: usize = args.get_as("requests");
    if dir.join("manifest.json").exists() {
        run_artifact_serving(&dir, requests)
    } else {
        println!(
            "artifacts not built — serving the fused native rotate→quantize \
             path instead (run `make artifacts` for the compiled variants)\n"
        );
        run_native_fused(requests)
    }
}

/// The shared randomised-rotation seed: QuaRot composes the Hadamard
/// with a random ±1 diagonal, and both pipelines below must draw the
/// *same* diagonal or the bit-identity comparison is vacuous.
const ROTATION_SEED: u64 = 0x9A07_5EED;

/// The no-artifact path: QuaRot-style rotate→FP8 serving through the
/// coordinator's fused epilogue, vs the two-pass client-side pattern.
/// Both arms carry the seeded sign-flip prologue, so what is measured is
/// the paper's full randomised rotation (D·H), not the bare transform.
fn run_native_fused(requests: usize) -> anyhow::Result<()> {
    // one attention block's K/V rows at the Llama-3 8B FFN width:
    // 14336 = 28 * 512 — a real down-projection rotation dim, only
    // admissible since the B * 2^k size family landed (the paper's
    // QuaRot pipeline rotates exactly these hidden dims)
    let (rows, n) = (8usize, 14336usize);
    let coord = Coordinator::start(None, CoordinatorConfig::default())?;
    println!(
        "serving {requests} rotate+quantise requests of shape ({rows}, {n}) \
         (28*512, Llama-3 8B FFN dim) on the native engine ({} exec lanes)",
        coord.exec_engine().threads()
    );

    let fused_cfg = WorkloadConfig {
        sizes: vec![n],
        rows_min: rows,
        rows_max: rows,
        epilogue: Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
        ..Default::default()
    };
    // identical payload stream (same seed), no fused epilogue
    let plain_cfg = WorkloadConfig { epilogue: Epilogue::None, ..fused_cfg.clone() };

    // fused: the server sign-flips, rotates, and fp8-quantises in one
    // pass; the response carries the per-request quantisation scale
    let mut wl = ServingWorkload::new(fused_cfg);
    let mut fused_ms: Vec<f64> = Vec::with_capacity(requests);
    let mut fused_out: Vec<(Vec<f32>, f32)> = Vec::with_capacity(requests);
    for _ in 0..requests {
        let mut req = wl.next_request();
        req.prologue = Prologue::SignFlip { seed: ROTATION_SEED };
        let t0 = Instant::now();
        let resp = coord.transform(req)?;
        fused_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let scale = resp.scales.per_tensor().unwrap_or(1.0);
        fused_out.push((resp.data.into_vec(), scale));
    }

    // two-pass: the seeded rotation served plain, then the client
    // traverses the whole tensor again to quantise — the avoidable data
    // exchange the fused epilogue removes (same prologue seed, so the
    // rotation itself is identical)
    let mut wl = ServingWorkload::new(plain_cfg);
    let mut two_ms: Vec<f64> = Vec::with_capacity(requests);
    let mut two_out: Vec<(Vec<f32>, f32)> = Vec::with_capacity(requests);
    for _ in 0..requests {
        let mut req = wl.next_request();
        req.prologue = Prologue::SignFlip { seed: ROTATION_SEED };
        let t0 = Instant::now();
        let mut resp = coord.transform(req)?;
        let scale = fp8_quantize_slice(&mut resp.data, Fp8Format::E4M3);
        two_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        two_out.push((resp.data.into_vec(), scale));
    }
    coord.shutdown();

    // numerics: the fused path must be bit-identical to two-pass
    for (i, ((a, sa), (b, sb))) in fused_out.iter().zip(two_out.iter()).enumerate()
    {
        assert_eq!(sa, sb, "request {i}: scale diverged");
        assert_eq!(a, b, "request {i}: fused output diverged from two-pass");
    }

    fused_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    two_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{:<22} {:>10} {:>10} {:>10}",
        "pipeline", "p50 ms", "p95 ms", "mean ms"
    );
    println!("{}", "-".repeat(56));
    for (label, ms) in
        [("fused epilogue", &fused_ms), ("two-pass (rot+quant)", &two_ms)]
    {
        let mean = ms.iter().sum::<f64>() / ms.len() as f64;
        println!(
            "{:<22} {:>10.3} {:>10.3} {:>10.3}",
            label,
            percentile(ms, 50.0),
            percentile(ms, 95.0),
            mean
        );
    }
    let speedup = percentile(&two_ms, 50.0) / percentile(&fused_ms, 50.0).max(1e-9);
    println!(
        "\nclaims checked: fused == two-pass bit-for-bit on all {requests} \
         requests (both under the seeded ±1 rotation prologue, seed \
         {ROTATION_SEED:#x}); per-request scales returned by the server; \
         fused p50 speedup {speedup:.2}x (one pass saved per tensor)."
    );
    Ok(())
}

/// The artifact path: serve compiled attention variants and compare
/// their numerics against the fp16 reference.
fn run_artifact_serving(dir: &Path, requests: usize) -> anyhow::Result<()> {
    let rt = Runtime::open(dir)?;
    let meta = rt.manifest().model.clone();
    let (b, t, d) = (meta.attn_batch, meta.seq_len, meta.dim);
    println!(
        "serving attention batches of shape ({b}, {t}, {d}) on {}",
        rt.platform()
    );

    // projection weights with channel-structured outliers (the LLM
    // activation regime rotations target — see DESIGN.md)
    let mut rng = Rng::new(42);
    let weights: Vec<Vec<f32>> = (0..4)
        .map(|wi| {
            let mut m: Vec<f32> = (0..d * d)
                .map(|_| rng.normal_f32() / (d as f32).sqrt())
                .collect();
            if wi < 3 {
                for c in [5usize, 21, 77] {
                    for r in 0..d {
                        m[r * d + c] *= 25.0;
                    }
                }
            }
            m
        })
        .collect();
    let weight_lits: Vec<xla::Literal> = weights
        .iter()
        .map(|w| literal_f32(w, &[d, d]).unwrap())
        .collect::<Vec<_>>();

    let variants = [
        ("fp16", "attn_fp16"),
        ("fp8 no-rot", "attn_fp8_norot"),
        ("fp8 + hadacore", "attn_fp8_rot_hadacore"),
        ("fp8 + exact", "attn_fp8_rot_butterfly"),
        ("int8 no-rot", "attn_int8_norot"),
        ("int8 + hadacore", "attn_int8_rot_hadacore"),
        ("int8 + exact", "attn_int8_rot_butterfly"),
    ];

    // one shared request stream so fidelity is comparable across variants
    let inputs: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..b * t * d).map(|_| rng.normal_f32()).collect())
        .collect();

    println!(
        "\n{:<16} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "variant", "p50 ms", "p95 ms", "req/s", "tok/s", "err vs fp16"
    );
    println!("{}", "-".repeat(76));

    let mut clean_outputs: Vec<Vec<f32>> = Vec::new();
    for (label, artifact) in variants {
        let art = rt.load(artifact)?;
        let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
        let mut outputs: Vec<Vec<f32>> = Vec::with_capacity(requests);
        let t_all = Instant::now();
        for x in &inputs {
            let x_lit = literal_f32(x, &[b, t, d])?;
            let mut lits: Vec<&xla::Literal> = vec![&x_lit];
            lits.extend(weight_lits.iter());
            let t0 = Instant::now();
            let outs = art.execute_refs(&lits)?;
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            outputs.push(literal_to_f32(&outs[0])?);
        }
        let wall = t_all.elapsed().as_secs_f64();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let err = if clean_outputs.is_empty() {
            0.0
        } else {
            let num: f64 = outputs
                .iter()
                .zip(clean_outputs.iter())
                .map(|(a, c)| rel_l2(a, c))
                .sum();
            num / outputs.len() as f64
        };
        println!(
            "{:<16} {:>10.2} {:>10.2} {:>10.1} {:>12.0} {:>12.5}",
            label,
            percentile(&lat_ms, 50.0),
            percentile(&lat_ms, 95.0),
            requests as f64 / wall,
            (requests * b * t) as f64 / wall,
            err
        );
        if clean_outputs.is_empty() {
            clean_outputs = outputs; // fp16 is the reference
        }
    }

    println!(
        "\nclaims checked: rotation kernels (hadacore vs exact) agree; int8\n\
         error drops with rotation; fp8 is rotation-neutral (float format).\n\
         Latency differences between variants show the rotation's serving\n\
         cost — the L1 kernel inside the compiled graph."
    );
    Ok(())
}
