//! Quickstart: the four ways to run a Hadamard transform with this crate.
//!
//! 1. Direct kernel call (library API) — no server, no artifacts.
//! 2. Batched execution engine — the same transform sharded across all
//!    cores with cached plans and reusable workspaces.
//! 3. Through the coordinator (native backend) — batching + metrics.
//! 4. Through the coordinator + PJRT (AOT artifacts) — the full
//!    three-layer path (requires `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`

use hadacore::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use hadacore::exec::ExecEngine;
use hadacore::hadamard::{fwht_hadacore_f32, FwhtOptions, KernelKind};
use hadacore::util::error as anyhow;
use hadacore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 1024;
    let rows = 4;
    let mut rng = Rng::new(7);

    // -- 1. direct kernel call ---------------------------------------
    let mut data = rng.normal_vec(rows * n);
    let original = data.clone();
    fwht_hadacore_f32(&mut data, n, &FwhtOptions::normalized(n));
    println!("[1] direct kernel: transformed {rows}x{n}");

    // orthonormal transform preserves norms and is its own inverse
    let norm_in: f32 = original.iter().map(|v| v * v).sum();
    let norm_out: f32 = data.iter().map(|v| v * v).sum();
    println!("    norm preserved: {:.4} -> {:.4}", norm_in, norm_out);
    fwht_hadacore_f32(&mut data, n, &FwhtOptions::normalized(n));
    let max_err = data
        .iter()
        .zip(original.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("    involution max error: {max_err:.2e}");

    // -- 2. batched multi-threaded engine ------------------------------
    let engine = ExecEngine::default();
    let big_rows = 512;
    let mut batch = rng.normal_vec(big_rows * n);
    let reference = {
        let mut r = batch.clone();
        fwht_hadacore_f32(&mut r, n, &FwhtOptions::normalized(n));
        r
    };
    let t0 = std::time::Instant::now();
    engine.run(KernelKind::HadaCore, &mut batch, n, &FwhtOptions::normalized(n));
    let dt = t0.elapsed();
    assert_eq!(batch, reference, "sharded execution is bit-identical");
    let stats = engine.stats();
    println!(
        "[2] exec engine: {big_rows}x{n} across {} lanes in {dt:?} \
         ({} chunks, bit-identical to the direct call)",
        engine.threads(),
        stats.chunks
    );

    // -- 3. coordinator, native backend -------------------------------
    let coord = Coordinator::start(None, CoordinatorConfig::default())?;
    let mut req = TransformRequest::new(1, n, rng.normal_vec(2 * n));
    req.kernel = KernelKind::HadaCore;
    let resp = coord.transform(req)?;
    println!(
        "[3] coordinator/native: id={} backend={} exec={}us",
        resp.id, resp.backend, resp.exec_us
    );
    coord.shutdown();

    // -- 4. coordinator + PJRT artifacts -------------------------------
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let coord = Coordinator::start(Some(dir.into()), CoordinatorConfig::default())?;
        let req = TransformRequest::new(2, 256, rng.normal_vec(8 * 256));
        let resp = coord.transform(req)?;
        println!(
            "[4] coordinator/pjrt: id={} backend={} exec={}us batch_rows={}",
            resp.id, resp.backend, resp.exec_us, resp.batch_rows
        );
        coord.shutdown();
    } else {
        println!("[4] skipped (run `make artifacts` to enable the PJRT path)");
    }
    Ok(())
}
