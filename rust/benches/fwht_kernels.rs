//! Kernel micro-benchmarks: measured CPU wall-clock for every transform
//! implementation across the paper's size axis.
//!
//! `cargo bench --bench fwht_kernels` — prints µs/iter medians plus the
//! HadaCore-vs-baseline speedup summary. The absolute numbers are CPU
//! numbers (the GPU grids are modelled — see examples/paper_tables.rs);
//! what must *hold* here is the algorithmic comparison: the 16x16-block
//! algorithm beating the butterfly through matrix-unit-friendly inner
//! loops, growing with transform size.

use hadacore::hadamard::{
    fwht_dao_f32, fwht_generic, fwht_hadacore_f32, fwht_scalar_f32, FwhtOptions,
    KernelKind,
};
use hadacore::util::bench::{bench, BenchConfig, Stats};
use hadacore::util::f16::{BF16, Element};
use hadacore::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    println!("# fwht_kernels — native kernel micro-benchmarks (CPU)\n");

    // -- scalar vs dao vs hadacore across sizes ------------------------
    let elems = 1 << 18; // 256K elements per call
    println!("## f32 kernels, {} elements/call", elems);
    let mut rows_speedup: Vec<(usize, f64, f64)> = Vec::new();
    for k in [7usize, 8, 9, 10, 11, 12, 13, 14, 15] {
        let n = 1usize << k;
        let rows = elems / n;
        let mut rng = Rng::new(n as u64);
        let base = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let mut run = |kind: KernelKind| -> Stats {
            let label = format!("{}_{}", kind.name(), n);
            let b = base.clone();
            let mut data = base.clone();
            bench(&label, &cfg, move |_| {
                data.copy_from_slice(&b);
                match kind {
                    KernelKind::Scalar => fwht_scalar_f32(&mut data, n, &opts),
                    KernelKind::Dao => fwht_dao_f32(&mut data, n, &opts),
                    KernelKind::HadaCore => fwht_hadacore_f32(&mut data, n, &opts),
                }
                data[0]
            })
        };
        let s_scalar = run(KernelKind::Scalar);
        let s_dao = run(KernelKind::Dao);
        let s_hc = run(KernelKind::HadaCore);
        println!("{}", s_scalar.line());
        println!("{}", s_dao.line());
        println!("{}", s_hc.line());
        rows_speedup.push((
            n,
            s_dao.median_ns / s_hc.median_ns,
            s_scalar.median_ns / s_hc.median_ns,
        ));
    }
    println!("\n## speedup summary (measured, this CPU)");
    println!("{:>8} {:>18} {:>18}", "size", "hadacore/dao", "hadacore/scalar");
    for (n, vs_dao, vs_scalar) in &rows_speedup {
        println!("{:>8} {:>17.2}x {:>17.2}x", n, vs_dao, vs_scalar);
    }

    // -- non-power-of-two sizes (B * 2^k family) -----------------------
    // the leading base-matrix stage's cost on top of the mma rounds, at
    // the Llama-relevant dims the family exists for
    println!("\n## non-power-of-two sizes (leading base stage + mma rounds)");
    for n in [768usize, 5120, 14336] {
        let rows = (1 << 18) / n;
        let mut rng = Rng::new(n as u64);
        let base = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        for kind in [KernelKind::Dao, KernelKind::HadaCore] {
            let label = format!("{}_{}", kind.name(), n);
            let b = base.clone();
            let mut data = base.clone();
            let s: Stats = bench(&label, &cfg, move |_| {
                data.copy_from_slice(&b);
                match kind {
                    KernelKind::Scalar => fwht_scalar_f32(&mut data, n, &opts),
                    KernelKind::Dao => fwht_dao_f32(&mut data, n, &opts),
                    KernelKind::HadaCore => fwht_hadacore_f32(&mut data, n, &opts),
                }
                data[0]
            });
            println!("{}", s.line());
        }
    }

    // -- bf16 (paper appendix C) ---------------------------------------
    println!("\n## bf16 path (fp32 accumulate + convert)");
    for n in [256usize, 4096] {
        let rows = (1 << 16) / n;
        let mut rng = Rng::new(3);
        let f32_data = rng.normal_vec(rows * n);
        let bf_base: Vec<BF16> = f32_data.iter().map(|&v| BF16::from_f32(v)).collect();
        let opts = FwhtOptions::normalized(n);
        for kind in [KernelKind::Dao, KernelKind::HadaCore] {
            let label = format!("bf16_{}_{}", kind.name(), n);
            let mut buf = bf_base.clone();
            let b = bf_base.clone();
            let s: Stats = bench(&label, &cfg, move |_| {
                buf.copy_from_slice(&b);
                fwht_generic(kind, &mut buf, n, &opts);
                buf[0]
            });
            println!("{}", s.line());
        }
    }

    // -- residual-mode ablation (DESIGN.md design-choice bench) ----------
    // BlockDiagonal (paper §3.3, uniform 16x16 rounds) vs SmallFactor
    // (direct small contraction): equal math, different pass structure.
    println!("\n## residual-mode ablation (non-power-of-16 sizes)");
    {
        use hadacore::hadamard::hadacore::{
            fwht_hadacore_f32_cfg, HadaCoreConfig, ResidualMode,
        };
        for n in [128usize, 512, 2048, 8192] {
            let rows = (1 << 17) / n;
            let mut rng = Rng::new(n as u64);
            let base = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            for (label, mode) in [
                ("blockdiag", ResidualMode::BlockDiagonal),
                ("smallfactor", ResidualMode::SmallFactor),
            ] {
                let b = base.clone();
                let mut buf = base.clone();
                let cfg_k = HadaCoreConfig { residual: mode };
                let s = bench(&format!("{label}_{n}"), &cfg, move |_| {
                    buf.copy_from_slice(&b);
                    fwht_hadacore_f32_cfg(&mut buf, n, &opts, &cfg_k);
                    buf[0]
                });
                println!("{}", s.line());
            }
        }
    }

    // -- per-group quantisation sweep (QuaRot granularity) ----------------
    println!("\n## int4 per-group quantisation error (outlier tensor, n=4096)");
    {
        use hadacore::quant::{group_size_sweep, IntBits};
        let mut rng = Rng::new(77);
        let mut x = rng.normal_vec(4096);
        for i in (0..4096).step_by(64) {
            x[i] *= 40.0;
        }
        for (g, err) in group_size_sweep(&x, &[32, 128, 1024, 4096], IntBits::Int4) {
            println!("group={g:>5}: rel_l2 {err:.5}");
        }
        let mut rot = x.clone();
        let opts = FwhtOptions::normalized(4096);
        fwht_hadacore_f32(&mut rot, 4096, &opts);
        for (g, err) in group_size_sweep(&rot, &[128, 4096], IntBits::Int4) {
            println!("rotated, group={g:>5}: rel_l2 {err:.5}");
        }
    }

    // -- in-place vs out-of-place (paper appendix B) ---------------------
    println!("\n## in-place vs out-of-place (cache-footprint ablation)");
    for log_e in [16usize, 21, 24] {
        let elems = 1usize << log_e;
        let n = 1024;
        let rows = elems / n;
        let mut rng = Rng::new(9);
        let base = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let mut ip_buf = base.clone();
        let b1 = base.clone();
        let s_ip = bench(&format!("inplace_{}K", elems >> 10), &cfg, move |_| {
            ip_buf.copy_from_slice(&b1);
            fwht_hadacore_f32(&mut ip_buf, n, &opts);
            ip_buf[0]
        });
        let b2 = base.clone();
        let s_oop = bench(&format!("outofplace_{}K", elems >> 10), &cfg, move |_| {
            // out-of-place: fresh destination allocation + copy + transform
            let mut dst = b2.clone();
            fwht_hadacore_f32(&mut dst, n, &opts);
            dst[0]
        });
        println!("{}", s_ip.line());
        println!("{}", s_oop.line());
        println!(
            "    in-place gain at {}K elements: {:.2}x",
            elems >> 10,
            s_oop.median_ns / s_ip.median_ns
        );
    }
}
