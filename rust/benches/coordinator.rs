//! Coordinator serving benchmarks: batching efficiency, per-request
//! overhead, and backend comparison (experiment E9's performance side).
//!
//! `cargo bench --bench coordinator`

use std::time::{Duration, Instant};

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouterConfig, TransformRequest,
};
use hadacore::hadamard::{fwht_hadacore_f32, FwhtOptions, KernelKind};
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::util::bench::percentile;
use hadacore::util::rng::Rng;

fn native(workers: usize, delay_us: u64) -> Coordinator {
    Coordinator::start(
        None,
        CoordinatorConfig {
            workers,
            batcher: BatcherConfig { max_delay: Duration::from_micros(delay_us), ..Default::default() },
            router: RouterConfig::default(),
            idle_timeout: Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap()
}

fn main() {
    println!("# coordinator — serving-path benchmarks\n");

    // -- 1. per-request overhead: coordinator vs direct kernel call -----
    let n = 1024;
    let mut rng = Rng::new(1);
    let payload = rng.normal_vec(n);
    let opts = FwhtOptions::normalized(n);

    let iters = 2000;
    let mut direct = payload.clone();
    let t0 = Instant::now();
    for _ in 0..iters {
        direct.copy_from_slice(&payload);
        fwht_hadacore_f32(&mut direct, n, &opts);
    }
    let t_direct_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let coord = native(2, 50);
    let mut lat = Vec::with_capacity(iters);
    for i in 0..iters {
        let t1 = Instant::now();
        let _ = coord
            .transform(TransformRequest::new(i as u64, n, payload.clone()))
            .unwrap();
        lat.push(t1.elapsed().as_secs_f64() * 1e6);
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = percentile(&lat, 50.0);
    println!("## per-request overhead (n={n}, closed loop)");
    println!("direct kernel call:        {t_direct_us:>8.1} µs");
    println!("through coordinator (p50): {p50:>8.1} µs");
    println!("overhead:                  {:>8.1} µs\n", p50 - t_direct_us);
    coord.shutdown();

    // -- 2. throughput scaling with workers ------------------------------
    // requests are pre-generated: the first version of this bench timed
    // the Box-Muller payload generation and was generator-bound (§Perf).
    println!("## open-loop throughput vs worker count (mixed sizes)");
    for workers in [1usize, 2, 4, 8] {
        let coord = native(workers, 200);
        let mut wl = ServingWorkload::new(WorkloadConfig {
            sizes: vec![128, 256, 1024, 4096],
            kernel: KernelKind::HadaCore,
            ..Default::default()
        });
        let total = 4000;
        let requests = wl.take(total);
        let t0 = Instant::now();
        let handles: Vec<_> = requests
            .into_iter()
            .map(|r| coord.submit(r).unwrap())
            .collect();
        let mut elems = 0usize;
        for h in handles {
            elems += h.recv().unwrap().unwrap().data.len();
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = coord.metrics().snapshot();
        println!(
            "workers={workers}: {:>7.0} req/s  {:>6.1} M elem/s  batches={} (avg {:.1} reqs/batch)",
            total as f64 / dt,
            elems as f64 / dt / 1e6,
            snap.batches,
            snap.completed as f64 / snap.batches.max(1) as f64,
        );
        coord.shutdown();
    }

    // -- 3. batching deadline sweep: latency/throughput trade ------------
    println!("\n## batching deadline sweep (n=256, 4000 open-loop requests)");
    println!(
        "{:>12} {:>10} {:>12} {:>14}",
        "deadline µs", "req/s", "e2e p50 µs", "reqs/batch"
    );
    for delay in [0u64, 100, 500, 2000] {
        let coord = native(4, delay);
        let mut wl = ServingWorkload::new(WorkloadConfig {
            sizes: vec![256],
            rows_min: 1,
            rows_max: 1,
            ..Default::default()
        });
        let total = 4000;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..total)
            .map(|_| coord.submit(wl.next_request()).unwrap())
            .collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = coord.metrics().snapshot();
        println!(
            "{:>12} {:>10.0} {:>12} {:>14.1}",
            delay,
            total as f64 / dt,
            snap.e2e_p50_us,
            snap.completed as f64 / snap.batches.max(1) as f64,
        );
        coord.shutdown();
    }

    // -- 4. PJRT backend (when artifacts exist) ---------------------------
    // requests carry 64 rows each so two requests fill the 128-row n=256
    // bucket: the pjrt arm genuinely executes on PJRT (under-filled
    // batches would fall back to native by policy).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        println!("\n## pjrt vs native backend (n=256, 64-row requests)");
        for force_native in [false, true] {
            let coord = Coordinator::start(
                Some(dir.into()),
                CoordinatorConfig {
                    workers: 2,
                    batcher: BatcherConfig { max_delay: Duration::from_micros(300), ..Default::default() },
                    ..Default::default()
                },
            )
            .unwrap();
            let total = 200;
            let rows = 64;
            let mut rng = Rng::new(5);
            let payloads: Vec<Vec<f32>> =
                (0..total).map(|_| rng.normal_vec(rows * 256)).collect();
            let t0 = Instant::now();
            let handles: Vec<_> = payloads
                .into_iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut req = TransformRequest::new(i as u64, 256, p);
                    req.force_native = force_native;
                    coord.submit(req).unwrap()
                })
                .collect();
            let mut backend = "";
            for h in handles {
                backend = h.recv().unwrap().unwrap().backend;
            }
            let dt = t0.elapsed().as_secs_f64();
            let snap = coord.metrics().snapshot();
            println!(
                "backend={backend:<7} {:>8.0} req/s  {:>6.1} M elem/s  (exec p50 {} µs, pjrt batches {})",
                total as f64 / dt,
                (total * rows * 256) as f64 / dt / 1e6,
                snap.exec_p50_us,
                snap.pjrt_batches,
            );
            coord.shutdown();
        }
    } else {
        println!("\n(pjrt comparison skipped: artifacts not built)");
    }
}
