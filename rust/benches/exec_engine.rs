//! Batched-engine benchmarks: single-thread vs pooled throughput across
//! the paper's size axis, plus the 16-bit workspace-reuse check.
//!
//! `cargo bench --bench exec_engine` (add `--quick` for a short run).
//!
//! The headline number is the **pool speedup** — batch throughput with
//! the worker pool over the same batch on one thread. On a multi-core
//! host the large-batch rows should report >= 2x; the engine's win is the
//! sharding, so tiny batches (which run inline by policy) report ~1x.

use hadacore::exec::{ExecConfig, ExecEngine};
use hadacore::hadamard::{FwhtOptions, KernelKind};
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::quant::{fp8_quantize_slice, Epilogue, Fp8Format};
use hadacore::util::bench::{bench, BenchConfig};
use hadacore::util::f16::{Element, F16};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    let single = ExecEngine::single_threaded();
    let pooled = ExecEngine::default();
    println!(
        "# exec_engine — batched execution engine (CPU, {} lanes)\n",
        pooled.threads()
    );

    // -- single-thread vs pooled, f32, fixed element budget ------------
    let elems = 1usize << 21; // 2M f32 per batch = 8 MiB
    println!("## f32 HadaCore batches, {} elements/batch", elems);
    let mut wl = ServingWorkload::new(WorkloadConfig::default());
    let mut summary: Vec<(usize, usize, f64)> = Vec::new();
    // 14336 = 28 * 512: the non-power-of-two Llama-3 FFN dim — the
    // engine shards its base-stage + mma-round schedule like any other
    for n in [256usize, 1024, 4096, 14336, 16384] {
        let rows = elems / n;
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);

        let b1 = base.clone();
        let mut buf1 = base.clone();
        let single_ref = &single;
        let s_single = bench(&format!("single_{rows}x{n}"), &cfg, move |_| {
            buf1.copy_from_slice(&b1);
            single_ref.run_f32(KernelKind::HadaCore, &mut buf1, n, &opts);
            buf1[0]
        });
        let b2 = base.clone();
        let mut buf2 = base;
        let pooled_ref = &pooled;
        let s_pooled = bench(&format!("pooled_{rows}x{n}"), &cfg, move |_| {
            buf2.copy_from_slice(&b2);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf2, n, &opts);
            buf2[0]
        });
        println!("{}", s_single.line());
        println!("{}", s_pooled.line());
        summary.push((n, rows, s_single.median_ns / s_pooled.median_ns));
    }
    println!("\n## pool speedup summary ({} lanes)", pooled.threads());
    println!("{:>8} {:>8} {:>12}", "size", "rows", "speedup");
    for (n, rows, speedup) in &summary {
        println!("{:>8} {:>8} {:>11.2}x", n, rows, speedup);
    }
    let best = summary.iter().map(|c| c.2).fold(0.0f64, f64::max);
    println!(
        "best pool speedup: {best:.2}x {}",
        if best >= 2.0 {
            "(meets the >= 2x multi-core bar)"
        } else {
            "(below 2x — single-core host or loaded machine?)"
        }
    );

    // -- fused rotate→quantize epilogue vs the unfused two-pass --------
    // two-pass = engine transform, then a second full traversal through
    // fp8_quantize_slice (amax pass + round pass over cold data); fused =
    // one engine call quantising each chunk while it is cache-hot, with
    // the amax reduced per chunk into a shared accumulator.
    println!("\n## fused fp8 epilogue vs two-pass (transform then quantize)");
    let mut fused_summary: Vec<(usize, usize, f64)> = Vec::new();
    for n in [256usize, 1024, 4096, 16384] {
        let rows = elems / n;
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);

        let b1 = base.clone();
        let mut buf1 = base.clone();
        let pooled_ref = &pooled;
        let s_two_pass = bench(&format!("two_pass_{rows}x{n}"), &cfg, move |_| {
            buf1.copy_from_slice(&b1);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf1, n, &opts);
            fp8_quantize_slice(&mut buf1, Fp8Format::E4M3)
        });
        let b2 = base.clone();
        let mut buf2 = base;
        let s_fused = bench(&format!("fused___{rows}x{n}"), &cfg, move |_| {
            buf2.copy_from_slice(&b2);
            pooled_ref
                .run_f32_with_epilogue(
                    KernelKind::HadaCore,
                    &mut buf2,
                    n,
                    &opts,
                    Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
                )
                .per_tensor()
                .unwrap_or(1.0)
        });
        println!("{}", s_two_pass.line());
        println!("{}", s_fused.line());
        fused_summary.push((n, rows, s_two_pass.median_ns / s_fused.median_ns));
    }
    println!("\n## fused epilogue speedup summary (vs two-pass)");
    println!("{:>8} {:>8} {:>12}", "size", "rows", "speedup");
    for (n, rows, speedup) in &fused_summary {
        println!("{:>8} {:>8} {:>11.2}x", n, rows, speedup);
    }

    // -- tiny batches route inline (sharding would cost more) ----------
    println!("\n## tiny-batch policy (1 row — runs inline by design)");
    for n in [256usize, 4096] {
        let base = wl.next_matrix(1, n);
        let opts = FwhtOptions::normalized(n);
        let mut buf = base.clone();
        let pooled_ref = &pooled;
        let s = bench(&format!("pooled_tiny_1x{n}"), &cfg, move |_| {
            buf.copy_from_slice(&base);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf, n, &opts);
            buf[0]
        });
        println!("{}", s.line());
    }

    // -- 16-bit path: workspace reuse = zero steady-state allocation ---
    println!("\n## f16 widen-compute-narrow path (per-thread workspaces)");
    let n = 4096;
    let rows = (1usize << 19) / n;
    let f32_base = wl.next_matrix(rows, n);
    let f16_base: Vec<F16> = f32_base.iter().map(|&v| F16::from_f32(v)).collect();
    let opts = FwhtOptions::normalized(n);
    let grows_before = pooled.stats().scratch_grows;
    let mut buf = f16_base.clone();
    let pooled_ref = &pooled;
    let s = bench(&format!("pooled_f16_{rows}x{n}"), &cfg, move |_| {
        buf.copy_from_slice(&f16_base);
        pooled_ref.run(KernelKind::HadaCore, &mut buf, n, &opts);
        buf[0].0
    });
    println!("{}", s.line());
    let stats = pooled.stats();
    println!(
        "workspace growths during the f16 run: {} (chunks executed: {}) — \
         bounded by lane count, flat in steady state",
        stats.scratch_grows - grows_before,
        stats.chunks
    );
}
