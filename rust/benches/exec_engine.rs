//! Batched-engine benchmarks: single-thread vs pooled throughput across
//! the paper's size axis, the round-fusion depth sweep, plus the 16-bit
//! workspace-reuse check.
//!
//! `cargo bench --bench exec_engine` (add `--quick` for a short run;
//! `--smoke` runs only the tiny-size fusion sweep — the CI gate that
//! checks the machine-readable output exists and is well-formed).
//!
//! Every run writes `BENCH_PR4.json` (override with
//! `HADACORE_BENCH_JSON`): one entry per measured (size × kernel ×
//! fusion depth × dtype) case, schema `hadacore-bench-v1` — the repo's
//! perf trajectory. The file is re-read and schema-validated before the
//! binary exits, so a malformed emission fails the run.
//!
//! Every run additionally writes `BENCH_PR8.json` (override with
//! `HADACORE_BENCH_PR8_JSON`): the scalar-table-vs-SIMD dispatch
//! comparison per (size × fusion depth), with the backend each case ran
//! under recorded in the `bench` field (`simd:<backend>`) and the
//! vector width in the `simd_lanes` extra.
//!
//! The headline numbers are the **pool speedup** — batch throughput with
//! the worker pool over the same batch on one thread — and the **fusion
//! speedup** — the tuned multi-round tile traversal over the classic
//! one-traversal-per-round schedule.

use hadacore::exec::{tuning_for, ExecConfig, ExecEngine};
use hadacore::hadamard::hadacore::{
    fwht_hadacore_f32_planned_depth, HadaCoreConfig, HadaCorePlan,
};
use hadacore::hadamard::{fwht_f32, FwhtOptions, KernelKind};
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::quant::{fp8_quantize_slice, Epilogue, Fp8Format};
use hadacore::util::bench::{bench, BenchConfig, BenchJson, BenchRecord};
use hadacore::util::f16::{DType, Element, F16};

/// The fusion-depth sweep: direct planned-kernel calls per depth (clean
/// attribution, no pool noise), every kernel at its natural depth, and
/// one tuned-engine row per size. Appends one JSON record per case.
fn fusion_sweep(
    sizes: &[usize],
    elems: usize,
    cfg: &BenchConfig,
    engine: &ExecEngine,
    engine_cfg: &ExecConfig,
    wl: &mut ServingWorkload,
    out: &mut BenchJson,
) {
    println!("\n## round-fusion sweep (direct planned kernel, f32)");
    for &n in sizes {
        let rows = (elems / n).max(1);
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);
        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());

        // butterfly baselines at their (only) depth
        for kind in [KernelKind::Scalar, KernelKind::Dao] {
            let b = base.clone();
            let mut buf = base.clone();
            let s = bench(
                &format!("{}_{rows}x{n}", kind.name()),
                cfg,
                move |_| {
                    buf.copy_from_slice(&b);
                    fwht_f32(kind, &mut buf, n, &opts);
                    buf[0]
                },
            );
            println!("{}", s.line());
            out.push(BenchRecord::new(
                "fusion_sweep",
                kind.name(),
                n,
                rows,
                DType::F32.name(),
                1,
                0,
                s,
            ));
        }

        // hadacore at every fusion depth; each record carries the
        // roofline model's recommended depth for this (n, lanes) so
        // bench/roofline_report.py can join prediction against the
        // measured sweep
        let model_depth = hadacore::gpu_model::roofline::recommend_fusion_depth_for_lanes(
            &plan,
            hadacore::exec::tune::FUSION_CACHE_BUDGET,
            hadacore::hadamard::simd::active().lanes(),
        )
        .min(plan.max_fusion_depth());
        let mut depth1_ns = 0.0f64;
        for depth in 1..=plan.max_fusion_depth() {
            let b = base.clone();
            let mut buf = base.clone();
            let p = plan.clone();
            let s = bench(
                &format!("hadacore_d{depth}_{rows}x{n}"),
                cfg,
                move |_| {
                    buf.copy_from_slice(&b);
                    fwht_hadacore_f32_planned_depth(&mut buf, &p, &opts, depth);
                    buf[0]
                },
            );
            println!("{}", s.line());
            if depth == 1 {
                depth1_ns = s.median_ns;
            } else {
                println!(
                    "    -> fusion speedup vs depth 1: {:.2}x (model bound {:.2}x)",
                    depth1_ns / s.median_ns,
                    hadacore::gpu_model::roofline::fusion_speedup_bound(n, depth),
                );
            }
            out.push(
                BenchRecord::new(
                    "fusion_sweep",
                    "hadacore",
                    n,
                    rows,
                    DType::F32.name(),
                    depth,
                    0,
                    s,
                )
                .with_extra("model_depth", model_depth as f64)
                .with_extra(
                    "simd_lanes",
                    hadacore::hadamard::simd::active().lanes() as f64,
                ),
            );
        }

        // the tuned engine end to end (whatever depth the tuner picked)
        let tuned =
            tuning_for(engine_cfg, KernelKind::HadaCore, n, rows, DType::F32);
        let b = base.clone();
        let mut buf = base;
        let s = bench(&format!("engine_tuned_{rows}x{n}"), cfg, move |_| {
            buf.copy_from_slice(&b);
            engine.run_f32(KernelKind::HadaCore, &mut buf, n, &opts);
            buf[0]
        });
        println!(
            "{}  [tuned depth {} chunk {} rows]",
            s.line(),
            tuned.fusion_depth,
            tuned.chunk_rows
        );
        out.push(BenchRecord::new(
            "engine_tuned",
            "hadacore",
            n,
            rows,
            DType::F32.name(),
            tuned.fusion_depth,
            engine.threads(),
            s,
        ));
    }
}

/// Scalar-table-vs-SIMD dispatch comparison (ISSUE 8): per (size ×
/// fusion depth), bench the direct planned kernel once under the forced
/// scalar table and once under the auto-detected vector backend, and
/// print the throughput ratio. Records land in the PR8 trajectory file:
/// `bench` = `simd:<backend>` names the table each case ran under,
/// `simd_lanes` carries the vector width (1 = scalar). When no vector
/// ISA is reachable (or `HADACORE_SIMD=off` froze the choice) only the
/// scalar rows are emitted — the file still records which backend was
/// active.
fn simd_compare(
    sizes: &[usize],
    elems: usize,
    cfg: &BenchConfig,
    wl: &mut ServingWorkload,
    out: &mut BenchJson,
) {
    use hadacore::hadamard::simd::{self, Backend};
    let best = simd::detect();
    println!(
        "\n## simd dispatch compare (forced scalar table vs {}, direct planned kernel)",
        best.name()
    );
    let prev = simd::active();
    let mut backends = vec![Backend::Scalar];
    if best != Backend::Scalar {
        backends.push(best);
    }
    for &n in sizes {
        let rows = (elems / n).max(1);
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);
        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
        for depth in 1..=plan.max_fusion_depth() {
            let mut scalar_ns = f64::NAN;
            for &backend in &backends {
                simd::force(backend).expect("compare backend reachable");
                let b = base.clone();
                let mut buf = base.clone();
                let p = plan.clone();
                let s = bench(
                    &format!("simd_{}_d{depth}_{rows}x{n}", backend.name()),
                    cfg,
                    move |_| {
                        buf.copy_from_slice(&b);
                        fwht_hadacore_f32_planned_depth(&mut buf, &p, &opts, depth);
                        buf[0]
                    },
                );
                println!("{}", s.line());
                if backend == Backend::Scalar {
                    scalar_ns = s.median_ns;
                } else {
                    println!(
                        "    -> simd speedup vs scalar table: {:.2}x ({} lanes)",
                        scalar_ns / s.median_ns,
                        backend.lanes()
                    );
                }
                out.push(
                    BenchRecord::new(
                        &format!("simd:{}", backend.name()),
                        "hadacore",
                        n,
                        rows,
                        DType::F32.name(),
                        depth,
                        0,
                        s,
                    )
                    .with_extra("simd_lanes", backend.lanes() as f64),
                );
            }
        }
    }
    simd::force(prev).expect("restore backend after compare");
}

/// Resolve the PR8 trajectory path: `HADACORE_BENCH_PR8_JSON` env
/// override, else `BENCH_PR8.json` in the cargo working directory.
fn pr8_json_path() -> String {
    std::env::var("HADACORE_BENCH_PR8_JSON")
        .unwrap_or_else(|_| "BENCH_PR8.json".to_string())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if quick || smoke {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let mut out = BenchJson::new();
    let json_path = BenchJson::output_path("BENCH_PR4.json");

    let engine_cfg = ExecConfig::default();
    let single = ExecEngine::single_threaded();
    let pooled = ExecEngine::new(engine_cfg);
    println!(
        "# exec_engine — batched execution engine (CPU, {} lanes)\n",
        pooled.threads()
    );

    if smoke {
        // CI gate: tiny sizes, quick config, JSON emission + validation
        let mut wl = ServingWorkload::new(WorkloadConfig::default());
        fusion_sweep(
            &[256, 768],
            1 << 14,
            &cfg,
            &pooled,
            &engine_cfg,
            &mut wl,
            &mut out,
        );
        finish_json(&out, &json_path);
        let mut out8 = BenchJson::new();
        simd_compare(&[256, 768], 1 << 14, &cfg, &mut wl, &mut out8);
        finish_json(&out8, &pr8_json_path());
        return;
    }

    // -- single-thread vs pooled, f32, fixed element budget ------------
    let elems = 1usize << 21; // 2M f32 per batch = 8 MiB
    println!("## f32 HadaCore batches, {} elements/batch", elems);
    let mut wl = ServingWorkload::new(WorkloadConfig::default());
    let mut summary: Vec<(usize, usize, f64)> = Vec::new();
    // 14336 = 28 * 512: the non-power-of-two Llama-3 FFN dim — the
    // engine shards its base-stage + mma-round schedule like any other
    for n in [256usize, 1024, 4096, 14336, 16384] {
        let rows = elems / n;
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);

        let b1 = base.clone();
        let mut buf1 = base.clone();
        let single_ref = &single;
        let s_single = bench(&format!("single_{rows}x{n}"), &cfg, move |_| {
            buf1.copy_from_slice(&b1);
            single_ref.run_f32(KernelKind::HadaCore, &mut buf1, n, &opts);
            buf1[0]
        });
        let b2 = base.clone();
        let mut buf2 = base;
        let pooled_ref = &pooled;
        let s_pooled = bench(&format!("pooled_{rows}x{n}"), &cfg, move |_| {
            buf2.copy_from_slice(&b2);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf2, n, &opts);
            buf2[0]
        });
        println!("{}", s_single.line());
        println!("{}", s_pooled.line());
        summary.push((n, rows, s_single.median_ns / s_pooled.median_ns));
    }
    println!("\n## pool speedup summary ({} lanes)", pooled.threads());
    println!("{:>8} {:>8} {:>12}", "size", "rows", "speedup");
    for (n, rows, speedup) in &summary {
        println!("{:>8} {:>8} {:>11.2}x", n, rows, speedup);
    }
    let best = summary.iter().map(|c| c.2).fold(0.0f64, f64::max);
    println!(
        "best pool speedup: {best:.2}x {}",
        if best >= 2.0 {
            "(meets the >= 2x multi-core bar)"
        } else {
            "(below 2x — single-core host or loaded machine?)"
        }
    );

    // -- round-fusion depth sweep (the autotuner's search space) -------
    fusion_sweep(
        &[256, 1024, 4096, 8192, 14336, 32768],
        elems,
        &cfg,
        &pooled,
        &engine_cfg,
        &mut wl,
        &mut out,
    );

    // -- fused rotate→quantize epilogue vs the unfused two-pass --------
    // two-pass = engine transform, then a second full traversal through
    // fp8_quantize_slice (amax pass + round pass over cold data); fused =
    // one engine call quantising each chunk while it is cache-hot, with
    // the amax reduced per chunk into a shared accumulator.
    println!("\n## fused fp8 epilogue vs two-pass (transform then quantize)");
    let mut fused_summary: Vec<(usize, usize, f64)> = Vec::new();
    for n in [256usize, 1024, 4096, 16384] {
        let rows = elems / n;
        let base = wl.next_matrix(rows, n);
        let opts = FwhtOptions::normalized(n);

        let b1 = base.clone();
        let mut buf1 = base.clone();
        let pooled_ref = &pooled;
        let s_two_pass = bench(&format!("two_pass_{rows}x{n}"), &cfg, move |_| {
            buf1.copy_from_slice(&b1);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf1, n, &opts);
            fp8_quantize_slice(&mut buf1, Fp8Format::E4M3)
        });
        let b2 = base.clone();
        let mut buf2 = base;
        let s_fused = bench(&format!("fused___{rows}x{n}"), &cfg, move |_| {
            buf2.copy_from_slice(&b2);
            pooled_ref
                .run_f32_with_epilogue(
                    KernelKind::HadaCore,
                    &mut buf2,
                    n,
                    &opts,
                    Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
                )
                .per_tensor()
                .unwrap_or(1.0)
        });
        println!("{}", s_two_pass.line());
        println!("{}", s_fused.line());
        fused_summary.push((n, rows, s_two_pass.median_ns / s_fused.median_ns));
    }
    println!("\n## fused epilogue speedup summary (vs two-pass)");
    println!("{:>8} {:>8} {:>12}", "size", "rows", "speedup");
    for (n, rows, speedup) in &fused_summary {
        println!("{:>8} {:>8} {:>11.2}x", n, rows, speedup);
    }

    // -- tiny batches route inline (sharding would cost more) ----------
    println!("\n## tiny-batch policy (1 row — runs inline by design)");
    for n in [256usize, 4096] {
        let base = wl.next_matrix(1, n);
        let opts = FwhtOptions::normalized(n);
        let mut buf = base.clone();
        let pooled_ref = &pooled;
        let s = bench(&format!("pooled_tiny_1x{n}"), &cfg, move |_| {
            buf.copy_from_slice(&base);
            pooled_ref.run_f32(KernelKind::HadaCore, &mut buf, n, &opts);
            buf[0]
        });
        println!("{}", s.line());
    }

    // -- 16-bit path: workspace reuse = zero steady-state allocation ---
    println!("\n## f16 widen-compute-narrow path (per-thread workspaces)");
    let n = 4096;
    let rows = (1usize << 19) / n;
    let f32_base = wl.next_matrix(rows, n);
    let f16_base: Vec<F16> = f32_base.iter().map(|&v| F16::from_f32(v)).collect();
    let opts = FwhtOptions::normalized(n);
    let grows_before = pooled.stats().scratch_grows;
    let mut buf = f16_base.clone();
    let pooled_ref = &pooled;
    let s = bench(&format!("pooled_f16_{rows}x{n}"), &cfg, move |_| {
        buf.copy_from_slice(&f16_base);
        pooled_ref.run(KernelKind::HadaCore, &mut buf, n, &opts);
        buf[0].0
    });
    println!("{}", s.line());
    let stats = pooled.stats();
    println!(
        "workspace growths during the f16 run: {} (chunks executed: {}) — \
         bounded by lane count, flat in steady state",
        stats.scratch_grows - grows_before,
        stats.chunks
    );
    out.push(BenchRecord::new(
        "engine_f16",
        "hadacore",
        n,
        rows,
        DType::F16.name(),
        tuning_for(&engine_cfg, KernelKind::HadaCore, n, rows, DType::F16)
            .fusion_depth,
        pooled.threads(),
        s,
    ));

    finish_json(&out, &json_path);

    // -- scalar table vs SIMD dispatch (the PR8 trajectory) ------------
    let mut out8 = BenchJson::new();
    simd_compare(&[256, 1024, 4096, 14336], elems, &cfg, &mut wl, &mut out8);
    finish_json(&out8, &pr8_json_path());
}

/// Write + re-validate the machine-readable output; a malformed emission
/// aborts the bench run (CI treats that as a failed smoke step).
fn finish_json(out: &BenchJson, path: &str) {
    match out.write(path) {
        Ok(entries) => {
            println!("\nwrote {path}: {entries} entries (schema valid)")
        }
        Err(e) => panic!("bench JSON emission failed: {e}"),
    }
}
