//! Serving-layer micro-benchmarks: wire codec cost and loopback RTT.
//!
//! Two sections:
//!
//! * `codec` — encode/decode cost of request/response frames across
//!   payload sizes and dtypes (the per-request serialization tax the
//!   serving layer adds on top of the transform itself).
//! * `loopback` — single-request round-trip latency and pipelined
//!   throughput through a real TCP server on the loopback interface.
//!
//! Run: `cargo bench --bench serve_wire` (add `-- --smoke` for the CI
//! quick pass).

use std::sync::Arc;
use std::time::Duration;

use hadacore::coordinator::{Coordinator, CoordinatorConfig};
use hadacore::hadamard::KernelKind;
use hadacore::serve::wire::{decode_frame, Frame, WireRequest, DEFAULT_MAX_FRAME_BYTES};
use hadacore::serve::{serve, Client, Reply, ServeConfig};
use hadacore::util::bench::{run_case, BenchConfig};
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if smoke { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: &[usize] = if smoke { &[256, 4096] } else { &[256, 4096, 14336] };

    println!("== wire codec ==");
    let mut rng = Rng::new(0xC0DEC);
    for &n in sizes {
        for dtype in [DType::F32, DType::F16] {
            let data = rng.normal_vec(4 * n);
            let frame = Frame::Request(WireRequest::from_f32(
                1,
                n,
                &data,
                KernelKind::HadaCore,
                dtype,
            ));
            let bytes = frame.encode();
            run_case(
                &format!("encode 4x{n} {}", dtype.name()),
                &cfg,
                |_| frame.encode(),
            );
            run_case(
                &format!("decode 4x{n} {}", dtype.name()),
                &cfg,
                |_| decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES).unwrap().unwrap(),
            );
        }
    }

    println!("\n== loopback serving ==");
    let coord = Arc::new(
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers: 2,
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let handle = serve(Arc::clone(&coord), ServeConfig::default()).unwrap();
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    for &n in sizes {
        let data = rng.normal_vec(n);
        run_case(&format!("rtt 1x{n} f32"), &cfg, |_| {
            client
                .transform(WireRequest::from_f32(0, n, &data, KernelKind::HadaCore, DType::F32))
                .unwrap()
        });
    }

    // pipelined throughput: a window of requests in flight at once
    // (kept under the server's pipeline_depth so nothing sheds)
    let window = if smoke { 8 } else { 16 };
    for &n in sizes {
        let data = rng.normal_vec(n);
        run_case(&format!("pipelined x{window} 1x{n} f32"), &cfg, |_| {
            let pending: Vec<_> = (0..window)
                .map(|_| {
                    client
                        .submit(WireRequest::from_f32(
                            0,
                            n,
                            &data,
                            KernelKind::HadaCore,
                            DType::F32,
                        ))
                        .unwrap()
                })
                .collect();
            let mut ok = 0;
            for p in pending {
                if matches!(p.wait(), Reply::Response(_)) {
                    ok += 1;
                }
            }
            assert_eq!(ok, window);
            ok
        });
    }

    drop(client);
    handle.shutdown();
    coord.drain();
    println!("\nserving metrics after bench:\n{}", coord.metrics().snapshot().report());
}
