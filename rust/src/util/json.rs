//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Handles the subset the project needs (artifact manifests, metrics dumps,
//! experiment records): objects, arrays, strings with escapes, numbers,
//! booleans, null. Not a general-purpose library — inputs are files this
//! repo itself generates — but the parser is strict enough to reject
//! malformed documents rather than guess.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(src: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialise with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (k, item) in items.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (k, (key, val)) in map.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // -------------------------------------------------------- accessors

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ------------------------------------------------------ constructors

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("fwht_256_float32")),
            ("rows", Json::num(32.0)),
            ("shapes", Json::Arr(vec![Json::num(32.0), Json::num(256.0)])),
            ("ok", Json::Bool(true)),
        ]);
        for s in [v.to_string(), v.to_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", r#"{"a" 1}"#, "tru", "1 2", "", "{]"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::num(32.0).to_string(), "32");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 256, "f": 1.5, "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(256));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.as_obj().is_some());
    }
}
