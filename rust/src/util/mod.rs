//! Std-only support utilities.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde, clap, criterion, proptest, half,
//! rand) are unavailable. Each submodule is a small, tested, purpose-built
//! replacement:
//!
//! * [`json`] — minimal JSON value model + parser + writer (manifest I/O).
//! * [`f16`] — IEEE binary16 and bfloat16 with correct round-to-nearest-even.
//! * [`rng`] — SplitMix64/xoshiro256++ deterministic PRNG.
//! * [`cli`] — tiny declarative flag parser for the binary and examples.
//! * [`bench`] — micro-benchmark timer (warmup, iterations, robust stats).
//! * [`prop`] — mini property-based test driver (random cases + replay seed).

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod prop;
pub mod rng;
