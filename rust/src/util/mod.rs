//! Std-only support utilities.
//!
//! The crate builds with **zero external dependencies** so the tier-1
//! verify (`cargo build --release && cargo test -q`) runs on any Rust
//! toolchain without network or vendored registries. The usual ecosystem
//! crates (serde, clap, criterion, proptest, half, rand, anyhow,
//! once_cell) are therefore replaced by small, tested, purpose-built
//! submodules:
//!
//! * [`json`] — minimal JSON value model + parser + writer (manifest I/O).
//! * [`f16`] — IEEE binary16 and bfloat16 with correct round-to-nearest-even.
//! * [`rng`] — SplitMix64/xoshiro256++ deterministic PRNG.
//! * [`cli`] — tiny declarative flag parser for the binary and examples.
//! * [`bench`] — micro-benchmark timer (warmup, iterations, robust stats).
//! * [`prop`] — mini property-based test driver (random cases + replay seed).
//! * [`error`] — message-carrying error type + context chaining (mini-anyhow).
//! * [`lazy`] — lazily-initialised statics over [`std::sync::OnceLock`].
//! * [`pool`] — size-classed f32 buffer pool with RAII return (the
//!   zero-copy serving path's payload storage).
//! * [`alloc`] — thread-aware counting global allocator (installed behind
//!   the `count-alloc` feature) proving the zero-alloc steady state.

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod error;
pub mod f16;
pub mod json;
pub mod lazy;
pub mod pool;
pub mod prop;
pub mod rng;
