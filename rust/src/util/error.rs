//! Minimal error type with context chaining (`anyhow` is unavailable
//! offline — see the module doc on [`crate::util`]).
//!
//! Provides the subset of the `anyhow` API this crate uses, with the same
//! names so call sites read identically:
//!
//! * [`Error`] — an opaque, message-carrying error value.
//! * [`Result<T>`] — `std::result::Result<T, Error>` with the error
//!   defaulted.
//! * [`Context`] — `.context(..)` / `.with_context(..)` adapters that
//!   prefix a message onto an underlying error.
//! * [`anyhow!`](crate::anyhow) / [`bail!`](crate::bail) — format-style
//!   constructors.
//!
//! Modules alias this as `use crate::util::error as anyhow;` so existing
//! `anyhow::Result<..>` signatures keep working unchanged.

use std::fmt;

/// An opaque error: a human-readable message, optionally chained onto the
/// message of a causing error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix `context` onto this error (outermost context first, matching
    /// `anyhow`'s display layout).
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` interop: any std error converts into `Error`. (`Error` itself
// deliberately does not implement `std::error::Error`, exactly like
// `anyhow::Error`, so this blanket impl cannot overlap the reflexive
// `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with the crate error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context adapters for `Result`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

// Re-export the crate-root macros so `use crate::util::error as anyhow;`
// makes `anyhow::anyhow!` / `anyhow::bail!` resolve.
pub use crate::{anyhow, bail};

/// Construct an [`Error`](crate::util::error::Error) from a format string
/// or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`](crate::util::error::Error) built as by
/// [`anyhow!`](crate::anyhow).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 7;
        let b = anyhow!("n={n} and {}", 8);
        assert_eq!(b.to_string(), "n=7 and 8");
        let c = anyhow!(io_err());
        assert_eq!(c.to_string(), "gone");
        let captured = anyhow!("value {n}");
        assert_eq!(captured.to_string(), "value 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_prefixes_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: gone");
    }

    #[test]
    fn bail_returns_early() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 2);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "failed with code 2");
    }

    #[test]
    fn debug_matches_display() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e:?}"), "boom");
    }
}
