//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Used by workload generators, property tests and the synthetic corpus.
//! Deterministic by construction so every experiment in EXPERIMENTS.md is
//! replayable from its seed.

/// xoshiro256++ generator (public-domain reference algorithm by
/// Blackman & Vigna), seeded via SplitMix64 so any u64 seed is safe.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for bound << 2^64 and this is not cryptographic.
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with standard-normal f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Vector of standard-normal f32 values.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.fill_normal(&mut v);
        v
    }

    /// Heavy-tailed sample: normal with probability `1-p_outlier`, scaled
    /// normal (x `outlier_scale`) otherwise. Models the activation-outlier
    /// distributions that motivate Hadamard rotations (QuaRot/SpinQuant).
    pub fn outlier_normal(&mut self, p_outlier: f64, outlier_scale: f64) -> f32 {
        let base = self.normal();
        if self.f64() < p_outlier {
            (base * outlier_scale) as f32
        } else {
            base as f32
        }
    }

    /// True with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork an independent stream (for per-worker RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn outlier_normal_has_heavier_tail() {
        let mut r = Rng::new(15);
        let big = (0..20_000)
            .map(|_| r.outlier_normal(0.05, 20.0))
            .filter(|x| x.abs() > 10.0)
            .count();
        assert!(big > 100, "outliers: {big}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(21);
        let mut b = a.fork();
        let matches = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(matches < 2);
    }
}
