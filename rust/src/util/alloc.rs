//! Thread-aware allocation counting for the zero-alloc serving gate.
//!
//! [`CountingAlloc`] is a [`GlobalAlloc`] that delegates every call to
//! [`System`] and, for **tracked threads only**, bumps process-wide
//! counters on each allocation. The serving stack marks its own threads
//! (acceptor, per-connection reader/writer, coordinator workers, exec
//! pool workers) as tracked at spawn; load-generator client threads stay
//! untracked, so a self-hosted `loadgen` run measures exactly the
//! server-side request path and nothing else.
//!
//! The allocator is only *installed* when the `count-alloc` cargo
//! feature is enabled (a `#[global_allocator]` item in the binary and in
//! the zero-alloc integration test). Everything here is still compiled
//! and callable without the feature — [`track_current_thread`] is then a
//! cheap no-op flag write and [`is_counting`] reports `false`, so the
//! serving layer calls it unconditionally.
//!
//! Implementation notes for correctness inside `GlobalAlloc`:
//! * the per-thread tracked flag is a **const-initialised**
//!   `thread_local!` `Cell<bool>` — no lazy initialisation (which could
//!   allocate) and no destructor (so no TLS re-entrancy at thread exit);
//!   reads go through `try_with`, which returns an error instead of
//!   panicking during thread teardown.
//! * counters are relaxed atomics; callers snapshot before/after a
//!   measured window ([`tracked`]) and look at the delta, so no
//!   ordering edge beyond the caller's own synchronisation is needed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Allocation calls observed on tracked threads.
static TRACKED_ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Bytes requested by those calls.
static TRACKED_BYTES: AtomicU64 = AtomicU64::new(0);
/// Whether a [`CountingAlloc`] is installed as the global allocator
/// (set by [`mark_installed`] from the registration site).
static INSTALLED: AtomicBool = AtomicBool::new(false);

thread_local! {
    // Const-init: safe to read from inside the allocator (doc above).
    static TRACK_THIS_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Counting global allocator; see the module doc. Install with
/// `#[global_allocator]` behind the `count-alloc` feature and call
/// [`mark_installed`] once at startup.
pub struct CountingAlloc;

#[inline]
fn record(bytes: usize) {
    let tracked = TRACK_THIS_THREAD.try_with(Cell::get).unwrap_or(false);
    if tracked {
        TRACKED_ALLOCS.fetch_add(1, Ordering::Relaxed);
        TRACKED_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

// SAFETY: pure delegation to `System`; the only additions are atomic
// counter bumps and a const-init TLS read, neither of which allocates
// or re-enters the allocator.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        // a grow is the allocation the serving path must not perform
        record(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

/// Record that a [`CountingAlloc`] is the process's global allocator.
/// Called once from the `count-alloc`-gated registration site; without
/// it, [`is_counting`] stays `false` and zero-alloc assertions know the
/// measurement is inactive rather than vacuously passing.
pub fn mark_installed() {
    INSTALLED.store(true, Ordering::Relaxed);
}

/// Whether allocation counting is live (allocator installed).
pub fn is_counting() -> bool {
    INSTALLED.load(Ordering::Relaxed)
}

/// Mark (or unmark) the current thread as tracked. Serving threads call
/// this at spawn, unconditionally — without the `count-alloc` feature it
/// is a no-op flag write.
pub fn track_current_thread(enable: bool) {
    let _ = TRACK_THIS_THREAD.try_with(|c| c.set(enable));
}

/// Whether the current thread is tracked (test hook).
pub fn current_thread_tracked() -> bool {
    TRACK_THIS_THREAD.try_with(Cell::get).unwrap_or(false)
}

/// Point-in-time totals of tracked-thread allocation activity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocation calls (alloc / alloc_zeroed / realloc-grow).
    pub allocs: u64,
    /// Bytes requested by those calls.
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Counters accrued since `earlier` (saturating).
    pub fn since(&self, earlier: AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs.saturating_sub(earlier.allocs),
            bytes: self.bytes.saturating_sub(earlier.bytes),
        }
    }
}

/// Snapshot the tracked-thread counters.
pub fn tracked() -> AllocSnapshot {
    AllocSnapshot {
        allocs: TRACKED_ALLOCS.load(Ordering::Relaxed),
        bytes: TRACKED_BYTES.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracking_flag_is_per_thread() {
        track_current_thread(true);
        assert!(current_thread_tracked());
        let other = std::thread::spawn(current_thread_tracked)
            .join()
            .unwrap();
        assert!(!other, "a new thread must start untracked");
        track_current_thread(false);
        assert!(!current_thread_tracked());
    }

    #[test]
    fn snapshot_delta_is_saturating_and_monotone() {
        let a = tracked();
        let b = tracked();
        let d = b.since(a);
        // counters only move when the allocator is installed AND the
        // thread is tracked; either way the delta is well-formed
        assert!(d.allocs <= b.allocs);
        assert_eq!(a.since(b).allocs, 0, "reverse delta saturates to zero");
    }

    #[test]
    fn counting_inactive_without_registration() {
        // this test binary does not register the allocator; the flag
        // must reflect that so zero-alloc asserts can refuse to pass
        // vacuously (the count-alloc loadgen run calls mark_installed)
        assert!(!is_counting());
    }
}
