//! Mini property-based testing driver (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it reports the case seed so the exact input can be replayed by
//! seeding a [`crate::util::rng::Rng`]. The environment variable
//! `PROP_CASES` scales the case count (e.g. in a longer CI run).

use crate::util::rng::Rng;

/// Number of cases to run, honouring the `PROP_CASES` override.
pub fn case_count(default_cases: usize) -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
///
/// The property receives a fresh deterministic RNG per case. Any panic
/// inside the property is attributed to the case seed for replay.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng)) {
    let cases = case_count(cases);
    let mut meta = Rng::new(0xC0FFEE ^ name.len() as u64);
    for case in 0..cases {
        let seed = meta.next_u64() ^ case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case}/{cases} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_close(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "index {i}: got {g}, want {w} (|diff| {} > tol {tol})",
            (g - w).abs()
        );
    }
}

/// Draw a random transform size from the full supported `B * 2^k`
/// family: any base in {1, 12, 20, 28, 40} with `2^k` up to
/// `2^max_pow2` (bases > 1 require k ≥ 2 only when reached via the odd
/// part — the family itself admits any k ≥ 0 for the canonical bases,
/// mirroring [`crate::hadamard::matrices::split_base`]). The driver for
/// differential fuzzing across every kernel path.
pub fn random_supported_size(rng: &mut Rng, max_pow2: u32) -> usize {
    let base = [1usize, 12, 20, 28, 40][rng.below(5)];
    let k = rng.range(0, max_pow2 as usize) as u32;
    let n = base << k;
    debug_assert!(
        crate::hadamard::matrices::is_supported_size(n),
        "generated unsupported size {n}"
    );
    n
}

/// Integer-valued f32 payload in `[-amp, amp]`. With the raw (scale = 1)
/// transform every kernel's arithmetic is exact as long as
/// `n * amp < 2^24`, so cross-kernel comparisons can assert **bit
/// equality**, not tolerances — the strongest differential oracle.
pub fn integer_vec(rng: &mut Rng, len: usize, amp: usize) -> Vec<f32> {
    (0..len)
        .map(|_| rng.below(2 * amp + 1) as f32 - amp as f32)
        .collect()
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Relative L2 error `||a-b|| / ||b||`.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 25, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_reports_seed_on_failure() {
        check("failing", 5, |_| panic!("boom"));
    }

    #[test]
    fn close_helpers() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-5);
        assert!(max_abs_diff(&[1.0, 5.0], &[1.5, 5.0]) == 0.5);
        assert!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert!(rel_l2(&[2.0], &[1.0]) > 0.9);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn close_rejects_length_mismatch() {
        assert_close(&[1.0], &[1.0, 2.0], 0.1, 0.1);
    }

    #[test]
    fn random_sizes_are_always_supported_and_cover_bases() {
        let mut rng = Rng::new(0x51);
        let mut bases = std::collections::HashSet::new();
        for _ in 0..300 {
            let n = random_supported_size(&mut rng, 6);
            assert!(crate::hadamard::matrices::is_supported_size(n), "n={n}");
            assert!(n <= 40 << 6);
            bases.insert(crate::hadamard::matrices::split_base(n).unwrap().0);
        }
        assert!(bases.len() >= 4, "all canonical bases should appear: {bases:?}");
    }

    #[test]
    fn integer_vec_is_integral_and_bounded() {
        let mut rng = Rng::new(0x52);
        let v = integer_vec(&mut rng, 1000, 4);
        for x in &v {
            assert_eq!(*x, x.round());
            assert!(x.abs() <= 4.0);
        }
        assert!(v.iter().any(|x| *x != v[0]), "degenerate stream");
    }
}
