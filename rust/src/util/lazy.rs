//! Lazily-initialised statics (`once_cell` is unavailable offline).
//!
//! [`Lazy`] is the subset of `once_cell::sync::Lazy` this crate needs:
//! a `static`-compatible cell holding a value built on first dereference
//! by a plain function pointer (every use site passes a non-capturing
//! closure, which coerces). Built on [`std::sync::OnceLock`], so
//! initialisation is thread-safe and happens exactly once.

use std::ops::Deref;
use std::sync::OnceLock;

/// A value initialised on first access.
pub struct Lazy<T> {
    cell: OnceLock<T>,
    init: fn() -> T,
}

impl<T> Lazy<T> {
    /// Create an empty cell that will run `init` on first dereference.
    pub const fn new(init: fn() -> T) -> Lazy<T> {
        Lazy { cell: OnceLock::new(), init }
    }

    /// Force initialisation and return the value.
    pub fn force(&self) -> &T {
        self.cell.get_or_init(self.init)
    }
}

impl<T> Deref for Lazy<T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.force()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static CALLS: AtomicUsize = AtomicUsize::new(0);
    static CELL: Lazy<u64> = Lazy::new(|| {
        CALLS.fetch_add(1, Ordering::SeqCst);
        42
    });

    #[test]
    fn initialises_once_across_threads() {
        let got: Vec<u64> = std::thread::scope(|s| {
            (0..4)
                .map(|_| s.spawn(|| *CELL))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        assert!(got.iter().all(|&v| v == 42));
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        assert_eq!(*CELL.force(), 42);
    }

    #[test]
    fn deref_through_reference() {
        static ARR: Lazy<[f32; 3]> = Lazy::new(|| [1.0, 2.0, 3.0]);
        let r: &[f32; 3] = &ARR;
        assert_eq!(r[1], 2.0);
        assert_eq!(ARR.len(), 3);
    }
}
