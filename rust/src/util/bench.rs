//! Micro-benchmark timer: warmup, calibrated iteration counts, robust stats.
//!
//! Criterion is unavailable offline; this provides the subset the paper's
//! evaluation needs — median / mean / MAD over repeated timed batches with
//! black-box protection — and a stable text report format that the bench
//! binaries (`cargo bench`, `harness = false`) print.
//!
//! [`BenchJson`] adds the machine-readable side: bench binaries collect
//! one [`BenchRecord`] per measured case and emit a `BENCH_PR4.json`
//! document (schema `hadacore-bench-v1`), giving the repo a perf
//! trajectory that CI can archive and diff across commits instead of
//! scraping stdout. `HADACORE_BENCH_JSON` overrides the output path.
//!
//! [`TablesJson`] is the accuracy-side twin: the quantised-pipeline
//! study (`examples/accuracy_study.rs`) collects one [`TableRecord`]
//! per (kernel × dtype × scheme × size × rotation) cell and emits a
//! `TABLES_PR6.json` document (schema `hadacore-tables-v1`) that CI
//! validates and archives. `HADACORE_TABLES_JSON` overrides the path.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Result statistics for one benchmark case (all values in nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    /// Build statistics from observed per-event latencies in µs (sorted
    /// ascending) — the open-loop serving path, where each "iteration"
    /// is one request rather than a repeated closed-loop call.
    pub fn from_sorted_us(name: &str, sorted_us: &[f64]) -> Stats {
        let ns: Vec<f64> = sorted_us.iter().map(|us| us * 1e3).collect();
        let median = percentile(&ns, 50.0);
        let mut devs: Vec<f64> = ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            name: name.to_string(),
            iters: ns.len() as u64,
            samples: ns.len(),
            mean_ns: if ns.is_empty() {
                f64::NAN
            } else {
                ns.iter().sum::<f64>() / ns.len() as f64
            },
            median_ns: median,
            min_ns: ns.first().copied().unwrap_or(f64::NAN),
            mad_ns: percentile(&devs, 50.0),
        }
    }

    /// Median in microseconds (the unit the paper reports).
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }

    /// Human-readable single-line summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.3} µs/iter  (min {:>10.3}, mad {:>8.3}, {} iters x {} samples)",
            self.name,
            self.median_ns / 1e3,
            self.min_ns / 1e3,
            self.mad_ns / 1e3,
            self.iters,
            self.samples,
        )
    }
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Target wall time per sample batch.
    pub sample_time: Duration,
    /// Number of sample batches.
    pub samples: usize,
    /// Warmup time before calibration.
    pub warmup: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            sample_time: Duration::from_millis(40),
            samples: 12,
            warmup: Duration::from_millis(60),
        }
    }
}

impl BenchConfig {
    /// A faster configuration for CI-style smoke benches.
    pub fn quick() -> Self {
        BenchConfig {
            sample_time: Duration::from_millis(10),
            samples: 6,
            warmup: Duration::from_millis(15),
        }
    }
}

/// Time `f` repeatedly and return robust statistics.
///
/// `f` receives the iteration index; its return value is black-boxed so the
/// optimiser cannot elide the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut(u64) -> T) -> Stats {
    // Warmup + calibration: find iters such that one batch ~ sample_time.
    let warm_start = Instant::now();
    let mut calib_iters = 0u64;
    while warm_start.elapsed() < cfg.warmup {
        black_box(f(calib_iters));
        calib_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
    let iters = ((cfg.sample_time.as_secs_f64() / per_iter.max(1e-9)) as u64).clamp(1, 1 << 24);

    let mut batch_ns: Vec<f64> = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for i in 0..iters {
            black_box(f(i));
        }
        batch_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    batch_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile(&batch_ns, 50.0);
    let min = batch_ns[0];
    let mean = batch_ns.iter().sum::<f64>() / batch_ns.len() as f64;
    let mut devs: Vec<f64> = batch_ns.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = percentile(&devs, 50.0);

    Stats {
        name: name.to_string(),
        iters,
        samples: cfg.samples,
        mean_ns: mean,
        median_ns: median,
        min_ns: min,
        mad_ns: mad,
    }
}

/// Percentile (0..=100) of a sorted slice via linear interpolation.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Convenience: run + print one benchmark case.
pub fn run_case<T>(name: &str, cfg: &BenchConfig, f: impl FnMut(u64) -> T) -> Stats {
    let s = bench(name, cfg, f);
    println!("{}", s.line());
    s
}

// ---------------------------------------------------------------------
// Machine-readable bench output (BENCH_PR4.json).

/// Schema identifier written into every emitted document; bump on any
/// incompatible field change.
pub const BENCH_SCHEMA: &str = "hadacore-bench-v1";

/// Per-entry fields every consumer may rely on (also what
/// [`validate_bench_json`] checks).
pub const REQUIRED_ENTRY_KEYS: [&str; 8] = [
    "bench",
    "kernel",
    "n",
    "rows",
    "dtype",
    "fusion_depth",
    "median_ns",
    "melems_per_s",
];

/// One measured configuration: a [`Stats`] plus the workload coordinates
/// (size × kernel × fusion depth × dtype) the perf trajectory indexes by.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Section of the bench binary (e.g. `"fusion_sweep"`).
    pub bench: String,
    /// Kernel name (`scalar` | `dao` | `hadacore`).
    pub kernel: String,
    /// Transform size.
    pub n: usize,
    /// Rows per batch.
    pub rows: usize,
    /// Storage dtype name (`float32` | `float16` | `bfloat16`).
    pub dtype: String,
    /// Round-fusion depth the case executed with (1 = unfused).
    pub fusion_depth: usize,
    /// Engine lanes used by the case (0 = direct kernel call).
    pub threads: usize,
    /// Robust timing statistics of one iteration.
    pub stats: Stats,
    /// Throughput in mega-elements per second (`rows * n / median` for
    /// closed-loop micro-benches; measured end-to-end for serving runs).
    pub melems_per_s: f64,
    /// Additional named measurements (serving runs attach QPS and
    /// latency percentiles here); appended verbatim to the JSON entry.
    pub extras: Vec<(String, f64)>,
}

impl BenchRecord {
    /// Build a record from a measured [`Stats`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        bench: &str,
        kernel: &str,
        n: usize,
        rows: usize,
        dtype: &str,
        fusion_depth: usize,
        threads: usize,
        stats: Stats,
    ) -> BenchRecord {
        let elems = (rows * n) as f64;
        let melems_per_s = elems / stats.median_ns.max(1e-9) * 1e3;
        BenchRecord {
            bench: bench.to_string(),
            kernel: kernel.to_string(),
            n,
            rows,
            dtype: dtype.to_string(),
            fusion_depth,
            threads,
            stats,
            melems_per_s,
            extras: Vec::new(),
        }
    }

    /// Build a record for an open-loop *serving* measurement, where
    /// throughput is measured end-to-end (not derived from the median)
    /// and the latency statistics come from observed per-request
    /// latencies rather than repeated closed-loop iterations. `n`/`rows`
    /// describe the traffic mix's shape envelope; fusion depth is
    /// whatever the engine's autotuner picked (recorded as 1 = "not a
    /// kernel sweep axis" so trajectory consumers can filter on bench
    /// name instead of a sentinel).
    #[allow(clippy::too_many_arguments)]
    pub fn serving(
        bench: &str,
        kernel: &str,
        n: usize,
        rows: usize,
        dtype: &str,
        threads: usize,
        stats: Stats,
        melems_per_s: f64,
    ) -> BenchRecord {
        BenchRecord {
            bench: bench.to_string(),
            kernel: kernel.to_string(),
            n,
            rows,
            dtype: dtype.to_string(),
            fusion_depth: 1,
            threads,
            stats,
            melems_per_s,
            extras: Vec::new(),
        }
    }

    /// Attach a named extra measurement (builder-style).
    pub fn with_extra(mut self, key: &str, value: f64) -> BenchRecord {
        self.extras.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bench", Json::str(self.bench.clone())),
            ("kernel", Json::str(self.kernel.clone())),
            ("n", Json::num(self.n as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("dtype", Json::str(self.dtype.clone())),
            ("fusion_depth", Json::num(self.fusion_depth as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("median_ns", Json::num(self.stats.median_ns)),
            ("min_ns", Json::num(self.stats.min_ns)),
            ("mad_ns", Json::num(self.stats.mad_ns)),
            ("iters", Json::num(self.stats.iters as f64)),
            ("samples", Json::num(self.stats.samples as f64)),
            ("melems_per_s", Json::num(self.melems_per_s)),
        ];
        for (k, v) in &self.extras {
            fields.push((k.as_str(), Json::num(*v)));
        }
        Json::obj(fields)
    }
}

/// Collector for a bench binary's machine-readable output.
#[derive(Default)]
pub struct BenchJson {
    records: Vec<BenchRecord>,
}

impl BenchJson {
    /// Empty collector.
    pub fn new() -> BenchJson {
        BenchJson::default()
    }

    /// Add one measured case.
    pub fn push(&mut self, record: BenchRecord) {
        self.records.push(record);
    }

    /// Records collected so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The emitted document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("entries", Json::Arr(self.records.iter().map(BenchRecord::to_json).collect())),
        ])
    }

    /// Resolve the output path: `HADACORE_BENCH_JSON` env override, else
    /// `default_path` (bench binaries pass `"BENCH_PR4.json"`, which
    /// lands in the cargo working directory — `rust/`).
    pub fn output_path(default_path: &str) -> String {
        std::env::var("HADACORE_BENCH_JSON").unwrap_or_else(|_| default_path.to_string())
    }

    /// Write the document (pretty-printed) and re-validate it from disk,
    /// so a bench run can never leave a malformed trajectory file behind.
    /// Returns the entry count on success.
    pub fn write(&self, path: &str) -> Result<usize, String> {
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| format!("write {path}: {e}"))?;
        validate_bench_json(path)
    }
}

/// Validate an emitted bench JSON file: parses, checks the schema tag,
/// requires a non-empty `entries` array, and checks every entry carries
/// the [`REQUIRED_ENTRY_KEYS`] with the right types and positive
/// throughput. Returns the entry count. Used by the bench binaries after
/// writing and by the CI smoke step.
pub fn validate_bench_json(path: &str) -> Result<usize, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some(BENCH_SCHEMA) {
        return Err(format!("{path}: missing or unknown schema tag"));
    }
    let entries = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: entries must be an array"))?;
    if entries.is_empty() {
        return Err(format!("{path}: entries array is empty"));
    }
    for (i, e) in entries.iter().enumerate() {
        for key in REQUIRED_ENTRY_KEYS {
            let v = e
                .get(key)
                .ok_or_else(|| format!("{path}: entry {i} missing '{key}'"))?;
            let ok = match key {
                "bench" | "kernel" | "dtype" => v.as_str().is_some(),
                "n" | "rows" | "fusion_depth" => {
                    v.as_usize().is_some_and(|u| u >= 1)
                }
                _ => v.as_f64().is_some_and(|f| f > 0.0),
            };
            if !ok {
                return Err(format!("{path}: entry {i} has invalid '{key}'"));
            }
        }
    }
    Ok(entries.len())
}

// ---------------------------------------------------------------------
// Machine-readable accuracy-table output (TABLES_PR6.json).

/// Schema identifier for accuracy-table documents; bump on any
/// incompatible field change.
pub const TABLES_SCHEMA: &str = "hadacore-tables-v1";

/// Per-entry fields every consumer of an accuracy table may rely on
/// (also what [`validate_tables_json`] checks).
pub const REQUIRED_TABLE_KEYS: [&str; 10] = [
    "study",
    "kernel",
    "n",
    "rows",
    "dtype",
    "scheme",
    "rotated",
    "layers",
    "snr_db",
    "rel_to_amax",
];

/// One cell of the quantised-pipeline accuracy study: the error of a
/// rotate→quantize→matmul→dequantize→unrotate pipeline against its
/// exact (unquantised) twin, indexed by the workload coordinates the
/// accuracy trajectory sweeps.
#[derive(Clone, Debug)]
pub struct TableRecord {
    /// Study section (e.g. `"quant_pipeline"`).
    pub study: String,
    /// Kernel name (`scalar` | `dao` | `hadacore`).
    pub kernel: String,
    /// Transform size.
    pub n: usize,
    /// Rows (activation vectors) per measured batch.
    pub rows: usize,
    /// Storage dtype name (`float32` | `float16` | `bfloat16`).
    pub dtype: String,
    /// Quantisation scheme name (`fp8_e4m3` | `fp8_e5m2` | `int8` | …).
    pub scheme: String,
    /// Whether the pipeline wrapped quantisation in a randomized
    /// Hadamard rotation (the with/without axis of the paper's tables).
    pub rotated: bool,
    /// Pipeline depth (number of rotate→quantize→matmul layers).
    pub layers: usize,
    /// Signal-to-quantisation-noise ratio of the pipeline output in dB.
    pub snr_db: f64,
    /// Max elementwise error relative to amax (PAPER.md §4.1 metric).
    pub rel_to_amax: f64,
    /// Additional named measurements (incoherence, per-layer SNR, …);
    /// appended verbatim to the JSON entry.
    pub extras: Vec<(String, f64)>,
}

impl TableRecord {
    /// Build a record from the measured error metrics.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        study: &str,
        kernel: &str,
        n: usize,
        rows: usize,
        dtype: &str,
        scheme: &str,
        rotated: bool,
        layers: usize,
        snr_db: f64,
        rel_to_amax: f64,
    ) -> TableRecord {
        TableRecord {
            study: study.to_string(),
            kernel: kernel.to_string(),
            n,
            rows,
            dtype: dtype.to_string(),
            scheme: scheme.to_string(),
            rotated,
            layers,
            snr_db,
            rel_to_amax,
            extras: Vec::new(),
        }
    }

    /// Attach a named extra measurement (builder-style).
    pub fn with_extra(mut self, key: &str, value: f64) -> TableRecord {
        self.extras.push((key.to_string(), value));
        self
    }

    /// Human-readable single-line summary (the stdout table row).
    pub fn line(&self) -> String {
        format!(
            "{:<16} {:<9} n={:<6} {:<9} {:<9} rot={:<5} L={} {:>9.2} dB  rel_amax {:.3e}",
            self.study,
            self.kernel,
            self.n,
            self.dtype,
            self.scheme,
            self.rotated,
            self.layers,
            self.snr_db,
            self.rel_to_amax,
        )
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("study", Json::str(self.study.clone())),
            ("kernel", Json::str(self.kernel.clone())),
            ("n", Json::num(self.n as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("dtype", Json::str(self.dtype.clone())),
            ("scheme", Json::str(self.scheme.clone())),
            ("rotated", Json::Bool(self.rotated)),
            ("layers", Json::num(self.layers as f64)),
            ("snr_db", Json::num(self.snr_db)),
            ("rel_to_amax", Json::num(self.rel_to_amax)),
        ];
        for (k, v) in &self.extras {
            fields.push((k.as_str(), Json::num(*v)));
        }
        Json::obj(fields)
    }
}

/// Collector for an accuracy study's machine-readable output.
#[derive(Default)]
pub struct TablesJson {
    records: Vec<TableRecord>,
}

impl TablesJson {
    /// Empty collector.
    pub fn new() -> TablesJson {
        TablesJson::default()
    }

    /// Add one measured cell.
    pub fn push(&mut self, record: TableRecord) {
        self.records.push(record);
    }

    /// Records collected so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The emitted document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(TABLES_SCHEMA)),
            ("entries", Json::Arr(self.records.iter().map(TableRecord::to_json).collect())),
        ])
    }

    /// Resolve the output path: `HADACORE_TABLES_JSON` env override, else
    /// `default_path` (the study passes `"TABLES_PR6.json"`, which lands
    /// in the cargo working directory — `rust/`).
    pub fn output_path(default_path: &str) -> String {
        std::env::var("HADACORE_TABLES_JSON").unwrap_or_else(|_| default_path.to_string())
    }

    /// Write the document (pretty-printed) and re-validate it from disk,
    /// so a study run can never leave a malformed table file behind.
    /// Returns the entry count on success.
    pub fn write(&self, path: &str) -> Result<usize, String> {
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| format!("write {path}: {e}"))?;
        validate_tables_json(path)
    }
}

/// Validate an emitted accuracy-table JSON file: parses, checks the
/// schema tag, requires a non-empty `entries` array, and checks every
/// entry carries the [`REQUIRED_TABLE_KEYS`] with the right types —
/// `rotated` a bool, sizes ≥ 1, `snr_db` finite, `rel_to_amax` finite
/// and non-negative. Additionally requires that the document covers both
/// sides of the rotation axis (at least one rotated and one unrotated
/// entry), since a table missing either side cannot support the paper's
/// with/without comparison. Returns the entry count. Used by the study
/// binary after writing and by the CI `accuracy-tables` step.
pub fn validate_tables_json(path: &str) -> Result<usize, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some(TABLES_SCHEMA) {
        return Err(format!("{path}: missing or unknown schema tag"));
    }
    let entries = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: entries must be an array"))?;
    if entries.is_empty() {
        return Err(format!("{path}: entries array is empty"));
    }
    let (mut saw_rotated, mut saw_plain) = (false, false);
    for (i, e) in entries.iter().enumerate() {
        for key in REQUIRED_TABLE_KEYS {
            let v = e
                .get(key)
                .ok_or_else(|| format!("{path}: entry {i} missing '{key}'"))?;
            let ok = match key {
                "study" | "kernel" | "dtype" | "scheme" => v.as_str().is_some(),
                "n" | "rows" | "layers" => v.as_usize().is_some_and(|u| u >= 1),
                "rotated" => v.as_bool().is_some(),
                "snr_db" => v.as_f64().is_some_and(f64::is_finite),
                _ => v.as_f64().is_some_and(|f| f.is_finite() && f >= 0.0),
            };
            if !ok {
                return Err(format!("{path}: entry {i} has invalid '{key}'"));
            }
        }
        match e.get("rotated").and_then(Json::as_bool) {
            Some(true) => saw_rotated = true,
            Some(false) => saw_plain = true,
            None => unreachable!("checked above"),
        }
    }
    if !(saw_rotated && saw_plain) {
        return Err(format!(
            "{path}: table must cover both rotated and unrotated entries"
        ));
    }
    Ok(entries.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_measures_something_positive() {
        let cfg = BenchConfig {
            sample_time: Duration::from_millis(2),
            samples: 3,
            warmup: Duration::from_millis(2),
        };
        let s = bench("spin", &cfg, |i| {
            let mut acc = i;
            for k in 0..100 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.iters >= 1);
    }

    fn stats_fixture(median_ns: f64) -> Stats {
        Stats {
            name: "case".into(),
            iters: 100,
            samples: 6,
            mean_ns: median_ns * 1.1,
            median_ns,
            min_ns: median_ns * 0.9,
            mad_ns: median_ns * 0.05,
        }
    }

    #[test]
    fn bench_json_roundtrips_and_validates() {
        let mut out = BenchJson::new();
        out.push(BenchRecord::new(
            "fusion_sweep",
            "hadacore",
            4096,
            512,
            "float32",
            2,
            8,
            stats_fixture(1_000_000.0),
        ));
        out.push(BenchRecord::new(
            "fusion_sweep",
            "dao",
            256,
            8192,
            "float16",
            1,
            0,
            stats_fixture(2_000_000.0),
        ));
        assert_eq!(out.len(), 2);
        let path = std::env::temp_dir()
            .join(format!("hc_bench_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        assert_eq!(out.write(&path).unwrap(), 2);
        assert_eq!(validate_bench_json(&path).unwrap(), 2);

        // throughput math: rows*n elems over the median
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        let mps = entries[0].get("melems_per_s").unwrap().as_f64().unwrap();
        assert!((mps - (512.0 * 4096.0) / 1e6 * 1e3).abs() < 1e-6, "{mps}");
        assert_eq!(
            entries[0].get("fusion_depth").unwrap().as_usize(),
            Some(2)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serving_records_carry_extras_and_validate() {
        let lat_us = [100.0, 150.0, 200.0, 400.0, 900.0];
        let stats = Stats::from_sorted_us("loadgen:mixed", &lat_us);
        assert_eq!(stats.iters, 5);
        assert!((stats.median_ns - 200_000.0).abs() < 1e-6);
        assert!((stats.min_ns - 100_000.0).abs() < 1e-6);
        let rec = BenchRecord::serving(
            "loadgen", "hadacore", 14336, 8, "float32", 4, stats, 123.4,
        )
        .with_extra("qps_offered", 500.0)
        .with_extra("qps_achieved", 480.5)
        .with_extra("p99_us", 900.0)
        .with_extra("busy", 3.0);
        assert!((rec.melems_per_s - 123.4).abs() < 1e-9);

        let mut out = BenchJson::new();
        out.push(rec);
        let path = std::env::temp_dir()
            .join(format!("hc_servebench_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        assert_eq!(out.write(&path).unwrap(), 1);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let e = &doc.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("qps_achieved").unwrap().as_f64(), Some(480.5));
        assert_eq!(e.get("p99_us").unwrap().as_f64(), Some(900.0));
        assert_eq!(e.get("fusion_depth").unwrap().as_usize(), Some(1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_json_validation_rejects_malformed_documents() {
        let dir = std::env::temp_dir();
        let cases = [
            ("empty", "{}".to_string()),
            (
                "bad_schema",
                r#"{"schema": "nope", "entries": []}"#.to_string(),
            ),
            (
                "no_entries",
                format!(r#"{{"schema": "{BENCH_SCHEMA}", "entries": []}}"#),
            ),
            (
                "missing_key",
                format!(
                    r#"{{"schema": "{BENCH_SCHEMA}", "entries": [{{"bench": "x"}}]}}"#
                ),
            ),
            (
                "zero_throughput",
                format!(
                    r#"{{"schema": "{BENCH_SCHEMA}", "entries": [{{
                        "bench": "x", "kernel": "dao", "n": 256, "rows": 1,
                        "dtype": "float32", "fusion_depth": 1,
                        "median_ns": 1.0, "melems_per_s": 0}}]}}"#
                ),
            ),
        ];
        for (name, text) in cases {
            let path = dir
                .join(format!("hc_badbench_{}_{name}.json", std::process::id()))
                .to_string_lossy()
                .into_owned();
            std::fs::write(&path, text).unwrap();
            assert!(validate_bench_json(&path).is_err(), "{name} must fail");
            std::fs::remove_file(&path).ok();
        }
        // writing an empty collector must also fail loudly
        let path = dir
            .join(format!("hc_emptybench_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        assert!(BenchJson::new().write(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_json_env_override_controls_the_path() {
        // read-only check of the resolution rule (no env mutation: tests
        // share the process)
        assert_eq!(
            BenchJson::output_path("BENCH_PR4.json"),
            std::env::var("HADACORE_BENCH_JSON")
                .unwrap_or_else(|_| "BENCH_PR4.json".to_string())
        );
    }

    fn table_fixture(rotated: bool, snr_db: f64) -> TableRecord {
        TableRecord::new(
            "quant_pipeline",
            "hadacore",
            4096,
            8,
            "float32",
            "fp8_e4m3",
            rotated,
            3,
            snr_db,
            0.015,
        )
    }

    #[test]
    fn tables_json_roundtrips_and_validates() {
        let mut out = TablesJson::new();
        out.push(table_fixture(false, 21.5).with_extra("incoherence", 14.2));
        out.push(table_fixture(true, 29.75));
        assert_eq!(out.len(), 2);
        let path = std::env::temp_dir()
            .join(format!("hc_tables_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        assert_eq!(out.write(&path).unwrap(), 2);
        assert_eq!(validate_tables_json(&path).unwrap(), 2);

        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(TABLES_SCHEMA));
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("rotated").unwrap().as_bool(), Some(false));
        assert_eq!(entries[1].get("rotated").unwrap().as_bool(), Some(true));
        assert_eq!(entries[0].get("incoherence").unwrap().as_f64(), Some(14.2));
        assert_eq!(entries[1].get("snr_db").unwrap().as_f64(), Some(29.75));
        std::fs::remove_file(&path).ok();

        // negative SNR is a legal (terrible) measurement — only
        // non-finite values are rejected
        let mut neg = TablesJson::new();
        neg.push(table_fixture(true, -3.0));
        neg.push(table_fixture(false, -5.0));
        assert_eq!(neg.write(&path).unwrap(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tables_json_validation_rejects_malformed_documents() {
        let dir = std::env::temp_dir();
        let entry_ok = r#""study": "s", "kernel": "dao", "n": 256, "rows": 1,
            "dtype": "float32", "scheme": "int8", "layers": 1,
            "snr_db": 20.0, "rel_to_amax": 0.01"#;
        let cases = [
            ("empty", "{}".to_string()),
            ("bad_schema", r#"{"schema": "nope", "entries": []}"#.to_string()),
            (
                "no_entries",
                format!(r#"{{"schema": "{TABLES_SCHEMA}", "entries": []}}"#),
            ),
            (
                "missing_rotated",
                format!(r#"{{"schema": "{TABLES_SCHEMA}", "entries": [{{{entry_ok}}}]}}"#),
            ),
            (
                "rotated_not_bool",
                format!(
                    r#"{{"schema": "{TABLES_SCHEMA}", "entries": [{{{entry_ok}, "rotated": 1}}]}}"#
                ),
            ),
            (
                "negative_rel_amax",
                format!(
                    r#"{{"schema": "{TABLES_SCHEMA}", "entries": [
                        {{{entry_ok}, "rotated": true}},
                        {{"study": "s", "kernel": "dao", "n": 256, "rows": 1,
                          "dtype": "float32", "scheme": "int8", "layers": 1,
                          "snr_db": 20.0, "rel_to_amax": -0.5, "rotated": false}}]}}"#
                ),
            ),
            (
                // both rotation sides must appear or the with/without
                // comparison is vacuous
                "only_one_rotation_side",
                format!(
                    r#"{{"schema": "{TABLES_SCHEMA}", "entries": [{{{entry_ok}, "rotated": true}}]}}"#
                ),
            ),
        ];
        for (name, text) in cases {
            let path = dir
                .join(format!("hc_badtables_{}_{name}.json", std::process::id()))
                .to_string_lossy()
                .into_owned();
            std::fs::write(&path, text).unwrap();
            assert!(validate_tables_json(&path).is_err(), "{name} must fail");
            std::fs::remove_file(&path).ok();
        }
        // writing an empty collector must also fail loudly
        let path = dir
            .join(format!("hc_emptytables_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        assert!(TablesJson::new().write(&path).is_err());
        std::fs::remove_file(&path).ok();

        // an infinite SNR must be clamped by the producer, not written
        let mut inf = TablesJson::new();
        inf.push(table_fixture(true, f64::INFINITY));
        inf.push(table_fixture(false, 10.0));
        assert!(inf.write(&path).is_err(), "non-finite snr must be rejected");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tables_json_env_override_controls_the_path() {
        assert_eq!(
            TablesJson::output_path("TABLES_PR6.json"),
            std::env::var("HADACORE_TABLES_JSON")
                .unwrap_or_else(|_| "TABLES_PR6.json".to_string())
        );
    }

    #[test]
    fn table_record_line_formats() {
        let line = table_fixture(true, 25.0).line();
        assert!(line.contains("hadacore"));
        assert!(line.contains("fp8_e4m3"));
        assert!(line.contains("dB"));
    }

    #[test]
    fn stats_line_formats() {
        let s = Stats {
            name: "x".into(),
            iters: 10,
            samples: 3,
            mean_ns: 1500.0,
            median_ns: 1400.0,
            min_ns: 1200.0,
            mad_ns: 50.0,
        };
        let line = s.line();
        assert!(line.contains("µs/iter"));
        assert!((s.median_us() - 1.4).abs() < 1e-9);
    }
}
