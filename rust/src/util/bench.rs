//! Micro-benchmark timer: warmup, calibrated iteration counts, robust stats.
//!
//! Criterion is unavailable offline; this provides the subset the paper's
//! evaluation needs — median / mean / MAD over repeated timed batches with
//! black-box protection — and a stable text report format that the bench
//! binaries (`cargo bench`, `harness = false`) print.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result statistics for one benchmark case (all values in nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    /// Median in microseconds (the unit the paper reports).
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }

    /// Human-readable single-line summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.3} µs/iter  (min {:>10.3}, mad {:>8.3}, {} iters x {} samples)",
            self.name,
            self.median_ns / 1e3,
            self.min_ns / 1e3,
            self.mad_ns / 1e3,
            self.iters,
            self.samples,
        )
    }
}

/// Benchmark configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Target wall time per sample batch.
    pub sample_time: Duration,
    /// Number of sample batches.
    pub samples: usize,
    /// Warmup time before calibration.
    pub warmup: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            sample_time: Duration::from_millis(40),
            samples: 12,
            warmup: Duration::from_millis(60),
        }
    }
}

impl BenchConfig {
    /// A faster configuration for CI-style smoke benches.
    pub fn quick() -> Self {
        BenchConfig {
            sample_time: Duration::from_millis(10),
            samples: 6,
            warmup: Duration::from_millis(15),
        }
    }
}

/// Time `f` repeatedly and return robust statistics.
///
/// `f` receives the iteration index; its return value is black-boxed so the
/// optimiser cannot elide the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut(u64) -> T) -> Stats {
    // Warmup + calibration: find iters such that one batch ~ sample_time.
    let warm_start = Instant::now();
    let mut calib_iters = 0u64;
    while warm_start.elapsed() < cfg.warmup {
        black_box(f(calib_iters));
        calib_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
    let iters = ((cfg.sample_time.as_secs_f64() / per_iter.max(1e-9)) as u64).clamp(1, 1 << 24);

    let mut batch_ns: Vec<f64> = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for i in 0..iters {
            black_box(f(i));
        }
        batch_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    batch_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile(&batch_ns, 50.0);
    let min = batch_ns[0];
    let mean = batch_ns.iter().sum::<f64>() / batch_ns.len() as f64;
    let mut devs: Vec<f64> = batch_ns.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = percentile(&devs, 50.0);

    Stats {
        name: name.to_string(),
        iters,
        samples: cfg.samples,
        mean_ns: mean,
        median_ns: median,
        min_ns: min,
        mad_ns: mad,
    }
}

/// Percentile (0..=100) of a sorted slice via linear interpolation.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Convenience: run + print one benchmark case.
pub fn run_case<T>(name: &str, cfg: &BenchConfig, f: impl FnMut(u64) -> T) -> Stats {
    let s = bench(name, cfg, f);
    println!("{}", s.line());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_measures_something_positive() {
        let cfg = BenchConfig {
            sample_time: Duration::from_millis(2),
            samples: 3,
            warmup: Duration::from_millis(2),
        };
        let s = bench("spin", &cfg, |i| {
            let mut acc = i;
            for k in 0..100 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.iters >= 1);
    }

    #[test]
    fn stats_line_formats() {
        let s = Stats {
            name: "x".into(),
            iters: 10,
            samples: 3,
            mean_ns: 1500.0,
            median_ns: 1400.0,
            min_ns: 1200.0,
            mad_ns: 50.0,
        };
        let line = s.line();
        assert!(line.contains("µs/iter"));
        assert!((s.median_us() - 1.4).abs() < 1e-9);
    }
}
