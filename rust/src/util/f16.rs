//! IEEE binary16 (`F16`) and bfloat16 (`BF16`) with bit-exact conversions.
//!
//! The paper evaluates FP16 and BF16 variants of both kernels (Appendix C);
//! the runtime exchanges 16-bit buffers with PJRT executables. The `half`
//! crate is unavailable offline, so conversions are implemented here with
//! correct round-to-nearest-even semantics (the rounding Tensor Cores and
//! the MXU use when down-converting from an FP32 accumulator).

/// Common behaviour of storage element types used by kernels and buffers.
pub trait Element: Copy + Send + Sync + 'static {
    /// dtype tag used by artifact manifests and the registry.
    const DTYPE: DType;
    /// Widen to f32 (exact for all three formats).
    fn to_f32(self) -> f32;
    /// Narrow from f32 with round-to-nearest-even.
    fn from_f32(v: f32) -> Self;
}

/// Element dtype tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    /// Canonical lowercase name (matches the python manifest).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F16 => "float16",
            DType::BF16 => "bfloat16",
        }
    }

    /// Parse a manifest dtype name.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "float16" | "f16" => Some(DType::F16),
            "bfloat16" | "bf16" => Some(DType::BF16),
            _ => None,
        }
    }

    /// Unit roundoff (half the distance between 1.0 and the next value).
    pub fn epsilon(self) -> f32 {
        match self {
            DType::F32 => f32::EPSILON,
            DType::F16 => 9.765_625e-4,  // 2^-10
            DType::BF16 => 7.812_5e-3,   // 2^-7
        }
    }
}

/// IEEE 754 binary16.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

/// bfloat16 (truncated-exponent-preserving 16-bit float).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct BF16(pub u16);

/// f32 -> binary16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        return if mant == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 // quiet NaN
        };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal half
        let half_exp = (e + 15) as u32;
        // 23 -> 10 bits: round bit at position 12
        let mant10 = mant >> 13;
        let round = mant & 0x1fff;
        let mut h = (half_exp << 10) as u16 | mant10 as u16;
        if round > 0x1000 || (round == 0x1000 && (mant10 & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent — still correct
        }
        return sign | h;
    }
    if e >= -25 {
        // subnormal half
        let full = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - e) + 13; // total right shift to 10-bit subnormal
        let mant10 = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = mant10 as u16;
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return sign | h;
    }
    sign // underflow to signed zero
}

/// binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalise. value = mant * 2^-24; with p the
            // highest set bit, that's 2^(p-24) * 1.fraction.
            let lz = mant.leading_zeros() - 21; // = 10 - p
            let mant_norm = (mant << lz) & 0x3ff;
            let e = 113 - lz; // biased f32 exponent: 127 + (p - 24)
            sign | (e << 23) | (mant_norm << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 bits with round-to-nearest-even.
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // keep sign, force quiet
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    // detect rounding overflow into inf is naturally handled: exponent
    // increments to 0xff and mantissa clears -> inf, the correct result.
    let _ = round_bit;
    (rounded >> 16) as u16
}

/// bfloat16 bits -> f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

impl Element for f32 {
    const DTYPE: DType = DType::F32;
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl Element for F16 {
    const DTYPE: DType = DType::F16;
    #[inline]
    fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        F16(f32_to_f16_bits(v))
    }
}

impl Element for BF16 {
    const DTYPE: DType = DType::BF16;
    #[inline]
    fn to_f32(self) -> f32 {
        bf16_bits_to_f32(self.0)
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        BF16(f32_to_bf16_bits(v))
    }
}

/// Convert a f32 slice into 16-bit storage.
pub fn narrow_slice<E: Element>(src: &[f32]) -> Vec<E> {
    src.iter().map(|&v| E::from_f32(v)).collect()
}

/// Convert 16-bit storage back to f32.
pub fn widen_slice<E: Element>(src: &[E]) -> Vec<f32> {
    src.iter().map(|v| v.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099975586] {
            let h = f32_to_f16_bits(v);
            let back = f16_bits_to_f32(h);
            let again = f32_to_f16_bits(back);
            assert_eq!(h, again, "unstable roundtrip for {v}");
        }
    }

    #[test]
    fn f16_known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite half
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00); // overflow -> +inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8); // min subnormal
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: ties-to-even
        // must round down to 1.0 (even mantissa).
        let v = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(v), 0x3c00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds up to even.
        let v2 = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(v2), 0x3c02);
    }

    #[test]
    fn f16_subnormals_roundtrip() {
        for i in 1u16..=0x3ff {
            let f = f16_bits_to_f32(i);
            assert_eq!(f32_to_f16_bits(f), i, "subnormal bits {i:#x}");
        }
    }

    #[test]
    fn f16_nan_stays_nan() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn f16_exhaustive_monotone_roundtrip() {
        // every finite half value round-trips bit-exactly through f32
        for bits in 0u16..=0xffff {
            let exp = (bits >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled elsewhere
            }
            let f = f16_bits_to_f32(bits);
            let rt = f32_to_f16_bits(f);
            // -0.0 and 0.0 keep their sign bit
            assert_eq!(rt, bits, "bits {bits:#x} -> {f} -> {rt:#x}");
        }
    }

    #[test]
    fn bf16_known_patterns() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(-1.0), 0xbf80);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
    }

    #[test]
    fn bf16_round_to_nearest_even() {
        // 1 + 2^-8 is halfway between 1.0 (0x3f80) and 1+2^-7 (0x3f81):
        // ties-to-even keeps 0x3f80.
        let v = 1.0 + 2f32.powi(-8);
        assert_eq!(f32_to_bf16_bits(v), 0x3f80);
        // 1 + 3*2^-8 is halfway between 0x3f81 and 0x3f82: rounds to even 0x3f82.
        let v2 = 1.0 + 3.0 * 2f32.powi(-8);
        assert_eq!(f32_to_bf16_bits(v2), 0x3f82);
    }

    #[test]
    fn bf16_roundtrip_stability() {
        let mut r = crate::util::rng::Rng::new(3);
        for _ in 0..10_000 {
            let v = (r.f64() as f32 - 0.5) * 1e4;
            let b = f32_to_bf16_bits(v);
            assert_eq!(f32_to_bf16_bits(bf16_bits_to_f32(b)), b);
        }
    }

    #[test]
    fn bf16_nan_preserved() {
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn element_trait_roundtrip() {
        let xs = [0.25f32, -3.5, 1000.0];
        let f16s = narrow_slice::<F16>(&xs);
        let back = widen_slice(&f16s);
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= 0.5 + a.abs() * 1e-3);
        }
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::F32.name(), "float32");
        assert_eq!(DType::parse("bfloat16"), Some(DType::BF16));
    }

    #[test]
    fn f16_error_bound_random() {
        let mut r = crate::util::rng::Rng::new(5);
        for _ in 0..20_000 {
            let v = r.normal_f32() * 100.0;
            let e = (F16::from_f32(v).to_f32() - v).abs();
            // relative error bounded by 2^-11 for normals in range
            assert!(e <= v.abs() * 4.9e-4 + 1e-7, "v={v} e={e}");
        }
    }
}
