//! Size-classed f32 buffer pool with RAII return — the allocation-free
//! substrate of the zero-copy serving path.
//!
//! A request payload lands in a [`PooledBuf`] once, at wire decode, and
//! that same buffer is transformed in place and framed into the response
//! bytes; when the response is dropped (after its bytes hit the socket)
//! the buffer's `Drop` returns it to the pool for the next request. In
//! steady state the serve path therefore performs **zero** payload
//! allocations per request — the property the `count-alloc` gate
//! measures (see [`crate::util::alloc`]).
//!
//! Design:
//! * **power-of-two size classes** from [`MIN_CLASS_ELEMS`] up to
//!   [`MAX_CLASS_ELEMS`]; a `get(len)` rounds up to its class so a
//!   returned buffer is reusable by any request of the same class, not
//!   just the same exact size. Requests above the top class fall back to
//!   a plain allocation that is *not* pooled (dropped normally) — they
//!   are outside the serving sweet spot and must not pin huge buffers.
//! * **bounded shelves**: each class keeps at most `shelf_cap` idle
//!   buffers (shelf vectors are pre-reserved, so returning a buffer
//!   never allocates). A return to a full shelf frees the buffer.
//! * **RAII**: [`PooledBuf`] derefs to `Vec<f32>` and returns itself on
//!   `Drop`, so every exit path — response written, request shed Busy,
//!   connection torn down mid-flight, malformed follow-up frame — gives
//!   the buffer back without bookkeeping at the call sites.
//! * **unpooled shim**: `From<Vec<f32>>` wraps a caller-owned vector
//!   without pool affiliation, keeping the public
//!   `Coordinator::transform` / test API source-compatible: such buffers
//!   simply drop like the `Vec` they wrap.
//!
//! [`serve_pool`] is the process-wide pool the TCP serving layer decodes
//! into; unit tests build private pools via [`BufferPool::new`].

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::lazy::Lazy;

/// Smallest pooled capacity: 256 f32 (1 KiB) — one interactive-mix row.
pub const MIN_CLASS_ELEMS: usize = 1 << 8;
/// Largest pooled capacity: 4 Mi f32 (16 MiB) — covers the router's
/// default per-request ceiling (`2^16` rows) at serving row lengths.
pub const MAX_CLASS_ELEMS: usize = 1 << 22;
/// Number of power-of-two classes in `[MIN_CLASS_ELEMS, MAX_CLASS_ELEMS]`.
const NUM_CLASSES: usize = 15;
/// Default idle buffers retained per class.
const DEFAULT_SHELF_CAP: usize = 32;

/// Class that can satisfy a request for `elems` (round capacity *up*),
/// or `None` above the top class.
fn class_for_request(elems: usize) -> Option<usize> {
    if elems > MAX_CLASS_ELEMS {
        return None;
    }
    let cap = elems.max(MIN_CLASS_ELEMS).next_power_of_two();
    Some(cap.trailing_zeros() as usize - MIN_CLASS_ELEMS.trailing_zeros() as usize)
}

/// Class a buffer of `capacity` can serve (round *down*): its capacity
/// covers every request of that class or below.
fn class_for_capacity(capacity: usize) -> Option<usize> {
    if capacity < MIN_CLASS_ELEMS {
        return None;
    }
    let idx = (usize::BITS - 1 - capacity.leading_zeros()) as usize
        - MIN_CLASS_ELEMS.trailing_zeros() as usize;
    Some(idx.min(NUM_CLASSES - 1))
}

/// Capacity (elements) of class `idx`.
fn class_elems(idx: usize) -> usize {
    MIN_CLASS_ELEMS << idx
}

struct PoolInner {
    shelves: Vec<Mutex<Vec<Vec<f32>>>>,
    shelf_cap: usize,
    allocated: AtomicU64,
    reused: AtomicU64,
    returned: AtomicU64,
    shelf_full_drops: AtomicU64,
    unpooled: AtomicU64,
    detached: AtomicU64,
    outstanding: AtomicI64,
}

impl PoolInner {
    /// Return a buffer to its (floor) class shelf, or free it if the
    /// shelf is full. The shelf vector is pre-reserved to `shelf_cap`,
    /// so the push itself never allocates.
    fn put(&self, mut buf: Vec<f32>) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
        self.returned.fetch_add(1, Ordering::Relaxed);
        let Some(class) = class_for_capacity(buf.capacity()) else {
            self.shelf_full_drops.fetch_add(1, Ordering::Relaxed);
            return;
        };
        buf.clear();
        let mut shelf = self.shelves[class].lock().unwrap();
        if shelf.len() < self.shelf_cap {
            shelf.push(buf);
        } else {
            drop(shelf);
            self.shelf_full_drops.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Counter snapshot of a [`BufferPool`]; the leak-detection tests key on
/// `outstanding` returning to its baseline after traffic drains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers created because no shelf had one (warmup / bursts).
    pub allocated: u64,
    /// `get` calls satisfied from a shelf — the zero-alloc hits.
    pub reused: u64,
    /// Buffers handed back through `Drop`.
    pub returned: u64,
    /// Returns that freed the buffer (full shelf or off-class capacity).
    pub shelf_full_drops: u64,
    /// `get` calls above [`MAX_CLASS_ELEMS`] served unpooled.
    pub unpooled: u64,
    /// Pooled buffers whose storage was detached via
    /// [`PooledBuf::into_vec`] (ownership transfers, not leaks).
    pub detached: u64,
    /// Pool-affiliated buffers currently held by callers.
    pub outstanding: i64,
}

/// Size-classed pool of reusable `Vec<f32>` payload buffers (module doc).
/// Cheap to clone-share internally; all methods take `&self`.
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new(DEFAULT_SHELF_CAP)
    }
}

impl BufferPool {
    /// A pool retaining at most `shelf_cap` idle buffers per size class.
    pub fn new(shelf_cap: usize) -> BufferPool {
        let shelf_cap = shelf_cap.max(1);
        let shelves = (0..NUM_CLASSES)
            .map(|_| Mutex::new(Vec::with_capacity(shelf_cap)))
            .collect();
        BufferPool {
            inner: Arc::new(PoolInner {
                shelves,
                shelf_cap,
                allocated: AtomicU64::new(0),
                reused: AtomicU64::new(0),
                returned: AtomicU64::new(0),
                shelf_full_drops: AtomicU64::new(0),
                unpooled: AtomicU64::new(0),
                detached: AtomicU64::new(0),
                outstanding: AtomicI64::new(0),
            }),
        }
    }

    /// An **empty** buffer with capacity for at least `elems` elements.
    /// Callers fill it with `extend`/`push` (the wire decoder widens
    /// directly into it); no zero-fill pass is paid.
    pub fn get(&self, elems: usize) -> PooledBuf {
        let Some(class) = class_for_request(elems) else {
            // above the top class: plain allocation, not pooled
            self.inner.unpooled.fetch_add(1, Ordering::Relaxed);
            return PooledBuf { data: Vec::with_capacity(elems), pool: None };
        };
        let recycled = self.inner.shelves[class].lock().unwrap().pop();
        let data = match recycled {
            Some(buf) => {
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.inner.allocated.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(class_elems(class))
            }
        };
        self.inner.outstanding.fetch_add(1, Ordering::Relaxed);
        PooledBuf { data, pool: Some(Arc::clone(&self.inner)) }
    }

    /// A pooled buffer filled with a copy of `src` (convenience for the
    /// scatter paths that cannot reuse a request buffer, e.g. PJRT).
    pub fn get_copy(&self, src: &[f32]) -> PooledBuf {
        let mut buf = self.get(src.len());
        buf.extend_from_slice(src);
        buf
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PoolStats {
        let i = &self.inner;
        PoolStats {
            allocated: i.allocated.load(Ordering::Relaxed),
            reused: i.reused.load(Ordering::Relaxed),
            returned: i.returned.load(Ordering::Relaxed),
            shelf_full_drops: i.shelf_full_drops.load(Ordering::Relaxed),
            unpooled: i.unpooled.load(Ordering::Relaxed),
            detached: i.detached.load(Ordering::Relaxed),
            outstanding: i.outstanding.load(Ordering::Relaxed),
        }
    }

    /// Buffers currently held by callers (0 == no leaks).
    pub fn outstanding(&self) -> i64 {
        self.inner.outstanding.load(Ordering::Relaxed)
    }
}

impl Clone for BufferPool {
    fn clone(&self) -> Self {
        BufferPool { inner: Arc::clone(&self.inner) }
    }
}

impl fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("BufferPool").field("stats", &self.stats()).finish()
    }
}

/// The process-wide pool the TCP serving layer decodes request payloads
/// into (one shared pool: a connection's buffers are reusable by every
/// other connection, which is what keeps bursty multi-client traffic
/// allocation-free).
static SERVE_POOL: Lazy<BufferPool> = Lazy::new(BufferPool::default);

/// The shared serving pool.
pub fn serve_pool() -> &'static BufferPool {
    &SERVE_POOL
}

// ---------------------------------------------------------------------
// Grouped-INT8 scale-vector recycler.

/// Free-list recycler for the grouped-INT8 epilogue's per-response
/// scale vectors (`rows * n / group` f32s, carried in
/// [`QuantScales::PerGroup`](crate::quant::QuantScales)).
///
/// The payload buffers are pooled ([`BufferPool`]), but until this
/// recycler existed every grouped-INT8 response allocated its scale
/// vector fresh — the last per-request allocation on the serve path.
/// The engine draws vectors from here ([`ScaleVecPool::get_zeroed`]),
/// and the server's writer thread returns them after the response
/// frame hits the socket ([`ScaleVecPool::put`]): in steady state a
/// traffic mix's scale shapes are all resident and the path allocates
/// nothing (asserted by the grouped-INT8 mix in the
/// `--assert-zero-alloc` loadgen gate).
///
/// The `Vec<f32>` type is unchanged end to end — `QuantScales` and the
/// wire encoding are untouched; recycling is purely a lifecycle hookup
/// at the two ends of the response's life.
pub struct ScaleVecPool {
    shelf: Mutex<Vec<Vec<f32>>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScaleVecPool {
    /// A recycler keeping at most `cap` idle vectors (the shelf is
    /// pre-reserved, so returns never allocate).
    pub fn new(cap: usize) -> ScaleVecPool {
        ScaleVecPool {
            shelf: Mutex::new(Vec::with_capacity(cap)),
            cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A zero-filled vector of exactly `len` elements. Reuses a shelved
    /// vector whose capacity suffices (clear + in-capacity resize — no
    /// heap traffic); falls back to a fresh allocation on a miss.
    pub fn get_zeroed(&self, len: usize) -> Vec<f32> {
        if len > 0 {
            let mut shelf = self.shelf.lock().unwrap();
            if let Some(i) = shelf.iter().position(|v| v.capacity() >= len) {
                let mut v = shelf.swap_remove(i);
                drop(shelf);
                self.hits.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v.resize(len, 0.0);
                return v;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        vec![0.0f32; len]
    }

    /// Shelve a spent scale vector for reuse. A return to a full shelf
    /// (or of an empty vector) frees it instead.
    pub fn put(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut shelf = self.shelf.lock().unwrap();
        if shelf.len() < self.cap {
            shelf.push(v);
        }
    }

    /// Reuse count (gets served from the shelf).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Fresh-allocation count (first use of a shape, or shelf pressure).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Idle-shelf bound of the process-wide [`scale_pool`]: comfortably
/// above any realistic (mix shapes × in-flight responses) working set,
/// small enough that pathological shape churn cannot pin memory.
const SCALE_POOL_CAP: usize = 128;

static SCALE_POOL: Lazy<ScaleVecPool> = Lazy::new(|| ScaleVecPool::new(SCALE_POOL_CAP));

/// The process-wide grouped-INT8 scale-vector recycler (engine draws,
/// serve writer returns).
pub fn scale_pool() -> &'static ScaleVecPool {
    &SCALE_POOL
}

/// An owned f32 payload buffer, optionally affiliated with a
/// [`BufferPool`] it returns to on `Drop`. Derefs to `Vec<f32>`, so all
/// existing `&resp.data` / `resp.data.len()` call sites compile
/// unchanged; `From<Vec<f32>>` keeps `TransformRequest::new(id, n, vec)`
/// source-compatible (such buffers are unpooled and drop normally).
pub struct PooledBuf {
    data: Vec<f32>,
    pool: Option<Arc<PoolInner>>,
}

impl PooledBuf {
    /// Wrap a caller-owned vector without pool affiliation.
    pub fn unpooled(data: Vec<f32>) -> PooledBuf {
        PooledBuf { data, pool: None }
    }

    /// Whether this buffer returns to a pool on drop.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Detach the underlying vector (the buffer does **not** return to
    /// its pool — the caller now owns the storage outright). This is an
    /// ownership transfer, not a leak: the pool's `outstanding` gauge is
    /// released and the detach is counted in [`PoolStats::detached`].
    pub fn into_vec(mut self) -> Vec<f32> {
        if let Some(pool) = self.pool.take() {
            pool.outstanding.fetch_sub(1, Ordering::Relaxed);
            pool.detached.fetch_add(1, Ordering::Relaxed);
        }
        std::mem::take(&mut self.data)
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put(std::mem::take(&mut self.data));
        }
    }
}

impl Deref for PooledBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.data
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.data
    }
}

impl From<Vec<f32>> for PooledBuf {
    fn from(data: Vec<f32>) -> PooledBuf {
        PooledBuf::unpooled(data)
    }
}

/// Deep copy, **unpooled** — cloning is a test/debug convenience and must
/// not silently multiply claims on a pool shelf.
impl Clone for PooledBuf {
    fn clone(&self) -> Self {
        PooledBuf::unpooled(self.data.clone())
    }
}

impl fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.data.fmt(f)
    }
}

impl PartialEq for PooledBuf {
    fn eq(&self, other: &PooledBuf) -> bool {
        self.data == other.data
    }
}

impl PartialEq<Vec<f32>> for PooledBuf {
    fn eq(&self, other: &Vec<f32>) -> bool {
        &self.data == other
    }
}

impl PartialEq<PooledBuf> for Vec<f32> {
    fn eq(&self, other: &PooledBuf) -> bool {
        self == &other.data
    }
}

impl PartialEq<[f32]> for PooledBuf {
    fn eq(&self, other: &[f32]) -> bool {
        self.data.as_slice() == other
    }
}

impl PartialEq<&[f32]> for PooledBuf {
    fn eq(&self, other: &&[f32]) -> bool {
        self.data.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding_up_and_down() {
        assert_eq!(class_for_request(0), Some(0));
        assert_eq!(class_for_request(1), Some(0));
        assert_eq!(class_for_request(256), Some(0));
        assert_eq!(class_for_request(257), Some(1));
        assert_eq!(class_for_request(512), Some(1));
        assert_eq!(class_for_request(MAX_CLASS_ELEMS), Some(NUM_CLASSES - 1));
        assert_eq!(class_for_request(MAX_CLASS_ELEMS + 1), None);

        assert_eq!(class_for_capacity(255), None);
        assert_eq!(class_for_capacity(256), Some(0));
        assert_eq!(class_for_capacity(511), Some(0));
        assert_eq!(class_for_capacity(512), Some(1));
        // capacities above the top class still land on the top shelf
        assert_eq!(class_for_capacity(MAX_CLASS_ELEMS * 2), Some(NUM_CLASSES - 1));
        // round-trip: a request's class capacity serves that request
        for elems in [1usize, 100, 256, 300, 4096, 14336, 1 << 20] {
            let class = class_for_request(elems).unwrap();
            assert!(class_elems(class) >= elems);
            assert_eq!(class_for_capacity(class_elems(class)), Some(class));
        }
    }

    #[test]
    fn get_returns_empty_buffer_with_capacity() {
        let pool = BufferPool::new(4);
        let buf = pool.get(1000);
        assert!(buf.is_empty());
        assert!(buf.capacity() >= 1000);
        assert!(buf.is_pooled());
        assert_eq!(pool.outstanding(), 1);
    }

    #[test]
    fn drop_returns_and_get_reuses() {
        let pool = BufferPool::new(4);
        let ptr = {
            let mut buf = pool.get(512);
            buf.extend_from_slice(&[1.0; 512]);
            buf.as_ptr()
        };
        let s = pool.stats();
        assert_eq!((s.allocated, s.returned, s.outstanding), (1, 1, 0));
        // the same storage comes back, cleared
        let buf = pool.get(512);
        assert_eq!(buf.as_ptr(), ptr);
        assert!(buf.is_empty());
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn different_sizes_share_a_class_shelf() {
        let pool = BufferPool::new(4);
        drop(pool.get(300)); // class 1 (512)
        let buf = pool.get(512); // same class: reuse
        assert_eq!(pool.stats().reused, 1);
        drop(buf);
        let _big = pool.get(4096); // class 4: fresh allocation
        assert_eq!(pool.stats().allocated, 2);
    }

    #[test]
    fn shelf_cap_bounds_retention() {
        let pool = BufferPool::new(2);
        let bufs: Vec<PooledBuf> = (0..5).map(|_| pool.get(256)).collect();
        assert_eq!(pool.stats().allocated, 5);
        drop(bufs);
        let s = pool.stats();
        assert_eq!(s.returned, 5);
        assert_eq!(s.shelf_full_drops, 3, "only shelf_cap buffers retained");
        assert_eq!(s.outstanding, 0);
    }

    #[test]
    fn oversized_requests_are_unpooled() {
        let pool = BufferPool::new(4);
        let buf = pool.get(MAX_CLASS_ELEMS + 1);
        assert!(!buf.is_pooled());
        assert!(buf.capacity() > MAX_CLASS_ELEMS);
        drop(buf);
        let s = pool.stats();
        assert_eq!(s.unpooled, 1);
        assert_eq!(s.outstanding, 0, "unpooled buffers never count outstanding");
    }

    #[test]
    fn unpooled_shim_and_into_vec() {
        let pool = BufferPool::new(4);
        let shim: PooledBuf = vec![1.0f32, 2.0].into();
        assert!(!shim.is_pooled());
        assert_eq!(shim, vec![1.0f32, 2.0]);
        drop(shim); // plain drop, no pool interaction

        let mut buf = pool.get(256);
        buf.push(3.0);
        let v = buf.into_vec();
        assert_eq!(v, vec![3.0f32]);
        // detached: the pool never gets it back, and the gauge must not
        // stay pinned — into_vec is an ownership transfer, not a leak
        let s = pool.stats();
        assert_eq!(s.returned, 0);
        assert_eq!(s.detached, 1);
        assert_eq!(s.outstanding, 0);
    }

    #[test]
    fn clone_is_deep_and_unpooled() {
        let pool = BufferPool::new(4);
        let mut buf = pool.get(256);
        buf.extend_from_slice(&[5.0; 8]);
        let c = buf.clone();
        assert!(!c.is_pooled());
        assert_eq!(c, buf);
        drop(buf);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(c.len(), 8);
    }

    #[test]
    fn equality_across_vec_and_slice() {
        let b = PooledBuf::unpooled(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(b, vec![1.0f32, 2.0, 3.0]);
        assert_eq!(vec![1.0f32, 2.0, 3.0], b);
        assert_eq!(b, [1.0f32, 2.0, 3.0][..]);
        assert!(b != vec![1.0f32, 2.0]);
    }

    #[test]
    fn concurrent_get_put_is_leak_free() {
        let pool = BufferPool::new(8);
        std::thread::scope(|s| {
            for t in 0..8 {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..200 {
                        let elems = 256 << ((t + i) % 4);
                        let mut buf = pool.get(elems);
                        buf.resize(elems, t as f32);
                        assert!(buf.iter().all(|&v| v == t as f32));
                    }
                });
            }
        });
        let s = pool.stats();
        assert_eq!(s.outstanding, 0);
        assert!(s.reused > 0, "contended traffic must hit the shelves");
        assert_eq!(s.allocated + s.reused, 8 * 200);
    }

    #[test]
    fn serve_pool_is_shared() {
        let a = serve_pool();
        let b = serve_pool();
        assert!(std::ptr::eq(a, b));
    }

    #[test]
    fn scale_pool_recycles_and_zeroes() {
        let pool = ScaleVecPool::new(4);
        let mut v = pool.get_zeroed(64);
        assert_eq!(v, vec![0.0f32; 64]);
        assert_eq!(pool.misses(), 1);
        v.iter_mut().for_each(|x| *x = 7.0);
        let cap = v.capacity();
        let ptr = v.as_ptr();
        pool.put(v);

        // same shape again: served from the shelf, zero-filled, and —
        // the zero-alloc contract — the very same heap block
        let v2 = pool.get_zeroed(64);
        assert_eq!(pool.hits(), 1);
        assert_eq!(v2, vec![0.0f32; 64]);
        assert_eq!(v2.capacity(), cap);
        assert_eq!(v2.as_ptr(), ptr);

        // a smaller request also fits the shelved capacity
        pool.put(v2);
        let v3 = pool.get_zeroed(16);
        assert_eq!(pool.hits(), 2);
        assert_eq!(v3.len(), 16);

        // a larger one is an honest miss
        let v4 = pool.get_zeroed(4096);
        assert_eq!(pool.misses(), 2);
        assert_eq!(v4.len(), 4096);
    }

    #[test]
    fn scale_pool_shelf_is_bounded() {
        let pool = ScaleVecPool::new(2);
        for _ in 0..5 {
            pool.put(vec![0.0f32; 32]);
        }
        // only two shelved: the rest were freed, so only two hits follow
        let _a = pool.get_zeroed(32);
        let _b = pool.get_zeroed(32);
        let _c = pool.get_zeroed(32);
        assert_eq!(pool.hits(), 2);
        assert_eq!(pool.misses(), 1);
        // empty vectors are never shelved
        pool.put(Vec::new());
        let _d = pool.get_zeroed(8);
        assert_eq!(pool.misses(), 2);
    }
}
