//! Tiny declarative CLI flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text. Used by the main binary, the
//! examples and the bench harnesses.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    /// Start a parser description.
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a valued option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (false unless present).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    /// Parse from an iterator (first element is NOT the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        mut self,
        args: I,
    ) -> Result<Self, String> {
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?
                    .clone();
                let value = if decl.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} requires a value"))?,
                    }
                };
                self.values.insert(name, value);
            } else {
                self.positionals.push(a);
            }
        }
        Ok(self)
    }

    /// Parse the process arguments; print help/error and exit on failure.
    pub fn parse(self) -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: {e}\n");
                std::process::exit(2);
            }
        }
    }

    /// Generated help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let default = match (&o.default, o.is_bool) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, default));
        }
        s
    }

    /// Raw string value of an option (declared default if absent).
    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_default()
    }

    /// Parse an option as any `FromStr` type; panics with context on error.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name);
        raw.parse().unwrap_or_else(|e| {
            eprintln!("error: --{name}={raw}: {e}");
            std::process::exit(2);
        })
    }

    /// Boolean switch state.
    pub fn flag(&self, name: &str) -> bool {
        self.values
            .get(name)
            .map(|v| v == "true" || v == "1")
            .unwrap_or(false)
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated list option as trimmed strings (empty elements
    /// dropped) — e.g. `--mixes interactive,llama-ffn`.
    pub fn get_str_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }

    /// Comma-separated list option parsed into numbers.
    pub fn get_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse().unwrap_or_else(|_| {
                    eprintln!("error: --{name}: bad list element {s:?}");
                    std::process::exit(2);
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Args {
        Args::new("t", "test")
            .opt("size", "256", "hadamard size")
            .opt("sizes", "1,2", "list")
            .switch("inplace", "transform in place")
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.get("size"), "256");
        assert!(!a.flag("inplace"));
        assert_eq!(a.get_as::<usize>("size"), 256);
    }

    #[test]
    fn parses_forms() {
        let a = base()
            .parse_from(
                ["--size", "512", "--inplace", "pos1"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        assert_eq!(a.get_as::<usize>("size"), 512);
        assert!(a.flag("inplace"));
        assert_eq!(a.positional(), &["pos1".to_string()]);

        let b = base()
            .parse_from(["--size=1024".to_string()])
            .unwrap();
        assert_eq!(b.get_as::<usize>("size"), 1024);
    }

    #[test]
    fn list_parsing() {
        let a = base()
            .parse_from(["--sizes=128,256,512".to_string()])
            .unwrap();
        assert_eq!(a.get_list("sizes"), vec![128, 256, 512]);
    }

    #[test]
    fn string_list_parsing() {
        let a = Args::new("t", "test")
            .opt("mixes", "mixed", "traffic mixes")
            .parse_from(["--mixes= interactive, llama-ffn ,".to_string()])
            .unwrap();
        assert_eq!(a.get_str_list("mixes"), vec!["interactive", "llama-ffn"]);
        let b = Args::new("t", "test")
            .opt("mixes", "mixed", "traffic mixes")
            .parse_from(Vec::<String>::new())
            .unwrap();
        assert_eq!(b.get_str_list("mixes"), vec!["mixed"]);
    }

    #[test]
    fn rejects_unknown() {
        assert!(base().parse_from(["--nope".to_string()]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(base().parse_from(["--size".to_string()]).is_err());
    }

    #[test]
    fn help_text_lists_options() {
        let h = base().help_text();
        assert!(h.contains("--size"));
        assert!(h.contains("--inplace"));
    }
}
