//! `hadacore` — the coordinator binary.
//!
//! Subcommands:
//!
//! * `info`      — artifact inventory, platform, weight stats.
//! * `transform` — one-off transform from the CLI (native or PJRT).
//! * `serve`     — run the TCP serving layer (`serve/`) over the
//!                 coordinator: wire-protocol server with admission
//!                 control and graceful drain.
//! * `cluster`   — run the scale-out tier (`serve/cluster.rs`): a
//!                 routing proxy over N backend serve processes (given
//!                 via `--backends` or spawned as children with
//!                 `--spawn`), with homogeneous shard routing, health
//!                 checks, and retriable failover.
//! * `loadgen`   — open-loop load generator: drive configurable QPS /
//!                 traffic mixes through the client library against a
//!                 server (or a self-hosted in-process one) and emit the
//!                 `BENCH_PR7.json` perf trajectory. Built with
//!                 `--features count-alloc` it also measures server-side
//!                 heap allocations per request (`--assert-zero-alloc`
//!                 turns the zero-alloc steady state into a hard gate).
//!                 `--cluster` self-hosts a whole fleet behind the
//!                 routing proxy instead and emits fleet-wide plus
//!                 per-backend records (`BENCH_PR9.json`).
//! * `stats`     — fetch a running server's (or proxy's) metrics
//!                 registry as Prometheus-style text over the wire
//!                 protocol (`StatsText` frame), or dump buffered
//!                 flight-recorder spans (`--trace`).
//! * `tables`    — regenerate the paper's evaluation tables from the GPU
//!                 model (see also `examples/paper_tables.rs`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hadacore::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use hadacore::exec::ExecConfig;
use hadacore::gpu_model::{speedup_grid, GridConfig, A100_PCIE, H100_PCIE};
use hadacore::hadamard::KernelKind;
use hadacore::harness::tables::{format_runtime_table, format_speedup_table};
use hadacore::harness::workload::{traffic_mix, TRAFFIC_MIXES};
use hadacore::runtime::Runtime;
use hadacore::obs::{serve_metrics, MetricsHandle};
use hadacore::serve::{
    cluster as cluster_tier, loadgen as lg, serve as serve_tcp, supervise, Client,
    ClusterConfig, ClusterHandle, LoadgenConfig, ServeConfig, ServeHandle, WireStats,
};
use hadacore::util::bench::{BenchJson, BenchRecord, Stats};
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

/// With `--features count-alloc` the binary runs under the counting
/// allocator, so a self-hosted `loadgen` can measure (and gate on) the
/// serve path's per-request heap allocations. Pure delegation to the
/// system allocator otherwise — see [`hadacore::util::alloc`].
#[cfg(feature = "count-alloc")]
#[global_allocator]
static COUNTING_ALLOC: hadacore::util::alloc::CountingAlloc =
    hadacore::util::alloc::CountingAlloc;

fn main() -> anyhow::Result<()> {
    #[cfg(feature = "count-alloc")]
    hadacore::util::alloc::mark_installed();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match cmd.as_str() {
        "info" => info(argv),
        "transform" => transform(argv),
        "serve" => serve(argv),
        "cluster" => cluster_cmd(argv),
        "loadgen" => loadgen(argv),
        "stats" => stats_cmd(argv),
        "tables" => tables(argv),
        _ => {
            println!(
                "hadacore {} — matrix-unit-accelerated Hadamard transform server\n\n\
                 usage: hadacore <info|transform|serve|cluster|loadgen|stats|tables> [flags]\n\
                 run `hadacore <cmd> --help` for per-command flags",
                hadacore::VERSION
            );
            Ok(())
        }
    }
}

fn artifacts_flag(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts"))
}

/// The artifact dir for serving paths: `None` (native-only) when the flag
/// is empty or the manifest is absent — a fresh clone has no artifacts and
/// must still serve.
fn serving_artifacts(args: &Args) -> Option<PathBuf> {
    let dir = args.get("artifacts");
    if dir.is_empty() {
        return None;
    }
    let p = PathBuf::from(dir);
    p.join("manifest.json").exists().then_some(p)
}

fn info(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore info", "artifact + runtime inventory")
        .opt("artifacts", "artifacts", "artifact directory")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::open(artifacts_flag(&args))?;
    println!("platform: {}", rt.platform());
    let m = rt.manifest();
    println!(
        "model: dim={} heads={} layers={} vocab={} seq={}",
        m.model.dim, m.model.n_heads, m.model.n_layers, m.model.vocab, m.model.seq_len
    );
    let w = rt.weights()?;
    println!("weights: {} tensors, {} params", w.len(), w.param_count());
    println!("artifacts ({}):", m.artifacts.len());
    for a in &m.artifacts {
        println!(
            "  {:<28} op={:<11} inputs={} outputs={}",
            a.name,
            a.op,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn transform(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore transform", "one-off Hadamard transform")
        .opt("n", "256", "Hadamard size")
        .opt("rows", "4", "rows to transform")
        .opt("kernel", "hadacore", "kernel: hadacore|dao|scalar")
        .opt("artifacts", "artifacts", "artifact directory ('' = native only)")
        .switch("native", "force the native backend")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let n: usize = args.get_as("n");
    let rows: usize = args.get_as("rows");
    let kernel = KernelKind::parse(&args.get("kernel"))
        .ok_or_else(|| anyhow::anyhow!("bad --kernel"))?;

    let coord = Coordinator::start(serving_artifacts(&args), CoordinatorConfig::default())?;

    let mut rng = Rng::new(0);
    let mut req = TransformRequest::new(0, n, rng.normal_vec(rows * n));
    req.kernel = kernel;
    req.force_native = args.flag("native");
    let t0 = Instant::now();
    let resp = coord.transform(req)?;
    println!(
        "transformed {rows}x{n} via {} in {:?} (queue {}us, exec {}us, batch rows {})",
        resp.backend,
        t0.elapsed(),
        resp.queue_us,
        resp.exec_us,
        resp.batch_rows
    );
    println!("first 8 outputs: {:?}", &resp.data[..8.min(resp.data.len())]);
    coord.shutdown();
    Ok(())
}

/// Shared engine-config plumbing for the serving subcommands.
fn exec_config(args: &Args) -> ExecConfig {
    ExecConfig::with_lanes(args.get_as("exec-threads"))
}

/// Start the optional HTTP `/metrics` listener (`--metrics-addr`); the
/// returned handle must stay alive for the command's lifetime.
fn metrics_listener(args: &Args) -> anyhow::Result<Option<MetricsHandle>> {
    let addr = args.get("metrics-addr");
    if addr.is_empty() {
        return Ok(None);
    }
    let handle = serve_metrics(&addr)?;
    println!("metrics exposition on http://{}/metrics", handle.addr());
    Ok(Some(handle))
}

/// Plain-sockets `GET /metrics` against our own listener: the loadgen
/// smoke proves the HTTP path end to end, not just the registry render.
fn http_get_metrics(addr: &str) -> anyhow::Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    s.write_all(b"GET /metrics HTTP/1.1\r\nhost: hadacore\r\nconnection: close\r\n\r\n")
        .map_err(|e| anyhow::anyhow!("write: {e}"))?;
    let mut raw = String::new();
    s.read_to_string(&mut raw).map_err(|e| anyhow::anyhow!("read: {e}"))?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed http response"))?;
    if !head.starts_with("HTTP/1.1 200") {
        anyhow::bail!("GET /metrics: {}", head.lines().next().unwrap_or(""));
    }
    Ok(body.to_string())
}

fn serve(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore serve", "TCP transform server (wire protocol v1)")
        .opt("addr", "127.0.0.1:7380", "bind address (port 0 = ephemeral)")
        .opt("artifacts", "artifacts", "artifact directory ('' = native only)")
        .opt("workers", "4", "batcher worker threads")
        .opt("exec-threads", "0", "engine compute lanes (0 = default: per-core, capped at 16)")
        .opt("max-conns", "64", "connection-handler pool bound")
        .opt("max-inflight", "256", "global in-flight request cap")
        .opt("pipeline", "32", "per-connection pipelining cap")
        .opt("max-queued-rows", "8192", "shed (Busy) when the batcher queues more rows")
        .opt("metrics-addr", "", "HTTP GET /metrics listener address ('' = off)")
        .opt("duration", "0", "seconds to serve (0 = until killed)")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let artifact_dir = serving_artifacts(&args);
    let backend = if artifact_dir.is_some() { "pjrt + native" } else { "native only" };
    let coord = Arc::new(Coordinator::start(
        artifact_dir,
        CoordinatorConfig {
            workers: args.get_as("workers"),
            exec: exec_config(&args),
            ..Default::default()
        },
    )?);
    let handle = serve_tcp(
        Arc::clone(&coord),
        ServeConfig {
            addr: args.get("addr"),
            max_conns: args.get_as("max-conns"),
            max_inflight: args.get_as("max-inflight"),
            pipeline_depth: args.get_as("pipeline"),
            max_queued_rows: args.get_as("max-queued-rows"),
            ..Default::default()
        },
    )?;
    println!("hadacore serving on {} ({backend})", handle.addr());
    let _metrics = metrics_listener(&args)?;

    let secs: u64 = args.get_as("duration");
    if secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));

    // graceful teardown: stop the front-end first (in-flight responses
    // flush to their connections), then drain the coordinator
    handle.shutdown();
    coord.drain();
    println!("{}", coord.metrics().snapshot().report());
    Ok(())
}

/// Launch one child `hadacore serve` backend on an ephemeral port and
/// parse its bound address off the "hadacore serving on …" banner. The
/// rest of the child's stdout is forwarded line-by-line with a
/// `[backend i]` prefix so fleet logs stay attributable.
fn spawn_backend(
    i: usize,
    workers: &str,
    exec_threads: &str,
    pipeline: &str,
) -> anyhow::Result<(std::process::Child, String)> {
    use std::io::BufRead;
    let exe = std::env::current_exe().map_err(|e| anyhow::anyhow!("current_exe: {e}"))?;
    let mut child = std::process::Command::new(exe)
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--artifacts",
            "",
            "--workers",
            workers,
            "--exec-threads",
            exec_threads,
            "--pipeline",
            pipeline,
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .map_err(|e| anyhow::anyhow!("spawn backend {i}: {e}"))?;
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow::anyhow!("backend {i}: no stdout"))?;
    let mut lines = std::io::BufReader::new(stdout).lines();
    let addr = loop {
        match lines.next() {
            Some(Ok(line)) => {
                if let Some(rest) = line.strip_prefix("hadacore serving on ") {
                    if let Some(addr) = rest.split_whitespace().next() {
                        break addr.to_string();
                    }
                }
                println!("[backend {i}] {line}");
            }
            Some(Err(e)) => {
                let _ = child.kill();
                return Err(anyhow::anyhow!("backend {i} stdout: {e}"));
            }
            None => {
                let _ = child.kill();
                return Err(anyhow::anyhow!("backend {i} exited before binding"));
            }
        }
    };
    std::thread::spawn(move || {
        for line in lines.map_while(Result::ok) {
            println!("[backend {i}] {line}");
        }
    });
    Ok((child, addr))
}

fn cluster_cmd(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new(
        "hadacore cluster",
        "routing proxy over N backend serve processes (wire protocol v1)",
    )
    .opt("addr", "127.0.0.1:7390", "proxy bind address (port 0 = ephemeral)")
    .opt("backends", "", "comma-separated addresses of already-running backends")
    .opt("spawn", "0", "spawn N child `hadacore serve` backends on ephemeral ports")
    .opt("workers", "4", "spawned backends: batcher worker threads")
    .opt("exec-threads", "0", "spawned backends: engine lanes (0 = default)")
    .opt(
        "pipeline",
        "256",
        "spawned backends: per-connection pipelining cap — the proxy \
         multiplexes every client over one upstream connection per \
         backend, so this should exceed the expected fleet in-flight",
    )
    .opt("max-inflight", "1024", "proxy-wide in-flight request cap")
    .opt("metrics-addr", "", "HTTP GET /metrics listener address ('' = off)")
    .opt("duration", "0", "seconds to run (0 = until killed)")
    .parse_from(argv)
    .map_err(|e| anyhow::anyhow!(e))?;

    let mut backends: Vec<String> = args.get_str_list("backends");
    let spawn: usize = args.get_as("spawn");
    let mut children = Vec::new();
    for i in 0..spawn {
        let (child, addr) = spawn_backend(
            i,
            &args.get("workers"),
            &args.get("exec-threads"),
            &args.get("pipeline"),
        )?;
        println!("spawned backend {i} on {addr}");
        backends.push(addr);
        children.push(child);
    }
    if backends.is_empty() {
        for mut c in children {
            let _ = c.kill();
        }
        anyhow::bail!("no backends: pass --backends addr,addr or --spawn N");
    }

    let handle = cluster_tier(ClusterConfig {
        addr: args.get("addr"),
        backends: backends.clone(),
        max_inflight: args.get_as("max-inflight"),
        ..Default::default()
    })
    .map_err(|e| {
        for c in children.iter_mut() {
            let _ = c.kill();
        }
        e
    })?;
    println!(
        "hadacore cluster proxy on {} fronting {} backends: {}",
        handle.addr(),
        backends.len(),
        backends.join(", ")
    );
    let _metrics = metrics_listener(&args)?;

    // self-healing supervisor over the *owned* children: a child that
    // exits (crash, OOM kill) is respawned on a fresh ephemeral port and
    // handed back to routing via replace_backend. Externally-managed
    // --backends slots are left alone.
    let handle = Arc::new(handle);
    let children = Arc::new(std::sync::Mutex::new(children));
    let supervisor = if spawn > 0 {
        let owned_from = backends.len() - spawn;
        let alive_children = Arc::clone(&children);
        let respawn_children = Arc::clone(&children);
        let (workers, exec_threads, pipeline) =
            (args.get("workers"), args.get("exec-threads"), args.get("pipeline"));
        Some(supervise(
            &handle,
            Duration::from_millis(500),
            move |i| {
                if i < owned_from {
                    return true;
                }
                // try_wait: Ok(None) = still running; an exited or
                // unwaitable child is dead either way
                let mut kids = alive_children.lock().unwrap();
                matches!(kids[i - owned_from].try_wait(), Ok(None))
            },
            move |i| match spawn_backend(i, &workers, &exec_threads, &pipeline) {
                Ok((child, addr)) => {
                    println!("supervisor: respawned backend {i} on {addr}");
                    let mut kids = respawn_children.lock().unwrap();
                    let mut old = std::mem::replace(&mut kids[i - owned_from], child);
                    drop(kids);
                    // reap the corpse (it already exited; kill is a no-op
                    // that tolerates the race where it hasn't quite)
                    let _ = old.kill();
                    let _ = old.wait();
                    Some(addr)
                }
                Err(e) => {
                    eprintln!("supervisor: respawn backend {i} failed: {e}");
                    None
                }
            },
        )?)
    } else {
        None
    };

    let secs: u64 = args.get_as("duration");
    if secs == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));

    // stop the supervisor first (no respawns during teardown), then the
    // proxy (relays flush their in-flight replies), then the owned
    // children
    if let Some(s) = supervisor {
        s.shutdown();
    }
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    for c in children.lock().unwrap().iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
    Ok(())
}

/// Look up one counter in a proxy stats frame (0 when absent).
fn stat(stats: &WireStats, key: &str) -> u64 {
    stats
        .counters
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| *v)
        .unwrap_or(0)
}

fn loadgen(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore loadgen", "open-loop TCP load generator")
        .opt("addr", "", "server address ('' = self-host an in-process server)")
        .opt("qps", "2000", "offered load across all connections (0 = unpaced)")
        .opt("requests", "2000", "requests per traffic mix")
        .opt("clients", "4", "client connections")
        .opt(
            "mixes",
            "mixed",
            "comma-separated traffic mixes \
             (interactive|batch|llama-ffn|quantized|int8-grouped|mixed)",
        )
        .opt("dtype", "float32", "wire dtype: float32|float16|bfloat16")
        .opt("kernel", "hadacore", "kernel: hadacore|dao|scalar")
        .opt(
            "json",
            "BENCH_PR7.json",
            "perf-trajectory output path (--cluster defaults to BENCH_PR9.json)",
        )
        .opt("workers", "4", "self-hosted server: batcher workers")
        .opt("exec-threads", "0", "self-hosted server: engine lanes (0 = default)")
        .switch(
            "cluster",
            "drive a sharded fleet behind the routing proxy instead of one \
             server; '' --addr self-hosts the whole fleet in-process, a \
             non-empty --addr points at a running `hadacore cluster` proxy. \
             Emits fleet-wide and per-backend records",
        )
        .opt("cluster-backends", "3", "--cluster self-host: backend count")
        .opt(
            "metrics-addr",
            "",
            "HTTP GET /metrics listener ('' = off); the run self-scrapes \
             it afterwards and prints the exposition (the CI smoke grep)",
        )
        .opt(
            "trace-every",
            "0",
            "stamp a span-trace id on every Nth request per connection \
             (0 = off); buffered spans are dumped after the run",
        )
        .switch("smoke", "tiny CI run (few requests, unpaced)")
        .switch(
            "assert-zero-alloc",
            "fail unless the measured (post-warmup) run performed zero \
             server-side heap allocations; needs --features count-alloc \
             and the self-hosted server ('' addr)",
        )
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let dtype = DType::parse(&args.get("dtype"))
        .ok_or_else(|| anyhow::anyhow!("bad --dtype"))?;
    let kernel = KernelKind::parse(&args.get("kernel"))
        .ok_or_else(|| anyhow::anyhow!("bad --kernel"))?;
    let (requests, qps): (usize, f64) = if args.flag("smoke") {
        (120, 0.0)
    } else {
        (args.get_as("requests"), args.get_as("qps"))
    };
    let cluster_mode = args.flag("cluster");
    let assert_zero = args.flag("assert-zero-alloc");
    if assert_zero {
        if cluster_mode {
            anyhow::bail!(
                "--assert-zero-alloc covers the single-server path; the \
                 proxy's failover bookkeeping allocates by design, so the \
                 two flags don't compose"
            );
        }
        if !args.get("addr").is_empty() {
            anyhow::bail!(
                "--assert-zero-alloc measures in-process server threads; \
                 it requires the self-hosted server (leave --addr empty)"
            );
        }
        if !hadacore::util::alloc::is_counting() {
            anyhow::bail!(
                "--assert-zero-alloc needs the counting allocator: \
                 rebuild with `--features count-alloc`"
            );
        }
    }

    let metrics = metrics_listener(&args)?;
    let trace_every: usize = args.get_as("trace-every");

    // '' = self-host: bind an ephemeral in-process server (or, with
    // --cluster, a whole fleet behind the routing proxy) so one command
    // exercises the full stack (the CI smoke paths)
    let mut selfhost: Option<(Arc<Coordinator>, ServeHandle)> = None;
    let mut fleet: Vec<(Arc<Coordinator>, ServeHandle)> = Vec::new();
    let mut proxy: Option<ClusterHandle> = None;
    let addr = {
        let a = args.get("addr");
        if !a.is_empty() {
            a
        } else if cluster_mode {
            let n: usize = args.get_as("cluster-backends");
            let n = n.max(1);
            for _ in 0..n {
                let coord = Arc::new(Coordinator::start(
                    None,
                    CoordinatorConfig {
                        workers: args.get_as("workers"),
                        exec: exec_config(&args),
                        ..Default::default()
                    },
                )?);
                // the proxy funnels every client through one upstream
                // connection per backend, so the per-connection
                // pipelining cap must absorb the fleet-wide in-flight
                let handle = serve_tcp(
                    Arc::clone(&coord),
                    ServeConfig {
                        pipeline_depth: 256,
                        max_inflight: 1024,
                        ..Default::default()
                    },
                )?;
                fleet.push((coord, handle));
            }
            let handle = cluster_tier(ClusterConfig {
                backends: fleet.iter().map(|(_, h)| h.addr().to_string()).collect(),
                ..Default::default()
            })?;
            let addr = handle.addr().to_string();
            println!("self-hosted cluster: proxy on {addr} fronting {n} backends");
            proxy = Some(handle);
            addr
        } else {
            let coord = Arc::new(Coordinator::start(
                None,
                CoordinatorConfig {
                    workers: args.get_as("workers"),
                    exec: exec_config(&args),
                    ..Default::default()
                },
            )?);
            let handle = serve_tcp(Arc::clone(&coord), ServeConfig::default())?;
            let addr = handle.addr().to_string();
            println!("self-hosted server on {addr}");
            selfhost = Some((coord, handle));
            addr
        }
    };

    // in cluster mode the per-backend records are deltas of the proxy's
    // stats frame across the run, so both the self-hosted and the
    // remote-proxy paths report the same way
    let stats_client = if cluster_mode { Some(Client::connect(&addr)?) } else { None };
    let stats_before = match &stats_client {
        Some(c) => Some(c.stats()?),
        None => None,
    };
    let run_start = Instant::now();
    let mut fleet_latencies: Vec<f64> = Vec::new();

    let mut out = BenchJson::new();
    for name in args.get_str_list("mixes") {
        let mut workload = traffic_mix(&name).ok_or_else(|| {
            anyhow::anyhow!("unknown mix {name:?}; known: {}", TRAFFIC_MIXES.join(", "))
        })?;
        workload.kernel = kernel;
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            mix: name,
            workload,
            qps,
            requests,
            clients: args.get_as("clients"),
            dtype,
            trace_every,
            ..Default::default()
        };
        // warmup pass: populate the buffer-pool shelves, batcher spare
        // vectors, and per-thread scratch so the measured run sees the
        // steady state the zero-alloc gate is defined over (unpaced —
        // warmup throughput is not a measurement)
        let warmup = LoadgenConfig {
            requests: (cfg.requests / 4).max(40),
            qps: 0.0,
            ..cfg.clone()
        };
        let _ = lg::run(&warmup)?;
        let report = lg::run(&cfg)?;
        println!("{}", report.line());
        if report.alloc_counting {
            println!(
                "{:<12} allocs/req {:.3}  ({} allocs, {} bytes over {} ok, post-warmup)",
                report.mix,
                report.allocs_per_request(),
                report.alloc_allocs,
                report.alloc_bytes,
                report.ok,
            );
        }
        if report.ok == 0 {
            anyhow::bail!("mix {}: no successful responses", cfg.mix);
        }
        if assert_zero && report.alloc_allocs > 0 {
            anyhow::bail!(
                "mix {}: {} server-side heap allocations over {} requests \
                 after warmup (expected 0)",
                cfg.mix,
                report.alloc_allocs,
                report.ok,
            );
        }
        let mut rec = report.to_record(&cfg);
        if cluster_mode {
            fleet_latencies.extend_from_slice(&report.latencies_us);
            rec = rec.with_extra("cluster", 1.0);
        }
        out.push(rec);
    }

    // cluster mode: per-backend and fleet-wide records from the delta of
    // the proxy's stats frame across the run (warmup traffic included —
    // the throughput is an over-the-whole-run average)
    if let (Some(c), Some(before)) = (&stats_client, &stats_before) {
        let after = c.stats()?;
        let wall = run_start.elapsed().as_secs_f64().max(1e-9);
        let kernel_name = args.get("kernel");
        let dtype_name = args.get("dtype");
        let clients: usize = args.get_as("clients");
        let nb = stat(&after, "proxy.backends") as usize;
        let mut total_elems = 0u64;
        for i in 0..nb {
            let delta = |key: &str| {
                let k = format!("backend{i}.{key}");
                stat(&after, &k).saturating_sub(stat(before, &k))
            };
            let elems = delta("elems");
            total_elems += elems;
            // the proxy's per-backend histogram is cumulative, so the
            // percentiles are whole-lifetime; a backend that served
            // nothing records the positive-throughput floor
            let p50 = stat(&after, &format!("backend{i}.p50_us")).max(1) as f64;
            let s = Stats::from_sorted_us(&format!("cluster-backend{i}"), &[p50]);
            let melems = (elems as f64 / wall / 1e6).max(f64::MIN_POSITIVE);
            out.push(
                BenchRecord::serving(
                    "cluster-backend",
                    &kernel_name,
                    1,
                    1,
                    &dtype_name,
                    clients,
                    s,
                    melems,
                )
                .with_extra("backend_index", i as f64)
                .with_extra("forwarded", delta("forwarded") as f64)
                .with_extra("responses", delta("responses") as f64)
                .with_extra("p90_us", stat(&after, &format!("backend{i}.p90_us")) as f64)
                .with_extra("p99_us", stat(&after, &format!("backend{i}.p99_us")) as f64),
            );
        }
        fleet_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = if fleet_latencies.is_empty() {
            Stats::from_sorted_us("cluster-fleet", &[1.0])
        } else {
            Stats::from_sorted_us("cluster-fleet", &fleet_latencies)
        };
        let melems = (total_elems as f64 / wall / 1e6).max(f64::MIN_POSITIVE);
        let pdelta =
            |key: &str| stat(&after, key).saturating_sub(stat(before, key)) as f64;
        out.push(
            BenchRecord::serving(
                "cluster-fleet",
                &kernel_name,
                1,
                1,
                &dtype_name,
                clients,
                s,
                melems,
            )
            .with_extra("cluster_backends", nb as f64)
            .with_extra("cluster_forwarded", pdelta("proxy.forwarded"))
            .with_extra("cluster_retries", pdelta("proxy.retries"))
            .with_extra("cluster_deferrals", pdelta("proxy.deferrals"))
            .with_extra("cluster_busy_out", pdelta("proxy.busy_out"))
            .with_extra("cluster_responses", pdelta("proxy.responses")),
        );
        println!("{}", after.report.trim_end());
    }

    // observability smoke: prove the HTTP scrape path end to end and
    // print buffered span chains, so CI can grep both from one run
    if let Some(m) = &metrics {
        let body = http_get_metrics(m.addr())?;
        println!("--- metrics scrape ({} bytes) ---", body.len());
        print!("{body}");
        println!("--- end metrics scrape ---");
    }
    if trace_every > 0 {
        let c = Client::connect(&addr)?;
        let events = c.trace_dump(0)?;
        println!("--- trace dump: {} span events ---", events.len());
        for e in &events {
            println!(
                "trace {:#018x} span {:<12} arg={} t={}us",
                e.trace,
                e.stage.name(),
                e.arg,
                e.t_us
            );
        }
        println!("--- end trace dump ---");
    }

    let mut json_path = args.get("json");
    if cluster_mode && json_path == "BENCH_PR7.json" {
        // the flag default is the single-server trajectory; cluster runs
        // feed their own file unless the user pointed somewhere explicit
        json_path = "BENCH_PR9.json".to_string();
    }
    let path = BenchJson::output_path(&json_path);
    let count = out.write(&path).map_err(|e| anyhow::anyhow!(e))?;
    println!("wrote {count} loadgen records to {path}");

    drop(stats_client);
    if let Some(handle) = proxy {
        handle.shutdown();
    }
    for (coord, handle) in fleet {
        handle.shutdown();
        coord.drain();
    }
    if let Some((coord, handle)) = selfhost {
        handle.shutdown();
        coord.drain();
        println!("{}", coord.metrics().snapshot().report());
    }
    Ok(())
}

fn stats_cmd(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new(
        "hadacore stats <addr>",
        "scrape a running server or cluster proxy over the wire protocol",
    )
    .opt("addr", "", "target address (or pass it as the positional argument)")
    .opt(
        "trace",
        "",
        "dump buffered span events instead of metrics: a trace id \
         (decimal or 0x-hex) or 'all'",
    )
    .parse_from(argv)
    .map_err(|e| anyhow::anyhow!(e))?;
    let addr = {
        let a = args.get("addr");
        if !a.is_empty() {
            a
        } else if let Some(p) = args.positional().first() {
            p.clone()
        } else {
            anyhow::bail!("usage: hadacore stats <addr> [--trace <id|all>]");
        }
    };
    let client = Client::connect(&addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let trace = args.get("trace");
    if trace.is_empty() {
        print!("{}", client.stats_text()?);
        return Ok(());
    }
    let want: u64 = if trace == "all" {
        0
    } else if let Some(hex) = trace.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
            .map_err(|e| anyhow::anyhow!("bad --trace {trace:?}: {e}"))?
    } else {
        trace
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --trace {trace:?}: {e}"))?
    };
    let events = client.trace_dump(want)?;
    println!("{} span events", events.len());
    for e in &events {
        println!(
            "trace {:#018x} span {:<12} arg={} t={}us",
            e.trace,
            e.stage.name(),
            e.arg,
            e.t_us
        );
    }
    Ok(())
}

fn tables(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore tables", "modelled paper tables")
        .opt("device", "a100", "a100|h100")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let dev = match args.get("device").as_str() {
        "h100" => &H100_PCIE,
        _ => &A100_PCIE,
    };
    let grid = speedup_grid(dev, GridConfig::default());
    let runtime: Vec<(usize, usize, f64)> =
        grid.iter().map(|c| (c.n, c.elems, c.hadacore_us)).collect();
    let speedup: Vec<(usize, usize, f64)> =
        grid.iter().map(|c| (c.n, c.elems, c.speedup())).collect();
    println!(
        "{}",
        format_runtime_table(
            &format!("{} HadaCore runtime (µs, modelled)", dev.name),
            runtime
        )
    );
    println!(
        "{}",
        format_speedup_table(
            &format!("{} speedup vs baseline (modelled)", dev.name),
            speedup
        )
    );
    Ok(())
}
