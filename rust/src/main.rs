//! `hadacore` — the coordinator binary.
//!
//! Subcommands:
//!
//! * `info`      — artifact inventory, platform, weight stats.
//! * `transform` — one-off transform from the CLI (native or PJRT).
//! * `serve`     — run the coordinator against a synthetic workload and
//!                 print the serving metrics (the e2e smoke path).
//! * `tables`    — regenerate the paper's evaluation tables from the GPU
//!                 model (see also `examples/paper_tables.rs`).

use std::path::PathBuf;
use std::time::Instant;

use hadacore::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use hadacore::exec::ExecConfig;
use hadacore::gpu_model::{speedup_grid, GridConfig, A100_PCIE, H100_PCIE};
use hadacore::hadamard::KernelKind;
use hadacore::harness::tables::{format_runtime_table, format_speedup_table};
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::runtime::Runtime;
use hadacore::util::cli::Args;
use hadacore::util::error as anyhow;
use hadacore::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match cmd.as_str() {
        "info" => info(argv),
        "transform" => transform(argv),
        "serve" => serve(argv),
        "tables" => tables(argv),
        _ => {
            println!(
                "hadacore {} — matrix-unit-accelerated Hadamard transform server\n\n\
                 usage: hadacore <info|transform|serve|tables> [flags]\n\
                 run `hadacore <cmd> --help` for per-command flags",
                hadacore::VERSION
            );
            Ok(())
        }
    }
}

fn artifacts_flag(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts"))
}

/// The artifact dir for serving paths: `None` (native-only) when the flag
/// is empty or the manifest is absent — a fresh clone has no artifacts and
/// must still serve.
fn serving_artifacts(args: &Args) -> Option<PathBuf> {
    let dir = args.get("artifacts");
    if dir.is_empty() {
        return None;
    }
    let p = PathBuf::from(dir);
    p.join("manifest.json").exists().then_some(p)
}

fn info(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore info", "artifact + runtime inventory")
        .opt("artifacts", "artifacts", "artifact directory")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::open(artifacts_flag(&args))?;
    println!("platform: {}", rt.platform());
    let m = rt.manifest();
    println!(
        "model: dim={} heads={} layers={} vocab={} seq={}",
        m.model.dim, m.model.n_heads, m.model.n_layers, m.model.vocab, m.model.seq_len
    );
    let w = rt.weights()?;
    println!("weights: {} tensors, {} params", w.len(), w.param_count());
    println!("artifacts ({}):", m.artifacts.len());
    for a in &m.artifacts {
        println!(
            "  {:<28} op={:<11} inputs={} outputs={}",
            a.name,
            a.op,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn transform(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore transform", "one-off Hadamard transform")
        .opt("n", "256", "Hadamard size")
        .opt("rows", "4", "rows to transform")
        .opt("kernel", "hadacore", "kernel: hadacore|dao|scalar")
        .opt("artifacts", "artifacts", "artifact directory ('' = native only)")
        .switch("native", "force the native backend")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let n: usize = args.get_as("n");
    let rows: usize = args.get_as("rows");
    let kernel = KernelKind::parse(&args.get("kernel"))
        .ok_or_else(|| anyhow::anyhow!("bad --kernel"))?;

    let coord = Coordinator::start(serving_artifacts(&args), CoordinatorConfig::default())?;

    let mut rng = Rng::new(0);
    let mut req = TransformRequest::new(0, n, rng.normal_vec(rows * n));
    req.kernel = kernel;
    req.force_native = args.flag("native");
    let t0 = Instant::now();
    let resp = coord.transform(req)?;
    println!(
        "transformed {rows}x{n} via {} in {:?} (queue {}us, exec {}us, batch rows {})",
        resp.backend,
        t0.elapsed(),
        resp.queue_us,
        resp.exec_us,
        resp.batch_rows
    );
    println!("first 8 outputs: {:?}", &resp.data[..8.min(resp.data.len())]);
    coord.shutdown();
    Ok(())
}

fn serve(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore serve", "synthetic serving workload")
        .opt("requests", "2000", "number of requests")
        .opt("artifacts", "artifacts", "artifact directory ('' = native only)")
        .opt("sizes", "128,256,1024,4096", "Hadamard size mix")
        .opt("workers", "4", "batcher worker threads")
        .opt("exec-threads", "0", "engine compute lanes (0 = default: per-core, capped at 16)")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let total: usize = args.get_as("requests");
    let artifact_dir = serving_artifacts(&args);

    let lanes: usize = args.get_as("exec-threads");
    let exec = if lanes == 0 {
        ExecConfig::default()
    } else {
        ExecConfig { threads: lanes, ..ExecConfig::default() }
    };
    let coord = Coordinator::start(
        artifact_dir,
        CoordinatorConfig {
            workers: args.get_as("workers"),
            exec,
            ..Default::default()
        },
    )?;
    let mut wl = ServingWorkload::new(WorkloadConfig {
        sizes: args.get_list("sizes"),
        ..Default::default()
    });

    println!("serving {total} requests...");
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(total);
    for _ in 0..total {
        handles.push(coord.submit(wl.next_request()).map_err(|e| anyhow::anyhow!(e))?);
    }
    let mut elems = 0usize;
    for h in handles {
        let resp = h.recv()??;
        elems += resp.data.len();
    }
    let dt = t0.elapsed();
    println!(
        "done: {total} requests / {:.2} M elements in {:?} = {:.0} req/s",
        elems as f64 / 1e6,
        dt,
        total as f64 / dt.as_secs_f64()
    );
    println!("{}", coord.metrics().snapshot().report());
    let es = coord.exec_engine().stats();
    println!(
        "engine:   {} lanes, {} sharded jobs ({} chunks), {} inline runs",
        coord.exec_engine().threads(),
        es.jobs,
        es.chunks,
        es.inline_runs
    );
    coord.shutdown();
    Ok(())
}

fn tables(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::new("hadacore tables", "modelled paper tables")
        .opt("device", "a100", "a100|h100")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let dev = match args.get("device").as_str() {
        "h100" => &H100_PCIE,
        _ => &A100_PCIE,
    };
    let grid = speedup_grid(dev, GridConfig::default());
    let runtime: Vec<(usize, usize, f64)> =
        grid.iter().map(|c| (c.n, c.elems, c.hadacore_us)).collect();
    let speedup: Vec<(usize, usize, f64)> =
        grid.iter().map(|c| (c.n, c.elems, c.speedup())).collect();
    println!(
        "{}",
        format_runtime_table(
            &format!("{} HadaCore runtime (µs, modelled)", dev.name),
            runtime
        )
    );
    println!(
        "{}",
        format_speedup_table(
            &format!("{} speedup vs baseline (modelled)", dev.name),
            speedup
        )
    );
    Ok(())
}
