//! Analytical GPU performance model for the paper's evaluation grids.
//!
//! **Why this exists** (DESIGN.md §Substitutions): the paper's evaluation
//! is wall-clock on A100-PCIe / H100-PCIe hardware that this environment
//! does not have (repro band 0/5). Rather than skip the experiments, this
//! module models both kernels' execution from first principles on
//! published device parameters and regenerates *every* table and figure.
//! The model is calibrated to reproduce the paper's qualitative structure,
//! not its exact cells:
//!
//! * launch-overhead floor (~1.6-2.3 µs) at small element counts;
//! * memory-bound linear scaling at large element counts (33.5M fp16
//!   elements ≈ 134 MB moved ≈ 87 µs at 1.56 TB/s — the table's corner);
//! * the L2-capacity cliff: the out-of-place baseline carries 2x the
//!   cache footprint, so it falls off L2 one octave of element count
//!   earlier than the in-place HadaCore — the paper's 8M (A100) / 16M
//!   (H100) speedup spike (Appendix B);
//! * the occupancy penalty of the baseline at small Hadamard sizes
//!   (`threads_per_row = n/8 <= 256`), which produces the paper's peak
//!   3.5x speedup at size 128;
//! * HadaCore's `ceil(log16 n)` round count, which produces the weak
//!   512 row and the 8K-pays-like-32K effect the paper's results notes
//!   call out;
//! * the BF16 conversion overhead on HadaCore (FP32 accumulate +
//!   down-convert, Appendix C).

pub mod grid;
pub mod kernels;
pub mod roofline;
pub mod specs;

pub use grid::{speedup_grid, GridCell, GridConfig, PAPER_ELEMENT_COUNTS, PAPER_SIZES};
pub use kernels::{dao_time_us, hadacore_time_us, KernelParams, Placement};
pub use specs::{DeviceSpec, GpuDType, A100_PCIE, H100_PCIE, L40S};
