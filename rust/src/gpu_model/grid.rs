//! The paper's evaluation grids (Figures 4-11).
//!
//! Sizes 128..32768 x element counts 512..33.5M — every (size, count)
//! cell with `count >= 4*size` (the paper's tables leave the top-left
//! triangle empty where fewer than a handful of rows exist).

use super::kernels::{dao_time_us, hadacore_time_us, KernelParams, Placement};
use super::specs::{DeviceSpec, GpuDType};

/// The paper's Hadamard-size axis.
pub const PAPER_SIZES: [usize; 9] =
    [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768];

/// The paper's element-count axis (2^9 .. 2^25).
pub const PAPER_ELEMENT_COUNTS: [usize; 17] = [
    512, 1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072, 262144, 524288,
    1048576, 2097152, 4194304, 8388608, 16777216, 33554432,
];

/// One grid cell.
#[derive(Clone, Copy, Debug)]
pub struct GridCell {
    /// Hadamard size.
    pub n: usize,
    /// Total element count.
    pub elems: usize,
    /// Baseline (Dao) modelled runtime, µs.
    pub dao_us: f64,
    /// HadaCore modelled runtime, µs.
    pub hadacore_us: f64,
}

impl GridCell {
    /// Speedup of HadaCore over the baseline (>1 = HadaCore faster).
    pub fn speedup(&self) -> f64 {
        self.dao_us / self.hadacore_us
    }
}

/// Grid generation configuration.
#[derive(Clone, Copy, Debug)]
pub struct GridConfig {
    /// Element dtype for both kernels.
    pub dtype: GpuDType,
    /// Baseline placement (the stock library is out-of-place; Fig 8/9
    /// patch it to in-place).
    pub dao_placement: Placement,
    /// HadaCore placement (in-place by default).
    pub hadacore_placement: Placement,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            dtype: GpuDType::F16,
            dao_placement: Placement::OutOfPlace,
            hadacore_placement: Placement::InPlace,
        }
    }
}

/// Generate the full evaluation grid for a device.
pub fn speedup_grid(dev: &DeviceSpec, cfg: GridConfig) -> Vec<GridCell> {
    let mut cells = Vec::new();
    for &n in &PAPER_SIZES {
        for &elems in &PAPER_ELEMENT_COUNTS {
            if elems < 4 * n {
                continue; // paper leaves these cells empty
            }
            let dao = dao_time_us(
                dev,
                n,
                elems,
                KernelParams { dtype: cfg.dtype, placement: cfg.dao_placement },
            );
            let hc = hadacore_time_us(
                dev,
                n,
                elems,
                KernelParams { dtype: cfg.dtype, placement: cfg.hadacore_placement },
            );
            cells.push(GridCell { n, elems, dao_us: dao, hadacore_us: hc });
        }
    }
    cells
}

/// In-place ablation grid (Fig 8/9): stock out-of-place baseline vs the
/// same baseline patched to in-place. Returns (n, elems, speedup) cells.
pub fn inplace_ablation_grid(
    dev: &DeviceSpec,
    dtype: GpuDType,
) -> Vec<(usize, usize, f64)> {
    let mut cells = Vec::new();
    for &n in &PAPER_SIZES {
        for &elems in &PAPER_ELEMENT_COUNTS {
            if elems < 4 * n {
                continue;
            }
            let oop = dao_time_us(
                dev,
                n,
                elems,
                KernelParams { dtype, placement: Placement::OutOfPlace },
            );
            let ip = dao_time_us(
                dev,
                n,
                elems,
                KernelParams { dtype, placement: Placement::InPlace },
            );
            cells.push((n, elems, oop / ip));
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::specs::{A100_PCIE, H100_PCIE};

    #[test]
    fn grid_covers_paper_cells() {
        let g = speedup_grid(&A100_PCIE, GridConfig::default());
        // 9 sizes x 17 counts minus the empty triangle
        let empty: usize = PAPER_SIZES
            .iter()
            .map(|&n| PAPER_ELEMENT_COUNTS.iter().filter(|&&e| e < 4 * n).count())
            .sum();
        assert_eq!(g.len(), 9 * 17 - empty);
        assert!(g.iter().all(|c| c.dao_us > 0.0 && c.hadacore_us > 0.0));
    }

    #[test]
    fn speedups_mostly_above_one_a100() {
        // paper Fig 6b: HadaCore wins nearly everywhere on A100
        let g = speedup_grid(&A100_PCIE, GridConfig::default());
        let wins = g.iter().filter(|c| c.speedup() > 0.97).count();
        assert!(
            wins as f64 / g.len() as f64 > 0.85,
            "only {wins}/{} cells at >=0.97x",
            g.len()
        );
        // and in the paper's typical band on the median cell
        let mut speedups: Vec<f64> = g.iter().map(|c| c.speedup()).collect();
        speedups.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = speedups[speedups.len() / 2];
        assert!(
            (0.95..2.2).contains(&median),
            "median speedup {median:.2} outside the paper's typical band"
        );
    }

    #[test]
    fn peak_speedup_at_size_128_large_counts() {
        let g = speedup_grid(&A100_PCIE, GridConfig::default());
        let peak = g
            .iter()
            .max_by(|a, b| a.speedup().partial_cmp(&b.speedup()).unwrap())
            .unwrap();
        assert_eq!(peak.n, 128, "paper's peak is the 128 row");
        assert!(peak.elems >= 1 << 22, "peak at large element counts");
        assert!(peak.speedup() > 2.5 && peak.speedup() < 6.0);
    }

    #[test]
    fn h100_grid_weaker_overall() {
        let a = speedup_grid(&A100_PCIE, GridConfig::default());
        let h = speedup_grid(&H100_PCIE, GridConfig::default());
        let mean = |g: &[GridCell]| {
            g.iter().map(|c| c.speedup()).sum::<f64>() / g.len() as f64
        };
        assert!(mean(&h) < mean(&a), "H100 {:.2} vs A100 {:.2}", mean(&h), mean(&a));
    }

    #[test]
    fn bf16_grid_same_shape_as_fp16() {
        let f = speedup_grid(&A100_PCIE, GridConfig::default());
        let b = speedup_grid(
            &A100_PCIE,
            GridConfig { dtype: GpuDType::BF16, ..Default::default() },
        );
        assert_eq!(f.len(), b.len());
        // paper appendix C: similar speedups for bf16
        for (cf, cb) in f.iter().zip(b.iter()) {
            assert!(
                (cf.speedup() / cb.speedup() - 1.0).abs() < 0.35,
                "n={} e={}: fp16 {:.2} vs bf16 {:.2}",
                cf.n,
                cf.elems,
                cf.speedup(),
                cb.speedup()
            );
        }
    }

    #[test]
    fn inplace_ablation_peaks_near_l2_capacity() {
        let cells = inplace_ablation_grid(&A100_PCIE, GpuDType::F16);
        // Appendix B: the in-place gain appears at 8M elements on A100
        // (16 MB in-place working set fits usable L2; the out-of-place
        // 32 MB one thrashes)
        let best = cells
            .iter()
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        assert_eq!(best.1, 8_388_608, "peak at 8M elements, got {}", best.1);
        assert!(best.2 > 1.3, "peak in-place gain {:.2}", best.2);
        // small workloads see no benefit
        let small: Vec<&(usize, usize, f64)> =
            cells.iter().filter(|c| c.1 <= 1 << 16).collect();
        assert!(small.iter().all(|c| (c.2 - 1.0).abs() < 0.05));
    }
}
