//! FLOP accounting and roofline analysis (paper §3.4, experiment E8).
//!
//! The paper's argument: HadaCore spends >= 2x the flops of the butterfly
//! (`16 m n ceil(log16 n)` vs `2 m n log2 n`) but wins because tensor
//! cores supply ~8x the throughput and the work needs far less shuffle
//! ALU traffic. This module derives those numbers for any configuration
//! and classifies each cell of the grid as memory- or compute-bound.

use super::specs::DeviceSpec;

/// FLOP counts for one (n, elems) configuration (paper §3.4 formulas).
#[derive(Clone, Copy, Debug)]
pub struct FlopReport {
    /// Hadamard size.
    pub n: usize,
    /// Total elements.
    pub elems: usize,
    /// Butterfly algorithm flops: `2 E log2 n`.
    pub butterfly_flops: f64,
    /// HadaCore flops: `32 E ceil(log16 n)` (two flops per MAC).
    pub hadacore_flops: f64,
}

impl FlopReport {
    /// Compute the report.
    pub fn new(n: usize, elems: usize) -> FlopReport {
        let e = elems as f64;
        let k = n.trailing_zeros();
        let rounds = (k / 4 + u32::from(k % 4 != 0)) as f64;
        FlopReport {
            n,
            elems,
            butterfly_flops: 2.0 * e * k as f64,
            hadacore_flops: 32.0 * e * rounds,
        }
    }

    /// HadaCore's flop overhead ratio (paper: >= 2x at power-of-16 sizes).
    pub fn flop_ratio(&self) -> f64 {
        self.hadacore_flops / self.butterfly_flops
    }
}

/// Bound classification of a kernel execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// Limited by DRAM/L2 bandwidth.
    Memory,
    /// Limited by arithmetic throughput.
    Compute,
}

/// Roofline classification for HadaCore at a given configuration.
pub fn hadacore_bound(dev: &DeviceSpec, n: usize, elems: usize) -> Bound {
    let r = FlopReport::new(n, elems);
    let bytes = 2.0 * elems as f64 * 2.0; // fp16 read+write
    let t_mem = bytes / dev.dram_bw;
    let t_comp = r.hadacore_flops / (dev.tensor_flops * 0.5);
    if t_mem >= t_comp {
        Bound::Memory
    } else {
        Bound::Compute
    }
}

/// Arithmetic intensity (flops/byte) of HadaCore at size n, fp16.
pub fn hadacore_intensity(n: usize) -> f64 {
    let r = FlopReport::new(n, n); // per-element basis
    r.hadacore_flops / (2.0 * n as f64 * 2.0)
}

/// The efficiency ratio the perf pass targets: achieved fraction of the
/// memory roofline for a measured runtime (µs) at a given configuration.
pub fn roofline_fraction(dev: &DeviceSpec, elems: usize, measured_us: f64) -> f64 {
    let bytes = 2.0 * elems as f64 * 2.0;
    let ideal_us = bytes / dev.dram_bw * 1e6;
    ideal_us / measured_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::specs::A100_PCIE;

    #[test]
    fn paper_flop_formulas() {
        // at power-of-16 sizes the ratio is exactly 16/log2(n) * log16(n)*2
        let r256 = FlopReport::new(256, 1 << 20);
        // butterfly: 2*E*8; hadacore: 32*E*2 => ratio 4
        assert!((r256.flop_ratio() - 4.0).abs() < 1e-12);
        let r4096 = FlopReport::new(4096, 1 << 20);
        // butterfly: 2*E*12; hadacore: 32*E*3 => ratio 4
        assert!((r4096.flop_ratio() - 4.0).abs() < 1e-12);
        // paper: "at least 2x the floating-point operations"
        for n in [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768] {
            assert!(FlopReport::new(n, 4096).flop_ratio() >= 2.0, "n={n}");
        }
    }

    #[test]
    fn hadacore_is_memory_bound_on_a100() {
        // the transform is streaming: on A100 every paper size is
        // memory-bound for HadaCore (tensor cores idle most of the time) —
        // which is exactly why beating the baseline requires bandwidth
        // efficiency, not flops
        for n in [256usize, 4096, 32768] {
            assert_eq!(
                hadacore_bound(&A100_PCIE, n, 1 << 22),
                Bound::Memory,
                "n={n}"
            );
        }
    }

    #[test]
    fn intensity_grows_with_rounds() {
        assert!(hadacore_intensity(32768) > hadacore_intensity(256));
        // but stays tiny compared to GEMM-class intensity (~100s)
        assert!(hadacore_intensity(32768) < 64.0);
    }

    #[test]
    fn roofline_fraction_sane() {
        // measured == ideal => fraction 1
        let bytes = 2.0 * (1 << 20) as f64 * 2.0;
        let ideal_us = bytes / A100_PCIE.dram_bw * 1e6;
        let f = roofline_fraction(&A100_PCIE, 1 << 20, ideal_us);
        assert!((f - 1.0).abs() < 1e-9);
        assert!(roofline_fraction(&A100_PCIE, 1 << 20, ideal_us * 2.0) < 0.51);
    }
}
