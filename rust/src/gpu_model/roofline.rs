//! FLOP accounting and roofline analysis (paper §3.4, experiment E8).
//!
//! The paper's argument: HadaCore spends >= 2x the flops of the butterfly
//! (`16 m n ceil(log16 n)` vs `2 m n log2 n`) but wins because tensor
//! cores supply ~8x the throughput and the work needs far less shuffle
//! ALU traffic. This module derives those numbers for any configuration
//! and classifies each cell of the grid as memory- or compute-bound.

use super::specs::DeviceSpec;

/// FLOP counts for one (n, elems) configuration (paper §3.4 formulas).
#[derive(Clone, Copy, Debug)]
pub struct FlopReport {
    /// Hadamard size.
    pub n: usize,
    /// Total elements.
    pub elems: usize,
    /// Butterfly algorithm flops: `2 E log2 n`.
    pub butterfly_flops: f64,
    /// HadaCore flops: `32 E ceil(log16 n)` (two flops per MAC).
    pub hadacore_flops: f64,
}

impl FlopReport {
    /// Compute the report.
    pub fn new(n: usize, elems: usize) -> FlopReport {
        let e = elems as f64;
        let k = n.trailing_zeros();
        let rounds = (k / 4 + u32::from(k % 4 != 0)) as f64;
        FlopReport {
            n,
            elems,
            butterfly_flops: 2.0 * e * k as f64,
            hadacore_flops: 32.0 * e * rounds,
        }
    }

    /// HadaCore's flop overhead ratio (paper: >= 2x at power-of-16 sizes).
    pub fn flop_ratio(&self) -> f64 {
        self.hadacore_flops / self.butterfly_flops
    }
}

/// Bound classification of a kernel execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// Limited by DRAM/L2 bandwidth.
    Memory,
    /// Limited by arithmetic throughput.
    Compute,
}

/// Roofline classification for HadaCore at a given configuration.
pub fn hadacore_bound(dev: &DeviceSpec, n: usize, elems: usize) -> Bound {
    let r = FlopReport::new(n, elems);
    let bytes = 2.0 * elems as f64 * 2.0; // fp16 read+write
    let t_mem = bytes / dev.dram_bw;
    let t_comp = r.hadacore_flops / (dev.tensor_flops * 0.5);
    if t_mem >= t_comp {
        Bound::Memory
    } else {
        Bound::Compute
    }
}

/// Arithmetic intensity (flops/byte) of HadaCore at size n, fp16.
pub fn hadacore_intensity(n: usize) -> f64 {
    let r = FlopReport::new(n, n); // per-element basis
    r.hadacore_flops / (2.0 * n as f64 * 2.0)
}

/// The efficiency ratio the perf pass targets: achieved fraction of the
/// memory roofline for a measured runtime (µs) at a given configuration.
pub fn roofline_fraction(dev: &DeviceSpec, elems: usize, measured_us: f64) -> f64 {
    let bytes = 2.0 * elems as f64 * 2.0;
    let ideal_us = bytes / dev.dram_bw * 1e6;
    ideal_us / measured_us
}

// ---------------------------------------------------------------------
// Round-fusion model (the part of the roofline that now drives a
// *runtime* decision — see `exec::tune`).
//
// The transform is memory-bound at serving sizes (`hadacore_bound`
// above), so its cost is dominated by how many times the buffer streams
// through the memory system: one read + one write per round traversal.
// Fusing `d` consecutive rounds per cache-blocked tile divides the pow2
// traversal count by `d` — the CPU realisation of the paper's
// keep-data-resident-across-rounds structure (GPU: register fragments
// chained through `mma` pairs; CPU: a tile that stays in L1/L2) —
// *provided the fused tile actually fits in cache*. These helpers give
// the tuner its seed: predicted traffic per depth, and the deepest
// depth whose tile fits a cache budget.

/// Main-memory traffic (bytes) of a planned HadaCore execution over
/// `elems` elements at fusion depth `depth`, assuming each fused
/// traversal streams the buffer once (read + write) and tiles stay
/// cache-resident within a traversal.
pub fn hadacore_traffic_bytes(
    n: usize,
    elems: usize,
    depth: usize,
    elem_bytes: usize,
) -> f64 {
    use crate::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
    let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
    2.0 * elems as f64 * elem_bytes as f64 * plan.passes_at(depth) as f64
}

/// Predicted upper-bound speedup of fusion depth `depth` over the
/// unfused schedule for a memory-bound execution: the traversal-count
/// ratio. Realised speedup is below this when tiles spill or compute
/// starts to bind.
pub fn fusion_speedup_bound(n: usize, depth: usize) -> f64 {
    use crate::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
    let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
    plan.passes_at(1) as f64 / plan.passes_at(depth) as f64
}

/// The model's seed for the autotuner: the deepest fusion depth (≤ the
/// plan's round count) whose fused-tile working set — tile bytes for
/// the f32 compute image, ×2 for the in-flight read+write halves —
/// fits `cache_bytes`. Depth 1 (no fusion, tile = 0) always fits.
pub fn recommend_fusion_depth(n: usize, cache_bytes: usize) -> usize {
    use crate::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
    recommend_fusion_depth_for(
        &HadaCorePlan::new(n, &HadaCoreConfig::default()),
        cache_bytes,
    )
}

/// [`recommend_fusion_depth`] over an already-built plan — what the
/// tuner's per-batch path uses (no plan construction, no allocation).
pub fn recommend_fusion_depth_for(
    plan: &crate::hadamard::hadacore::HadaCorePlan,
    cache_bytes: usize,
) -> usize {
    for depth in (1..=plan.max_fusion_depth()).rev() {
        if plan.fused_tile_elems(depth) * 4 * 2 <= cache_bytes {
            return depth;
        }
    }
    1
}

// ---------------------------------------------------------------------
// Lane-aware refinement (ISSUE 8): fusion trades memory passes for a
// cache-blocked compute schedule, so its payoff depends on how far the
// execution sits from the compute roofline — and the SIMD dispatch
// moved that roofline. A 16-lane AVX-512 butterfly retires ~16x the
// per-cycle work of the scalar loop, so the memory wall that justified
// depth-3 fusion for vector backends is *not* binding for the scalar
// fallback, where the compute floor is already above the single-pass
// memory time and fusing only shrinks the chunk rows the measured
// refinement can work with.

/// Modelled per-element memory cost of one buffer traversal
/// (read + write through the cache hierarchy), in nanoseconds.
pub const MEM_NS_PER_ELEM_PASS: f64 = 0.5;

/// Modelled per-element compute cost of one butterfly round at one
/// f32 lane, in nanoseconds. A backend with `l` lanes divides this.
pub const COMP_NS_PER_ELEM_ROUND: f64 = 2.0;

/// Lane-aware [`recommend_fusion_depth`]: the shallowest depth (within
/// the cache-budget recommendation) whose remaining memory time has
/// already dropped to the backend's compute floor — fusing deeper than
/// that cannot help, and shallower schedules give the measured
/// refinement more chunk granularity. Falls back to the cache-budget
/// depth when memory still binds at every admissible depth (the wide-
/// vector regime).
///
/// `lanes` is [`crate::hadamard::simd::Backend::lanes`] of the active
/// backend; `lanes == 1` models the scalar fallback.
pub fn recommend_fusion_depth_for_lanes(
    plan: &crate::hadamard::hadacore::HadaCorePlan,
    cache_bytes: usize,
    lanes: usize,
) -> usize {
    let cache_cap = recommend_fusion_depth_for(plan, cache_bytes);
    let rounds = plan.max_fusion_depth() as f64;
    let compute_ns = COMP_NS_PER_ELEM_ROUND * rounds / lanes.max(1) as f64;
    for depth in 1..=cache_cap {
        if MEM_NS_PER_ELEM_PASS * plan.passes_at(depth) as f64 <= compute_ns {
            return depth;
        }
    }
    cache_cap
}

/// [`recommend_fusion_depth_for_lanes`] by size — builds the default
/// plan (tests / one-off callers; the tuner uses the `_for_lanes` form
/// on its cached plan).
pub fn recommend_fusion_depth_lanes(n: usize, cache_bytes: usize, lanes: usize) -> usize {
    use crate::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
    recommend_fusion_depth_for_lanes(
        &HadaCorePlan::new(n, &HadaCoreConfig::default()),
        cache_bytes,
        lanes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::specs::A100_PCIE;

    #[test]
    fn paper_flop_formulas() {
        // at power-of-16 sizes the ratio is exactly 16/log2(n) * log16(n)*2
        let r256 = FlopReport::new(256, 1 << 20);
        // butterfly: 2*E*8; hadacore: 32*E*2 => ratio 4
        assert!((r256.flop_ratio() - 4.0).abs() < 1e-12);
        let r4096 = FlopReport::new(4096, 1 << 20);
        // butterfly: 2*E*12; hadacore: 32*E*3 => ratio 4
        assert!((r4096.flop_ratio() - 4.0).abs() < 1e-12);
        // paper: "at least 2x the floating-point operations"
        for n in [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768] {
            assert!(FlopReport::new(n, 4096).flop_ratio() >= 2.0, "n={n}");
        }
    }

    #[test]
    fn hadacore_is_memory_bound_on_a100() {
        // the transform is streaming: on A100 every paper size is
        // memory-bound for HadaCore (tensor cores idle most of the time) —
        // which is exactly why beating the baseline requires bandwidth
        // efficiency, not flops
        for n in [256usize, 4096, 32768] {
            assert_eq!(
                hadacore_bound(&A100_PCIE, n, 1 << 22),
                Bound::Memory,
                "n={n}"
            );
        }
    }

    #[test]
    fn intensity_grows_with_rounds() {
        assert!(hadacore_intensity(32768) > hadacore_intensity(256));
        // but stays tiny compared to GEMM-class intensity (~100s)
        assert!(hadacore_intensity(32768) < 64.0);
    }

    #[test]
    fn fusion_model_tracks_the_plan() {
        // 4096 = 16^3: three plain rounds; traffic scales with passes
        let t1 = hadacore_traffic_bytes(4096, 1 << 20, 1, 4);
        let t3 = hadacore_traffic_bytes(4096, 1 << 20, 3, 4);
        assert_eq!(t1, 3.0 * t3); // 3 traversals -> 1 traversal
        assert!((fusion_speedup_bound(4096, 3) - 3.0).abs() < 1e-12);
        // fusing beyond the round count saturates
        assert_eq!(
            fusion_speedup_bound(4096, 8),
            fusion_speedup_bound(4096, 3)
        );
        // non-pow2: the base pass is never fused away
        assert!((fusion_speedup_bound(14336, 2) - 3.0 / 2.0).abs() < 1e-12);
    }

    #[test]
    fn recommended_depth_respects_the_cache_budget() {
        // 4096: depth-2 tile = 256 elems (2 KiB working set), depth-3
        // tile = 4096 elems (32 KiB) — a 4 KiB budget stops at depth 2
        assert_eq!(recommend_fusion_depth(4096, 4 << 10), 2);
        assert_eq!(recommend_fusion_depth(4096, 1 << 20), 3);
        // a zero budget still returns the valid no-fusion depth
        assert_eq!(recommend_fusion_depth(4096, 0), 1);
        // 256 has two rounds with a 256-elem final tile: 1 MiB is plenty
        assert_eq!(recommend_fusion_depth(256, 1 << 20), 2);
        // 32768 at full fusion needs 256 KiB of tile; a 64 KiB budget
        // backs off to depth 2 (16 KiB tile)
        assert_eq!(recommend_fusion_depth(32768, 64 << 10), 2);
    }

    #[test]
    fn lane_aware_depth_tracks_the_compute_floor() {
        // n = 4096: three pow2 rounds, cache cap 3 at a 1 MiB budget.
        // Wide vectors (8/16 lanes): compute floor is far below even the
        // fully-fused single pass — memory binds everywhere, keep the
        // cache-cap depth.
        assert_eq!(recommend_fusion_depth_lanes(4096, 1 << 20, 16), 3);
        assert_eq!(recommend_fusion_depth_lanes(4096, 1 << 20, 8), 3);
        // NEON (4 lanes): compute 2.0*3/4 = 1.5 ns/elem equals the
        // unfused 3-pass memory time — depth 1 already sits on the
        // floor, fusion can't pay.
        assert_eq!(recommend_fusion_depth_lanes(4096, 1 << 20, 4), 1);
        // scalar: compute-bound outright at depth 1
        assert_eq!(recommend_fusion_depth_lanes(4096, 1 << 20, 1), 1);
        // the cache budget still caps the vector regime
        assert_eq!(
            recommend_fusion_depth_lanes(4096, 4 << 10, 16),
            recommend_fusion_depth(4096, 4 << 10)
        );
        // degenerate lanes=0 treated as scalar, never panics
        assert_eq!(
            recommend_fusion_depth_lanes(4096, 1 << 20, 0),
            recommend_fusion_depth_lanes(4096, 1 << 20, 1)
        );
    }

    #[test]
    fn roofline_fraction_sane() {
        // measured == ideal => fraction 1
        let bytes = 2.0 * (1 << 20) as f64 * 2.0;
        let ideal_us = bytes / A100_PCIE.dram_bw * 1e6;
        let f = roofline_fraction(&A100_PCIE, 1 << 20, ideal_us);
        assert!((f - 1.0).abs() < 1e-9);
        assert!(roofline_fraction(&A100_PCIE, 1 << 20, ideal_us * 2.0) < 0.51);
    }
}
