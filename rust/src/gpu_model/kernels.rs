//! Per-kernel analytical time models.
//!
//! Both models share the same skeleton:
//!
//! ```text
//! t = launch + max(t_memory, t_compute) + t_shuffle + t_sync
//! ```
//!
//! with kernel-specific occupancy, flop counts, and staging costs. The
//! constants are calibrated once against the paper's corner cells (see
//! gpu_model/mod.rs) and then *frozen*; the tests assert structural
//! properties, not cell values.

use super::specs::{DeviceSpec, GpuDType};

/// Whether the kernel writes its result over the input (Appendix B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Separate destination tensor (the stock Dao library default).
    OutOfPlace,
    /// Destination == source (HadaCore's default; the Appendix B patch).
    InPlace,
}

/// Model inputs common to both kernels.
#[derive(Clone, Copy, Debug)]
pub struct KernelParams {
    /// Element dtype.
    pub dtype: GpuDType,
    /// In-place vs out-of-place.
    pub placement: Placement,
}

impl KernelParams {
    /// The paper's default comparison: fp16, baseline out-of-place.
    pub fn fp16_oop() -> Self {
        KernelParams { dtype: GpuDType::F16, placement: Placement::OutOfPlace }
    }

    /// fp16, in-place.
    pub fn fp16_ip() -> Self {
        KernelParams { dtype: GpuDType::F16, placement: Placement::InPlace }
    }
}

/// Effective bandwidth given the working-set footprint.
///
/// L2-resident working sets stream at L2 speed. Above the usable capacity
/// (~70% of nominal — the rest is claimed by other allocations, exactly
/// the Appendix B caveat) the hit rate collapses with a thrash exponent:
/// pseudo-random replacement gives roughly `(usable/ws)^3` reuse, so a
/// working set slightly over capacity already loses most of the benefit —
/// the sharp cliff the paper measures at 8M (A100) / 16M (H100) elements.
fn effective_bw(dev: &DeviceSpec, footprint: f64) -> f64 {
    let usable = 0.7 * dev.l2_bytes;
    if footprint <= usable {
        return dev.l2_bw;
    }
    let hit = 0.9 * (usable / footprint).powi(3);
    1.0 / (hit / dev.l2_bw + (1.0 - hit) / dev.dram_bw)
}

/// Bandwidth fraction achievable at a given thread occupancy: DRAM
/// saturates around half occupancy on Ampere/Hopper-class parts.
fn bw_fraction(occupancy: f64) -> f64 {
    (occupancy / 0.5).min(1.0)
}

/// Dao `fast-hadamard-transform` baseline (paper §2.4).
///
/// Occupancy: the library assigns `threads_per_row = min(n/8, 256)` —
/// small transforms run in tiny threadblocks, and the per-SM resident
/// block limit then caps occupancy (25% at n=128). This is the mechanism
/// behind the paper's headline 3.5x speedup at size 128.
pub fn dao_time_us(dev: &DeviceSpec, n: usize, elems: usize, p: KernelParams) -> f64 {
    let es = p.dtype.size() as f64;
    let e = elems as f64;

    let threads_per_block = ((n as f64) / 8.0).clamp(1.0, 256.0);
    let resident_threads = (dev.blocks_per_sm * threads_per_block)
        .min(dev.threads_per_sm)
        .min(e / 8.0 / dev.sm_count); // grid too small to fill the device
    let occupancy = (resident_threads / dev.threads_per_sm).clamp(1e-3, 1.0);

    let footprint = match p.placement {
        Placement::OutOfPlace => 2.0 * e * es,
        Placement::InPlace => e * es,
    };
    let bytes_moved = 2.0 * e * es; // read + write regardless of placement
    let t_mem = bytes_moved / (effective_bw(dev, footprint) * bw_fraction(occupancy));

    // Butterfly arithmetic: each 2-element butterfly costs ~2 flops plus
    // the "complicated indexing to achieve its warp-level data shuffling"
    // the paper calls out (shuffle + address ALU), which holds the kernel
    // to a fraction of nominal CUDA flops. alu_overhead folds that in:
    // effective butterfly throughput ~ cuda_flops / 3.9 (~20 TFLOP-equiv
    // on A100 — calibrated against the paper's L2-resident columns where
    // the baseline is instruction-bound, not bandwidth-bound).
    let alu_overhead = 3.9;
    let flops = 2.0 * e * (n as f64).log2() * alu_overhead;
    let t_comp = flops / (dev.cuda_flops * (occupancy / 0.5).min(1.0));

    // block-wide syncs: the library needs 2 shared-memory exchanges for
    // transforms above what a warp covers (2048 elements per block)
    let syncs = if n > 2048 { 2.0 } else { 0.0 };
    let blocks = (e / 2048.0).max(1.0);
    let sync_visibility = (dev.sm_count * dev.blocks_per_sm / blocks).min(1.0);
    let t_sync = syncs * dev.block_sync_s * sync_visibility;

    // shared-memory transpose traffic for the two block-level exchanges
    let t_smem = if n > 2048 { 4.0 * e * es / dev.smem_bw } else { 0.0 };

    let bf16_penalty = if p.dtype == GpuDType::BF16 { 1.02 } else { 1.0 };
    (dev.launch_s + t_mem.max(t_comp) * bf16_penalty + t_smem + t_sync) * 1e6
}

/// HadaCore (paper §3).
///
/// `ceil(log16 n)` tensor-core rounds; a shared-memory transpose pass for
/// n > 256 (partially uncoalesced above 4K); flexible threadblock shapes
/// keep occupancy high until shared-memory capacity limits residency at
/// the largest sizes.
pub fn hadacore_time_us(
    dev: &DeviceSpec,
    n: usize,
    elems: usize,
    p: KernelParams,
) -> f64 {
    let es = p.dtype.size() as f64;
    let e = elems as f64;
    let rounds = {
        let k = n.trailing_zeros();
        (k / 4 + u32::from(k % 4 != 0)) as f64
    };

    // occupancy: flexible configs fill the device unless (a) the grid is
    // too small, or (b) double-buffered row staging exhausts shared memory
    let smem_per_block = 2.0 * (n as f64) * es; // double-buffered row
    let resident_blocks = (164e3 / smem_per_block).max(0.5);
    let smem_occ = (resident_blocks / 2.0).min(1.0);
    let fill = (e / 2048.0 / dev.sm_count).min(1.0); // 2048 elems per block
    let occupancy = smem_occ.min(fill.max(0.05)).clamp(1e-3, 1.0);

    let footprint = match p.placement {
        Placement::InPlace => e * es,
        Placement::OutOfPlace => 2.0 * e * es,
    };
    let bytes_moved = 2.0 * e * es;
    let t_mem = bytes_moved / (effective_bw(dev, footprint) * bw_fraction(occupancy));

    // tensor-core rounds: 32 flops/element/round at the mma level; the
    // kernel sustains ~50% of dense tensor throughput (register-resident
    // operands, no smem-staged MMA pipelining like GEMMs use)
    let tensor_eff = 0.5 * hopper_derate(dev);
    let flops = 32.0 * e * rounds;
    let t_comp = flops / (dev.tensor_flops * tensor_eff);

    // n > 256: one transpose pass through shared memory; above 4K the
    // coalescing scheme is only partial (paper results notes)
    let t_smem = if n > 256 {
        let coalesce_penalty = if n >= 8192 { 1.35 } else { 1.0 };
        2.0 * e * es * coalesce_penalty / dev.smem_bw
    } else {
        0.0
    };
    let syncs = if n > 256 { 1.0 } else { 0.0 };
    let blocks = (e / 2048.0).max(1.0);
    let sync_visibility = (dev.sm_count * dev.blocks_per_sm / blocks).min(1.0);
    let t_sync = syncs * dev.block_sync_s * sync_visibility;

    // Appendix C: BF16 accumulates in FP32 and converts back
    let bf16_penalty = if p.dtype == GpuDType::BF16 { 1.12 } else { 1.0 };

    (dev.launch_s + t_mem.max(t_comp * bf16_penalty) + t_smem + t_sync) * 1e6
}

/// The paper's H100 results are weaker than A100 ("we focused on
/// pre-Hopper GPUs"): HadaCore realises a smaller fraction of Hopper's
/// much larger tensor throughput. Modelled as a flat derate.
fn hopper_derate(dev: &DeviceSpec) -> f64 {
    if dev.name.starts_with("H100") {
        0.45
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::specs::{A100_PCIE, H100_PCIE};

    const MB33: usize = 33_554_432;

    #[test]
    fn launch_floor_at_small_element_counts() {
        for n in [128usize, 1024] {
            let t = hadacore_time_us(&A100_PCIE, n, 512, KernelParams::fp16_ip());
            assert!(t > 1.0 && t < 4.0, "n={n}: {t} µs (paper floor ~1.6-2.3)");
            let td = dao_time_us(&A100_PCIE, n, 512, KernelParams::fp16_oop());
            assert!(td > 1.0 && td < 6.0, "dao n={n}: {td}");
        }
    }

    #[test]
    fn memory_bound_corner_near_paper() {
        // paper A100 corner (33.5M elements): ~87-126 µs depending on size
        let t = hadacore_time_us(&A100_PCIE, 256, MB33, KernelParams::fp16_ip());
        assert!(t > 50.0 && t < 150.0, "corner {t} µs");
    }

    #[test]
    fn runtime_monotone_in_element_count() {
        for kernel in [true, false] {
            let mut last = 0.0;
            for k in 9..=25 {
                let e = 1usize << k;
                let t = if kernel {
                    hadacore_time_us(&A100_PCIE, 1024, e, KernelParams::fp16_ip())
                } else {
                    dao_time_us(&A100_PCIE, 1024, e, KernelParams::fp16_oop())
                };
                assert!(t >= last * 0.999, "e=2^{k}: {t} < {last}");
                last = t;
            }
        }
    }

    #[test]
    fn size_128_peak_speedup() {
        // the paper's headline: ~3.5x at size 128, large element counts
        let e = 8 * 1024 * 1024;
        let dao = dao_time_us(&A100_PCIE, 128, e, KernelParams::fp16_oop());
        let hc = hadacore_time_us(&A100_PCIE, 128, e, KernelParams::fp16_ip());
        let speedup = dao / hc;
        assert!(speedup > 2.0, "expected >2x at n=128/8M, got {speedup:.2}");
        assert!(speedup < 6.0, "unphysically large speedup {speedup:.2}");
    }

    #[test]
    fn size_512_weakest_mid_grid() {
        // the paper: 512 is the weakest speedup row (pays 3 rounds + sync)
        let e = 1 << 16;
        let s = |n: usize| {
            dao_time_us(&A100_PCIE, n, e, KernelParams::fp16_oop())
                / hadacore_time_us(&A100_PCIE, n, e, KernelParams::fp16_ip())
        };
        assert!(s(512) < s(128), "512 should be weaker than 128");
        assert!(s(512) < s(4096), "512 should be weaker than 4096");
        assert!(s(512) > 0.6, "512 should not collapse: {}", s(512));
    }

    #[test]
    fn rounds_penalty_8k_equals_32k() {
        // 8K pays the same 4 rounds as 32K (paper results note): its
        // compute term per element must match 32K's, not 4K's.
        let e = 1 << 22;
        let t4 = hadacore_time_us(&A100_PCIE, 4096, e, KernelParams::fp16_ip());
        let t8 = hadacore_time_us(&A100_PCIE, 8192, e, KernelParams::fp16_ip());
        assert!(t8 > t4, "8K pays a 4th round + coalescing penalty over 4K");
    }

    #[test]
    fn l2_cliff_creates_speedup_spike() {
        // out-of-place baseline falls off L2 one octave earlier: speedup
        // at 8M elements (16 MB in-place vs 32 MB oop on 40 MB L2) must
        // exceed speedup at 1M (both L2-resident) and be >= the 33M value
        // (both DRAM-bound)
        let s = |e: usize| {
            dao_time_us(&A100_PCIE, 256, e, KernelParams::fp16_oop())
                / hadacore_time_us(&A100_PCIE, 256, e, KernelParams::fp16_ip())
        };
        let spike = s(8 * 1024 * 1024);
        assert!(spike > s(1024 * 1024), "spike {spike} vs 1M {}", s(1024 * 1024));
        assert!(spike >= s(MB33) * 0.95, "spike {spike} vs 33M {}", s(MB33));
    }

    #[test]
    fn inplace_dao_helps_near_l2_capacity() {
        // Fig 8: patching the baseline to in-place gives its own speedup
        // around the L2 boundary
        let e = 16 * 1024 * 1024; // 32 MB in-place vs 64 MB oop
        let oop = dao_time_us(&A100_PCIE, 1024, e, KernelParams::fp16_oop());
        let ip = dao_time_us(&A100_PCIE, 1024, e, KernelParams::fp16_ip());
        assert!(oop / ip > 1.2, "in-place should win near capacity: {}", oop / ip);
        // far above capacity both are DRAM-bound
        let oop_big = dao_time_us(&A100_PCIE, 1024, MB33, KernelParams::fp16_oop());
        let ip_big = dao_time_us(&A100_PCIE, 1024, MB33, KernelParams::fp16_ip());
        assert!((oop_big / ip_big - 1.0).abs() < 0.15);
    }

    #[test]
    fn bf16_slightly_slower_than_fp16() {
        let e = 1 << 20;
        let f16 = hadacore_time_us(&A100_PCIE, 1024, e, KernelParams::fp16_ip());
        let bf16 = hadacore_time_us(
            &A100_PCIE,
            1024,
            e,
            KernelParams { dtype: GpuDType::BF16, placement: Placement::InPlace },
        );
        assert!(bf16 >= f16, "bf16 conversion overhead missing");
        assert!(bf16 < f16 * 1.3, "bf16 penalty too large");
    }

    #[test]
    fn h100_speedups_weaker_than_a100() {
        // paper: "The H100 results are overall worse than the A100 results"
        let e = 1 << 21;
        let s_a = dao_time_us(&A100_PCIE, 256, e, KernelParams::fp16_oop())
            / hadacore_time_us(&A100_PCIE, 256, e, KernelParams::fp16_ip());
        let s_h = dao_time_us(&H100_PCIE, 256, e, KernelParams::fp16_oop())
            / hadacore_time_us(&H100_PCIE, 256, e, KernelParams::fp16_ip());
        assert!(s_h < s_a * 1.05, "H100 {s_h:.2} should not beat A100 {s_a:.2}");
    }
}
