//! Device parameter tables (published A100-PCIe / H100-PCIe figures).

/// Element type on the modelled GPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpuDType {
    /// IEEE half (the paper's primary evaluation dtype).
    F16,
    /// bfloat16 (Appendix C).
    BF16,
}

impl GpuDType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        2
    }
}

/// One GPU's modelling parameters.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    /// Marketing name.
    pub name: &'static str,
    /// DRAM bandwidth achievable by a well-shaped kernel, bytes/s.
    pub dram_bw: f64,
    /// L2 cache capacity in bytes.
    pub l2_bytes: f64,
    /// Effective L2 bandwidth for streaming hits, bytes/s.
    pub l2_bw: f64,
    /// CUDA-core FP16 throughput, flop/s (the butterfly baseline's units).
    pub cuda_flops: f64,
    /// Tensor-core dense FP16 throughput, flop/s (HadaCore's units).
    pub tensor_flops: f64,
    /// Kernel launch + driver overhead, seconds.
    pub launch_s: f64,
    /// Cost of one threadblock-wide barrier + shared-memory exchange per
    /// resident block, seconds.
    pub block_sync_s: f64,
    /// Number of SMs (occupancy modelling).
    pub sm_count: f64,
    /// Resident threads per SM at full occupancy.
    pub threads_per_sm: f64,
    /// Max resident threadblocks per SM.
    pub blocks_per_sm: f64,
    /// Shared-memory/register shuffle bandwidth per device, bytes/s
    /// (bounds the transpose rounds of sizes > 256).
    pub smem_bw: f64,
}

/// A100-PCIe (GA100): 1.56 TB/s HBM2e, 40 MB L2, 78 TFLOPS FP16 CUDA,
/// 312 TFLOPS FP16 tensor core (dense), 108 SMs.
pub const A100_PCIE: DeviceSpec = DeviceSpec {
    name: "A100-PCIe",
    dram_bw: 1.40e12, // ~90% of 1.555 TB/s peak is a realistic stream rate
    l2_bytes: 40.0e6,
    l2_bw: 4.5e12,
    cuda_flops: 78.0e12,
    tensor_flops: 312.0e12,
    launch_s: 1.55e-6,
    block_sync_s: 0.15e-6,
    sm_count: 108.0,
    threads_per_sm: 2048.0,
    blocks_per_sm: 32.0,
    smem_bw: 35.0e12,
};

/// H100-PCIe (GH100): 2.0 TB/s HBM2e, 50 MB L2, ~96 TFLOPS FP16 CUDA,
/// ~756 TFLOPS FP16 tensor core dense (PCIe clocks), 114 SMs.
///
/// The paper notes its H100 results are weaker ("we focused on pre-Hopper
/// GPUs"): the kernel's load instructions and tile shapes are tuned for
/// Ampere, so HadaCore realises a smaller fraction of Hopper's tensor
/// throughput. `tensor_eff_hadacore` (in kernels.rs) carries that factor.
pub const H100_PCIE: DeviceSpec = DeviceSpec {
    name: "H100-PCIe",
    dram_bw: 1.80e12,
    l2_bytes: 50.0e6,
    l2_bw: 5.5e12,
    cuda_flops: 96.0e12,
    tensor_flops: 756.0e12,
    launch_s: 1.75e-6,
    block_sync_s: 0.15e-6,
    sm_count: 114.0,
    threads_per_sm: 2048.0,
    blocks_per_sm: 32.0,
    smem_bw: 40.0e12,
};

/// L40S (AD102): the third GPU the paper's Appendix B cites for L2
/// capacity (48 MB). 864 GB/s GDDR6, ~91 TFLOPS FP16 CUDA-equivalent,
/// 362 TFLOPS FP16 tensor dense, 142 SMs.
pub const L40S: DeviceSpec = DeviceSpec {
    name: "L40S",
    dram_bw: 0.78e12,
    l2_bytes: 48.0e6,
    l2_bw: 4.0e12,
    cuda_flops: 91.0e12,
    tensor_flops: 362.0e12,
    launch_s: 1.6e-6,
    block_sync_s: 0.15e-6,
    sm_count: 142.0,
    threads_per_sm: 1536.0,
    blocks_per_sm: 24.0,
    smem_bw: 30.0e12,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l40s_l2_between_a100_and_h100() {
        // Appendix B: "The H100, A100, and L40S have 50MB, 40MB, and 48MB"
        assert!(L40S.l2_bytes > A100_PCIE.l2_bytes);
        assert!(L40S.l2_bytes < H100_PCIE.l2_bytes);
        assert!(L40S.l2_bw > L40S.dram_bw);
    }

    #[test]
    fn specs_are_physical() {
        for d in [&A100_PCIE, &H100_PCIE] {
            assert!(d.l2_bw > d.dram_bw, "{}: L2 must beat DRAM", d.name);
            assert!(d.tensor_flops > d.cuda_flops, "{}: TC must beat CUDA", d.name);
            assert!(d.launch_s > 0.0 && d.launch_s < 1e-5);
            assert!(d.l2_bytes >= 40e6);
        }
        // paper: H100 has more L2 and bandwidth than A100
        assert!(H100_PCIE.l2_bytes > A100_PCIE.l2_bytes);
        assert!(H100_PCIE.dram_bw > A100_PCIE.dram_bw);
    }

    #[test]
    fn memory_bound_corner_matches_paper_scale() {
        // 33.5M fp16 elements: read+write = 134 MB; the paper's A100 corner
        // cells sit at ~87 µs -> implied ~1.55 TB/s. Our dram_bw must put
        // the modelled corner within 2x of that.
        let bytes = 2.0 * 33_554_432.0 * 2.0;
        let t_us = bytes / A100_PCIE.dram_bw * 1e6;
        assert!(t_us > 40.0 && t_us < 180.0, "corner {t_us} µs");
    }
}
