//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! The Rust side of the AOT bridge (see `python/compile/aot.py`): at startup
//! the [`Runtime`] reads `artifacts/manifest.json`, compiles each HLO-text
//! module on the PJRT CPU client, and exposes typed `execute_*` calls used
//! by the coordinator's hot path. Python never runs here.
//!
//! fwht artifacts implement the same per-row convention as the native
//! kernels — `x <- (x @ H_n) * (1/sqrt(n))`, with the orthonormal scale
//! baked into the compiled module (which is why custom-scale requests
//! route native; see `coordinator::TransformRequest::scale`).
//!
//! **Backend note:** this build resolves the `xla` surface to the
//! dependency-free host stub in [`pjrt`] — literals and manifests are
//! fully functional; compiling/executing artifacts reports a clean
//! error (a coordinator started *with* an artifact dir fails fast at
//! preload; started without one, it serves natively). Point the
//! [`xla`] alias at the real `xla` crate to enable artifact execution.

pub mod manifest;
pub mod pjrt;
pub mod tensor;
pub mod weights;

pub use manifest::{ArtifactEntry, Manifest, ModelMeta, TensorSpec};
pub use tensor::{literal_f32, literal_i32, literal_to_f32, Tensor};
pub use weights::Weights;

// The `xla` name every call site (and the integration tests) imports.
// Currently the host stub; point it at the real crate to enable PJRT.
pub use self::pjrt as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::error::{anyhow, Context, Result};

/// A compiled PJRT executable plus its manifest entry.
pub struct LoadedArtifact {
    /// Manifest metadata (shapes, op kind, bucket geometry).
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with raw literals; returns the tupled result unpacked into
    /// one literal per output.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.execute_refs(&refs)
    }

    /// Execute with borrowed literals (lets callers reuse large inputs —
    /// e.g. the LM weights — across calls without copying).
    pub fn execute_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("pjrt execute failed: {e:?}"))?;
        let first = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("pjrt returned no buffers"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync failed: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("result untuple failed: {e:?}"))?;
        Ok(parts)
    }

    /// Execute a single-f32-input / single-f32-output artifact.
    pub fn execute_f32(&self, input: &Tensor) -> Result<Tensor> {
        let lit = literal_f32(&input.data, &input.shape)?;
        let outs = self.execute(&[lit])?;
        let out = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("artifact produced no outputs"))?;
        let spec = &self.entry.outputs[0];
        Ok(Tensor { shape: spec.shape.clone(), data: literal_to_f32(&out)? })
    }
}

/// The PJRT runtime: one CPU client + all compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    // compiled lazily: HLO-text parse+compile costs ~10-100ms per module,
    // and most tools touch only a few artifacts.
    compiled: Mutex<HashMap<String, &'static LoadedArtifact>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact directory path.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) an artifact by manifest name.
    ///
    /// Returns a `'static` reference: compiled executables are leaked
    /// intentionally — they live for the process lifetime and are shared
    /// across worker threads without refcounting on the hot path.
    pub fn load(&self, name: &str) -> Result<&'static LoadedArtifact> {
        if let Some(a) = self.compiled.lock().unwrap().get(name) {
            return Ok(a);
        }
        let entry = self
            .manifest
            .artifacts
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no artifact named {name:?} in manifest"))?
            .clone();
        let path = self.dir.join(&entry.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let leaked: &'static LoadedArtifact =
            Box::leak(Box::new(LoadedArtifact { entry, exe }));
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), leaked);
        Ok(leaked)
    }

    /// Eagerly compile every artifact (server startup path). Returns count.
    pub fn load_all(&self) -> Result<usize> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|e| e.name.clone()).collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Load the trained weights referenced by the manifest.
    pub fn weights(&self) -> Result<Weights> {
        Weights::load(&self.dir, &self.manifest)
    }

    /// Find the fwht artifact entry for (kernel, n) if one was built.
    pub fn find_fwht(&self, kernel: &str, n: usize) -> Option<&ArtifactEntry> {
        self.manifest.artifacts.iter().find(|e| {
            e.op == "fwht" && e.kernel.as_deref() == Some(kernel) && e.n == Some(n)
        })
    }
}
