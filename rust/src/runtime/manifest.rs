//! `artifacts/manifest.json` parsing — the contract with `aot.py`.

use std::path::Path;

use crate::util::error::{anyhow, Context, Result};
use crate::util::f16::DType;
use crate::util::json::Json;

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: DType,
    /// For LM inputs: which named weight this slot binds to.
    pub weight: Option<String>,
}

impl TensorSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .and_then(DType::parse)
            .or_else(|| {
                // int32 token inputs: treated as a distinct tag by the
                // runtime but carried as F32 size-wise is wrong — keep a
                // side flag via weight=None + dtype name check instead.
                None
            });
        let dtype_name = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?;
        let dtype = match dtype {
            Some(d) => d,
            None if dtype_name == "int32" => DType::F32, // size-compatible; tokens handled specially
            None => return Err(anyhow!("unsupported dtype {dtype_name}")),
        };
        Ok(TensorSpec {
            shape,
            dtype,
            weight: v.get("weight").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Manifest name, e.g. `fwht_hadacore_1024x32`.
    pub name: String,
    /// Operation kind: `fwht` | `attention` | `lm_forward`.
    pub op: String,
    /// HLO-text file name within the artifact dir.
    pub file: String,
    /// Kernel tag for fwht artifacts (`hadacore` | `butterfly`).
    pub kernel: Option<String>,
    /// Numerics variant for attention/LM artifacts.
    pub variant: Option<String>,
    /// Hadamard size for fwht artifacts.
    pub n: Option<usize>,
    /// Row-bucket size for fwht artifacts.
    pub rows: Option<usize>,
    /// Input tensor specs, in execute() order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// One named weight tensor inside `weights.bin`.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    /// Dotted parameter path, e.g. `layers.0.attn.wq`.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Element offset within the f32 stream.
    pub offset: usize,
    /// Element count.
    pub numel: usize,
}

/// Model hyperparameters recorded by aot.py.
#[derive(Clone, Debug, Default)]
pub struct ModelMeta {
    pub vocab: usize,
    pub dim: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub lm_batch: usize,
    pub attn_batch: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// All artifact entries.
    pub artifacts: Vec<ArtifactEntry>,
    /// Weight layout of `weights.bin`.
    pub weights: Vec<WeightEntry>,
    /// Model hyperparameters.
    pub model: ModelMeta,
}

impl Manifest {
    /// Load and validate a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            let gets = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            artifacts.push(ArtifactEntry {
                name: gets("name")?,
                op: gets("op")?,
                file: gets("file")?,
                kernel: a.get("kernel").and_then(Json::as_str).map(str::to_string),
                variant: a.get("variant").and_then(Json::as_str).map(str::to_string),
                n: a.get("n").and_then(Json::as_usize),
                rows: a.get("rows").and_then(Json::as_usize),
                inputs: a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
            });
        }

        let weights = root
            .get("weights")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|w| {
                Ok(WeightEntry {
                    name: w
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("weight missing name"))?
                        .to_string(),
                    shape: w
                        .get("shape")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    offset: w
                        .get("offset")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("weight missing offset"))?,
                    numel: w
                        .get("numel")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("weight missing numel"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let m = root.get("model");
        let getm = |k: &str| -> usize {
            m.and_then(|m| m.get(k)).and_then(Json::as_usize).unwrap_or(0)
        };
        let model = ModelMeta {
            vocab: getm("vocab"),
            dim: getm("dim"),
            n_heads: getm("n_heads"),
            n_layers: getm("n_layers"),
            seq_len: getm("seq_len"),
            lm_batch: getm("lm_batch"),
            attn_batch: getm("attn_batch"),
        };

        Ok(Manifest { artifacts, weights, model })
    }

    /// Entry lookup by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|e| e.name == name)
    }

    /// All fwht bucket entries for a kernel, sorted by n.
    pub fn fwht_buckets(&self, kernel: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .artifacts
            .iter()
            .filter(|e| e.op == "fwht" && e.kernel.as_deref() == Some(kernel))
            .collect();
        v.sort_by_key(|e| e.n.unwrap_or(0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "model": {"vocab": 256, "dim": 128, "n_heads": 4, "n_layers": 2,
                "seq_len": 64, "lm_batch": 8, "attn_batch": 4},
      "artifacts": [
        {"name": "fwht_hadacore_256x128", "op": "fwht", "kernel": "hadacore",
         "file": "fwht_hadacore_256x128.hlo.txt", "n": 256, "rows": 128,
         "inputs": [{"shape": [128, 256], "dtype": "float32"}],
         "outputs": [{"shape": [128, 256], "dtype": "float32"}]},
        {"name": "lm_fp16", "op": "lm_forward", "variant": "fp16",
         "file": "lm_fp16.hlo.txt",
         "inputs": [{"shape": [8, 64], "dtype": "int32"},
                    {"shape": [256, 128], "dtype": "float32", "weight": "embed"}],
         "outputs": [{"shape": [8, 64, 256], "dtype": "float32"}]}
      ],
      "weights": [
        {"name": "embed", "shape": [256, 128], "offset": 0, "numel": 32768}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.model.dim, 128);
        let f = m.find("fwht_hadacore_256x128").unwrap();
        assert_eq!(f.n, Some(256));
        assert_eq!(f.rows, Some(128));
        assert_eq!(f.inputs[0].numel(), 128 * 256);
        let lm = m.find("lm_fp16").unwrap();
        assert_eq!(lm.inputs[1].weight.as_deref(), Some("embed"));
        assert_eq!(m.weights[0].numel, 32768);
        assert_eq!(m.fwht_buckets("hadacore").len(), 1);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // validated against the actual build output when artifacts exist
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.artifacts.len() >= 19);
            assert!(!m.weights.is_empty());
            assert_eq!(m.model.dim, 128);
        }
    }
}
