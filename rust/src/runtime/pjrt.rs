//! Host-side stand-in for the `xla` crate's PJRT surface.
//!
//! The runtime layer was written against the [`xla-rs`] API
//! (`PjRtClient` / `PjRtLoadedExecutable` / `Literal`). That crate links
//! the multi-hundred-megabyte `xla_extension` C++ library, which this
//! build environment does not ship — so this module provides the same
//! types with the same signatures, split in two tiers:
//!
//! * **Host tier (fully functional):** [`Literal`] construction, reshape,
//!   and readback are pure host-memory operations and are implemented for
//!   real. Manifest parsing, weight loading, and every test that only
//!   moves buffers works identically to the real backend.
//! * **Device tier (gated):** [`PjRtClient::compile`] returns a clean
//!   error — compiled-artifact execution requires the real PJRT runtime.
//!   Deployments without an artifact directory (a fresh clone) are
//!   unaffected: the coordinator routes everything native. A deployment
//!   that *does* pass an artifact directory fails fast instead of
//!   degrading — `Coordinator::start` preloads artifacts by default and
//!   surfaces the compile error at startup.
//!
//! Swapping the real crate back in is a two-line change: add `xla` to
//! `Cargo.toml` and re-point the `pub use self::pjrt as xla;` alias in
//! [`crate::runtime`].
//!
//! [`xla-rs`]: https://github.com/LaurentMazare/xla-rs

use std::fmt;

/// Error type matching the `xla::Error` role: call sites format it with
/// `{e:?}`, so only `Debug` is load-bearing.
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

const NO_BACKEND: &str = "PJRT execution requires the real `xla` crate \
     (this build uses the dependency-free host stub; native kernels remain \
     fully functional)";

/// Typed literal storage (f32 and i32 cover every artifact input/output
/// this repo produces). Public only because [`NativeType`] mentions it.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized + 'static {
    #[doc(hidden)]
    fn wrap(data: &[Self]) -> Storage;
    #[doc(hidden)]
    fn unwrap(storage: &Storage) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Storage {
        Storage::F32(data.to_vec())
    }

    fn unwrap(storage: &Storage) -> Result<Vec<Self>> {
        match storage {
            Storage::F32(v) => Ok(v.clone()),
            Storage::I32(_) => Err(Error::new("literal holds i32, requested f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Storage {
        Storage::I32(data.to_vec())
    }

    fn unwrap(storage: &Storage) -> Result<Vec<Self>> {
        match storage {
            Storage::I32(v) => Ok(v.clone()),
            Storage::F32(_) => Err(Error::new("literal holds f32, requested i32")),
        }
    }
}

/// A host-memory tensor literal (shape + typed storage).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::wrap(data) }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.storage.len() {
            return Err(Error::new(format!(
                "reshape to {:?} ({numel} elements) of a {}-element literal",
                dims,
                self.storage.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the contents out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
    }

    /// Unpack a tuple literal. The host stub never produces tuples (they
    /// only arise from device execution), so this is always an error.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::new(NO_BACKEND))
    }
}

/// Parsed HLO module text. The stub validates only that the file exists
/// and is readable; structural validation happens at compile time on the
/// real backend.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping an HLO module, ready to compile.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: HloModuleProto { text: proto.text.clone() } }
    }
}

/// A device buffer handle produced by execution. Unconstructible in the
/// stub (execution always fails first); present so signatures match.
pub struct PjRtBuffer {
    never: std::convert::Infallible,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

/// A compiled executable. Unconstructible in the stub.
pub struct PjRtLoadedExecutable {
    never: std::convert::Infallible,
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

/// The PJRT client. The stub constructs (so [`crate::runtime::Runtime`]
/// opens, manifests parse, and weights load) but cannot compile.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Open the CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform label; `-stub` marks the host-only build.
    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    /// Compile a computation. Always an error in the stub build.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let lit = Literal::vec1(&data);
        assert_eq!(lit.dims(), &[6]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), data);
        assert!(lit.reshape(&[4, 4]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn i32_literals_work() {
        let toks = vec![1i32, 2, 3, 4];
        let lit = Literal::vec1(&toks).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), toks);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_opens_but_cannot_compile() {
        let dir = std::env::temp_dir().join(format!("hc_hlo_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule m").unwrap();

        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("PJRT execution requires"));

        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
