//! Host tensors and Literal conversion helpers.
//!
//! [`Tensor`] is the plain row-major host container the harness and
//! examples trade in; the `literal_*` helpers convert to and from the
//! PJRT [`xla::Literal`] exchange type at the runtime boundary.

use super::xla;
use crate::util::error::{anyhow, Result};

/// A host-side f32 tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Row-major contents; `data.len() == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Construct, validating the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(anyhow!(
                "shape {:?} implies {} elements, got {}",
                shape,
                numel,
                data.len()
            ));
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape, data: vec![0.0; numel] }
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Build an f32 literal with the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        return Err(anyhow!("literal shape/data mismatch"));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape failed: {e:?}"))
}

/// Build an i32 literal with the given shape (token inputs).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    if numel != data.len() {
        return Err(anyhow!("literal shape/data mismatch"));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape failed: {e:?}"))
}

/// Copy a literal's contents out as f32.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec failed: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.numel(), 16);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let lit = literal_f32(&data, &[3, 4]).unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), data);
        assert!(literal_f32(&data, &[5, 5]).is_err());
    }

    #[test]
    fn literal_i32_builds() {
        let toks: Vec<i32> = vec![1, 2, 3, 4];
        let lit = literal_i32(&toks, &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), toks);
    }
}
