//! Trained-weight loading (`artifacts/weights.bin`).
//!
//! Layout contract with `python/compile/train.py::save_weights`:
//! concatenated little-endian f32 tensors in `flatten_params` order, with
//! per-tensor (name, shape, offset, numel) recorded in the manifest.

use std::collections::HashMap;
use std::path::Path;

use super::manifest::Manifest;
use super::tensor::Tensor;
use super::xla;
use crate::util::error::{anyhow, Context, Result};

/// All trained weights, addressable by name and in manifest order.
#[derive(Clone, Debug)]
pub struct Weights {
    ordered: Vec<(String, Tensor)>,
    by_name: HashMap<String, usize>,
}

impl Weights {
    /// Load `weights.bin` using the manifest's layout.
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Weights> {
        let path = dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("weights.bin length {} not /4", bytes.len()));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut ordered = Vec::with_capacity(manifest.weights.len());
        let mut by_name = HashMap::new();
        for w in &manifest.weights {
            let end = w.offset + w.numel;
            if end > floats.len() {
                return Err(anyhow!(
                    "weight {} spans [{}, {}) beyond file ({} floats)",
                    w.name,
                    w.offset,
                    end,
                    floats.len()
                ));
            }
            let numel: usize = w.shape.iter().product();
            if numel != w.numel {
                return Err(anyhow!("weight {} shape/numel mismatch", w.name));
            }
            by_name.insert(w.name.clone(), ordered.len());
            ordered.push((
                w.name.clone(),
                Tensor { shape: w.shape.clone(), data: floats[w.offset..end].to_vec() },
            ));
        }
        Ok(Weights { ordered, by_name })
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.ordered.len()
    }

    /// True when no tensors were loaded.
    pub fn is_empty(&self) -> bool {
        self.ordered.is_empty()
    }

    /// Tensor by dotted name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.by_name.get(name).map(|&i| &self.ordered[i].1)
    }

    /// Tensors in manifest (= artifact-input) order.
    pub fn ordered(&self) -> &[(String, Tensor)] {
        &self.ordered
    }

    /// Total scalar parameter count.
    pub fn param_count(&self) -> usize {
        self.ordered.iter().map(|(_, t)| t.numel()).sum()
    }

    /// Build the literal list an LM artifact expects after the token input:
    /// one literal per weight in order.
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.ordered
            .iter()
            .map(|(_, t)| super::tensor::literal_f32(&t.data, &t.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn tiny_manifest(numel: usize) -> Manifest {
        Manifest::parse(&format!(
            r#"{{"artifacts": [],
                 "weights": [{{"name": "w", "shape": [{numel}], "offset": 0,
                               "numel": {numel}}}],
                 "model": {{}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join(format!("hc_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), &bytes).unwrap();

        let w = Weights::load(&dir, &tiny_manifest(8)).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w.param_count(), 8);
        assert_eq!(w.get("w").unwrap().data, vals);
        assert!(w.get("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_overrun() {
        let dir = std::env::temp_dir().join(format!("hc_w2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap(); // 2 floats
        assert!(Weights::load(&dir, &tiny_manifest(8)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_weights_load_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("weights.bin").exists() {
            let m = Manifest::load(&dir.join("manifest.json")).unwrap();
            let w = Weights::load(&dir, &m).unwrap();
            assert!(w.param_count() > 100_000);
            assert!(w.get("embed").is_some());
        }
    }
}
