//! Per-group (block-wise) quantisation — the granularity QuaRot/QuIP#
//! actually deploy (per-tensor scales are the worst case for outliers;
//! per-group scales of 32-128 elements bound the blast radius of each
//! outlier to its own group, and rotation then flattens *within* groups).

use crate::util::f16::Element;

use super::int::{int_round, IntBits};

/// Per-group quantisation of a slice, writing each group's scale into
/// the matching `scales` slot (one per group, in element order).
/// Widens/narrows 16-bit storage through [`Element`]. The single
/// per-group loop behind both [`int_quantize_grouped`] and the execution
/// engine's fused epilogue — one implementation is what makes the fused
/// path bit-identical to the two-pass reference by construction.
pub fn int_group_apply_slice<E: Element>(
    data: &mut [E],
    group: usize,
    bits: IntBits,
    scales: &mut [f32],
) {
    debug_assert_eq!(data.len() / group.max(1), scales.len());
    for (g, slot) in data.chunks_exact_mut(group).zip(scales.iter_mut()) {
        let amax = crate::quant::amax_slice(g);
        let scale = if amax == 0.0 { 1.0 } else { amax / bits.qmax() as f32 };
        for v in g.iter_mut() {
            *v = E::from_f32(int_round(v.to_f32(), scale, bits));
        }
        *slot = scale;
    }
}

/// Per-group symmetric INT quantisation of the last axis.
///
/// `x` is `(rows, n)` row-major; each contiguous `group` elements share a
/// max-abs scale. Returns the scales, `(rows * n / group)` of them.
pub fn int_quantize_grouped(
    x: &mut [f32],
    group: usize,
    bits: IntBits,
) -> Vec<f32> {
    assert!(group > 0 && x.len() % group == 0, "bad group size");
    let mut scales = vec![0.0f32; x.len() / group];
    int_group_apply_slice(x, group, bits, &mut scales);
    scales
}

/// Error statistics comparing per-tensor vs per-group quantisation of the
/// same data, used by the ablation bench and tests.
pub fn group_size_sweep(
    x: &[f32],
    sizes: &[usize],
    bits: IntBits,
) -> Vec<(usize, f64)> {
    sizes
        .iter()
        .map(|&g| {
            let mut q = x.to_vec();
            int_quantize_grouped(&mut q, g, bits);
            (g, crate::util::prop::rel_l2(&q, x))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::{fwht_hadacore_f32, FwhtOptions};
    use crate::util::rng::Rng;

    #[test]
    fn group_of_full_length_equals_per_tensor() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(256);
        let mut grouped = x.clone();
        int_quantize_grouped(&mut grouped, 256, IntBits::Int8);
        let mut tensor = x;
        crate::quant::int::int_quantize_slice(&mut tensor, IntBits::Int8);
        assert_eq!(grouped, tensor);
    }

    #[test]
    fn smaller_groups_reduce_outlier_damage() {
        let mut rng = Rng::new(2);
        let mut x = rng.normal_vec(4096);
        x[17] = 500.0; // one outlier
        let sweep = group_size_sweep(&x, &[32, 256, 4096], IntBits::Int4);
        // error must be monotone non-decreasing with group size
        assert!(sweep[0].1 <= sweep[1].1);
        assert!(sweep[1].1 <= sweep[2].1);
        // and the improvement should be substantial for int4
        assert!(
            sweep[0].1 < sweep[2].1 * 0.5,
            "per-group should beat per-tensor: {sweep:?}"
        );
    }

    #[test]
    fn scales_are_per_group() {
        let mut x = vec![1.0f32; 64];
        x[32] = 100.0; // second group carries the outlier
        let scales = int_quantize_grouped(&mut x, 32, IntBits::Int8);
        assert_eq!(scales.len(), 2);
        assert!(scales[1] > scales[0] * 10.0);
        // first group is untouched by the outlier
        assert!((x[0] - 1.0).abs() < 0.01);
    }

    #[test]
    fn rotation_plus_grouping_compose() {
        // rotation flattens within groups, grouping bounds across groups:
        // the combination beats either alone on clustered outliers
        let mut rng = Rng::new(3);
        let n = 4096;
        let mut x = rng.normal_vec(n);
        for i in (0..n).step_by(64) {
            x[i] *= 40.0;
        }
        let err = |v: &[f32]| crate::util::prop::rel_l2(v, &x);

        let mut per_tensor = x.clone();
        int_quantize_grouped(&mut per_tensor, n, IntBits::Int4);

        let mut rotated = x.clone();
        let opts = FwhtOptions::normalized(n);
        fwht_hadacore_f32(&mut rotated, n, &opts);
        int_quantize_grouped(&mut rotated, 128, IntBits::Int4);
        fwht_hadacore_f32(&mut rotated, n, &opts);

        assert!(
            err(&rotated) < err(&per_tensor) * 0.6,
            "rot+group {} vs per-tensor {}",
            err(&rotated),
            err(&per_tensor)
        );
    }

    #[test]
    #[should_panic(expected = "bad group size")]
    fn rejects_misaligned_group() {
        let mut x = vec![0.0f32; 100];
        int_quantize_grouped(&mut x, 64, IntBits::Int8);
    }
}
