//! Symmetric integer quantisation (INT8 / INT4) — QuaRot's precisions.

/// Integer bit-width selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntBits {
    /// 8-bit symmetric: levels -127..=127.
    Int8,
    /// 4-bit symmetric: levels -7..=7.
    Int4,
}

impl IntBits {
    /// Largest positive level.
    pub fn qmax(self) -> i32 {
        match self {
            IntBits::Int8 => 127,
            IntBits::Int4 => 7,
        }
    }
}

/// Round-to-nearest-even quantisation of one value under `scale`.
#[inline]
pub fn int_round(v: f32, scale: f32, bits: IntBits) -> f32 {
    let qmax = bits.qmax() as f32;
    let q = (v / scale).clamp(-qmax, qmax);
    let r = {
        // ties-to-even
        let f = q.floor();
        let d = q - f;
        if d > 0.5 {
            f + 1.0
        } else if d < 0.5 {
            f
        } else if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    };
    r * scale
}

/// Fake-quantise a slice with a per-tensor symmetric max-abs scale.
/// Returns the scale.
pub fn int_quantize_slice(x: &mut [f32], bits: IntBits) -> f32 {
    let amax = crate::quant::amax_slice(x);
    if amax == 0.0 {
        return 1.0;
    }
    let scale = amax / bits.qmax() as f32;
    for v in x.iter_mut() {
        *v = int_round(*v, scale, bits);
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_levels() {
        // scale 1.0: integers round-trip exactly within range
        for i in -127..=127 {
            assert_eq!(int_round(i as f32, 1.0, IntBits::Int8), i as f32);
        }
        assert_eq!(int_round(200.0, 1.0, IntBits::Int8), 127.0);
        assert_eq!(int_round(-200.0, 1.0, IntBits::Int8), -127.0);
    }

    #[test]
    fn int4_is_very_coarse() {
        assert_eq!(IntBits::Int4.qmax(), 7);
        assert_eq!(int_round(0.6, 1.0, IntBits::Int4), 1.0);
        assert_eq!(int_round(0.4, 1.0, IntBits::Int4), 0.0);
        // tie at 0.5 -> even (0)
        assert_eq!(int_round(0.5, 1.0, IntBits::Int4), 0.0);
        assert_eq!(int_round(1.5, 1.0, IntBits::Int4), 2.0);
    }

    #[test]
    fn slice_quantisation_error_bounded_by_half_step() {
        let mut rng = crate::util::rng::Rng::new(9);
        let x = rng.normal_vec(1000);
        let mut q = x.clone();
        let scale = int_quantize_slice(&mut q, IntBits::Int8);
        for (a, b) in x.iter().zip(q.iter()) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn outliers_blow_up_int4_error() {
        // one huge outlier forces a large scale -> everything else crushed;
        // this is exactly the failure mode Hadamard rotation fixes.
        let mut x = vec![0.1f32; 255];
        x.push(100.0);
        let mut q = x.clone();
        int_quantize_slice(&mut q, IntBits::Int4);
        // all the small values quantise to zero
        assert!(q[..255].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn zero_slice_noop() {
        let mut z = vec![0.0f32; 16];
        assert_eq!(int_quantize_slice(&mut z, IntBits::Int4), 1.0);
        assert!(z.iter().all(|v| *v == 0.0));
    }
}
