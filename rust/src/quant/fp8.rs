//! Bit-exact software FP8 (e4m3 / e5m2) with round-to-nearest-even.
//!
//! e4m3 follows the OCP FP8 / Nvidia `float8_e4m3fn` convention: no
//! infinities, max finite 448, NaN at 0x7f/0xff. e5m2 is IEEE-like with
//! infinities and max finite 57344. These are the formats FP8 attention
//! (FlashAttention-3, the paper's end-to-end setting) quantises to.

use crate::util::f16::Element;

/// FP8 format selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fp8Format {
    /// 4 exponent bits, 3 mantissa bits, bias 7, finite-only (fn variant).
    E4M3,
    /// 5 exponent bits, 2 mantissa bits, bias 15, IEEE-style inf.
    E5M2,
}

impl Fp8Format {
    /// Largest representable finite magnitude.
    pub fn max_finite(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    fn mant_bits(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }

    fn min_exp(self) -> i32 {
        // minimum normal exponent (unbiased)
        match self {
            Fp8Format::E4M3 => -6,
            Fp8Format::E5M2 => -14,
        }
    }
}

/// Round `v` to the nearest representable FP8 value (ties to even),
/// saturating at max finite (the `fn` convention used by ML stacks).
pub fn fp8_round(v: f32, fmt: Fp8Format) -> f32 {
    if v.is_nan() {
        return f32::NAN;
    }
    if v == 0.0 {
        return v; // preserves signed zero
    }
    let max = fmt.max_finite();
    let mant_bits = fmt.mant_bits();
    let min_exp = fmt.min_exp();

    let a = v.abs();
    if a >= max {
        return max.copysign(v); // saturate (fn convention)
    }
    // exponent of the value
    let e = a.log2().floor() as i32;
    let e = e.max(min_exp); // subnormal range quantises at fixed step
    // quantum = 2^(e - mant_bits)
    let q = (e - mant_bits) as f32;
    let quantum = q.exp2();
    let scaled = a / quantum;
    // round half to even
    let r = round_ties_even(scaled);
    let out = r * quantum;
    if out > max {
        return max.copysign(v);
    }
    out.copysign(v)
}

#[inline]
fn round_ties_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Scale + round every element under a fixed per-tensor scale
/// (`x <- fp8(x / scale) * scale`), widening/narrowing 16-bit storage
/// through [`Element`]. The single rounding loop behind both
/// [`fp8_quantize_slice`] and the execution engine's fused epilogue —
/// one implementation is what makes the fused path bit-identical to the
/// two-pass reference by construction.
pub fn fp8_apply_slice<E: Element>(data: &mut [E], scale: f32, fmt: Fp8Format) {
    for v in data.iter_mut() {
        *v = E::from_f32(fp8_round(v.to_f32() / scale, fmt) * scale);
    }
}

/// Fake-quantise a slice through FP8 with a per-tensor symmetric scale
/// mapping max-abs to the format's max finite value. Returns the scale
/// (`x_quantised = fp8(x / scale) * scale`).
pub fn fp8_quantize_slice(x: &mut [f32], fmt: Fp8Format) -> f32 {
    let amax = crate::quant::amax_slice(x);
    if amax == 0.0 {
        return 1.0;
    }
    let scale = amax / fmt.max_finite();
    fp8_apply_slice(x, scale, fmt);
    scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_e4m3() {
        // all integers up to 8 are exactly representable in e4m3
        for i in 0..=8 {
            let v = i as f32;
            assert_eq!(fp8_round(v, Fp8Format::E4M3), v);
            assert_eq!(fp8_round(-v, Fp8Format::E4M3), -v);
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(fp8_round(1e9, Fp8Format::E4M3), 448.0);
        assert_eq!(fp8_round(-1e9, Fp8Format::E4M3), -448.0);
        assert_eq!(fp8_round(1e9, Fp8Format::E5M2), 57344.0);
    }

    #[test]
    fn e4m3_quantum_above_one() {
        // in [16, 32) the e4m3 quantum is 2; 17 is not representable
        let q = fp8_round(17.0, Fp8Format::E4M3);
        assert!(q == 16.0 || q == 18.0);
        // ties to even: 17 is exactly halfway -> 16 (even multiple of 2)
        assert_eq!(q, 16.0);
    }

    #[test]
    fn e5m2_coarser_than_e4m3_near_one() {
        // near 1.0: e4m3 step 0.125, e5m2 step 0.25
        assert_eq!(fp8_round(1.125, Fp8Format::E4M3), 1.125);
        assert_eq!(fp8_round(1.125, Fp8Format::E5M2), 1.0); // tie to even
        assert_eq!(fp8_round(1.25, Fp8Format::E5M2), 1.25);
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::util::rng::Rng::new(5);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for _ in 0..2000 {
                let v = rng.normal_f32() * 50.0;
                let q = fp8_round(v, fmt);
                assert_eq!(fp8_round(q, fmt), q, "fmt {fmt:?} v {v}");
            }
        }
    }

    #[test]
    fn relative_error_bound_normal_range() {
        let mut rng = crate::util::rng::Rng::new(6);
        for _ in 0..5000 {
            let v = rng.normal_f32() * 10.0;
            if v.abs() < 0.02 {
                continue; // subnormal range has absolute, not relative bound
            }
            let q = fp8_round(v, Fp8Format::E4M3);
            let rel = ((q - v) / v).abs();
            assert!(rel <= 0.0625 + 1e-6, "v={v} q={q} rel={rel}");
        }
    }

    #[test]
    fn quantize_slice_scales_to_max() {
        let mut x = vec![1.0f32, -2.0, 448.0, 0.5];
        let scale = fp8_quantize_slice(&mut x, Fp8Format::E4M3);
        assert!((scale - 1.0).abs() < 1e-6);
        assert_eq!(x[2], 448.0);
        let mut y = vec![0.0f32; 8];
        assert_eq!(fp8_quantize_slice(&mut y, Fp8Format::E4M3), 1.0);
    }

    #[test]
    fn nan_passthrough() {
        assert!(fp8_round(f32::NAN, Fp8Format::E4M3).is_nan());
    }

    #[test]
    fn signed_zero_preserved() {
        assert_eq!(fp8_round(0.0, Fp8Format::E4M3).to_bits(), 0.0f32.to_bits());
        assert_eq!(
            fp8_round(-0.0, Fp8Format::E4M3).to_bits(),
            (-0.0f32).to_bits()
        );
    }
}
