//! Quantisation-error statistics: the numbers that explain why rotation
//! helps (QuaRot §1, QuIP# incoherence processing).

use super::Scheme;

/// Summary of a quantisation experiment on one tensor.
#[derive(Clone, Debug)]
pub struct QuantReport {
    /// Scheme applied.
    pub scheme: &'static str,
    /// Mean squared quantisation error.
    pub mse: f64,
    /// Relative L2 error.
    pub rel_l2: f64,
    /// Fraction of total mass in elements > 4 sigma (outlier mass).
    pub outlier_mass: f64,
    /// Incoherence mu = max|x| * sqrt(n) / ||x||  (QuIP# definition).
    pub incoherence: f64,
}

/// Incoherence `mu = max|x| * sqrt(n) / ||x||_2`. Lower = flatter = easier
/// to quantise; a random rotation drives mu toward O(sqrt(log n)).
pub fn incoherence(x: &[f32]) -> f64 {
    let n = x.len() as f64;
    let amax = x.iter().fold(0.0f64, |m, v| m.max(v.abs() as f64));
    let l2: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    if l2 == 0.0 {
        return 0.0;
    }
    amax * n.sqrt() / l2
}

/// Fraction of squared mass carried by elements beyond `k` standard
/// deviations of the empirical distribution.
pub fn outlier_mass(x: &[f32], k: f64) -> f64 {
    let n = x.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mean: f64 = x.iter().map(|v| *v as f64).sum::<f64>() / n;
    let var: f64 = x.iter().map(|v| (*v as f64 - mean).powi(2)).sum::<f64>() / n;
    let sd = var.sqrt();
    if sd == 0.0 {
        return 0.0;
    }
    let total: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
    let tail: f64 = x
        .iter()
        .filter(|v| ((**v as f64) - mean).abs() > k * sd)
        .map(|v| (*v as f64).powi(2))
        .sum();
    if total == 0.0 {
        0.0
    } else {
        tail / total
    }
}

/// Mean squared error between original and quantised tensors.
pub fn quant_mse(orig: &[f32], quant: &[f32]) -> f64 {
    assert_eq!(orig.len(), quant.len());
    orig.iter()
        .zip(quant.iter())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / orig.len().max(1) as f64
}

/// Signal-to-quantisation-noise ratio in dB:
/// `10 * log10( ||x||^2 / ||x - q||^2 )`. Higher is better; an exact
/// reconstruction returns `f64::INFINITY`, and an all-zero signal
/// returns 0 (no signal, nothing to measure).
pub fn quant_snr(orig: &[f32], quant: &[f32]) -> f64 {
    assert_eq!(orig.len(), quant.len());
    let signal: f64 = orig.iter().map(|v| (*v as f64).powi(2)).sum();
    if signal == 0.0 {
        return 0.0;
    }
    let noise: f64 = orig
        .iter()
        .zip(quant.iter())
        .map(|(a, b)| ((*a as f64) - (*b as f64)).powi(2))
        .sum();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (signal / noise).log10()
}

/// Max elementwise error relative to the tensor's max magnitude:
/// `max|x - q| / max|x|` — the paper's accuracy-table metric
/// (PAPER.md §4.1 reports FP16/BF16 transform error relative to amax).
/// An all-zero original returns 0.
pub fn rel_to_amax(orig: &[f32], quant: &[f32]) -> f64 {
    assert_eq!(orig.len(), quant.len());
    let amax = orig.iter().fold(0.0f64, |m, v| m.max(v.abs() as f64));
    if amax == 0.0 {
        return 0.0;
    }
    let maxerr = orig
        .iter()
        .zip(quant.iter())
        .fold(0.0f64, |m, (a, b)| m.max(((*a as f64) - (*b as f64)).abs()));
    maxerr / amax
}

/// Quantise a copy of `x` under `scheme` and report the error statistics.
pub fn evaluate(x: &[f32], scheme: Scheme) -> QuantReport {
    let mut q = x.to_vec();
    super::fake_quantize(&mut q, scheme);
    QuantReport {
        scheme: scheme.name(),
        mse: quant_mse(x, &q),
        rel_l2: crate::util::prop::rel_l2(&q, x),
        outlier_mass: outlier_mass(x, 4.0),
        incoherence: incoherence(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::{fwht_hadacore_f32, FwhtOptions};
    use crate::util::rng::Rng;

    #[test]
    fn incoherence_of_flat_vector_is_one() {
        let x = vec![1.0f32; 64];
        assert!((incoherence(&x) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn incoherence_of_impulse_is_sqrt_n() {
        let mut x = vec![0.0f32; 64];
        x[3] = 5.0;
        assert!((incoherence(&x) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn rotation_reduces_incoherence_of_outlier_vectors() {
        // This is the paper's core motivation measured directly: a heavy-
        // tailed activation vector becomes "flat" after a Hadamard rotation,
        // so its max-abs scale stops crushing the small values.
        let mut rng = Rng::new(11);
        let n = 1024;
        let mut x: Vec<f32> = (0..n).map(|_| rng.outlier_normal(0.01, 50.0)).collect();
        let mu_before = incoherence(&x);
        fwht_hadacore_f32(&mut x, n, &FwhtOptions::normalized(n));
        let mu_after = incoherence(&x);
        assert!(
            mu_after < mu_before * 0.5,
            "rotation should flatten: before {mu_before}, after {mu_after}"
        );
    }

    #[test]
    fn rotation_reduces_int4_quant_error() {
        let mut rng = Rng::new(13);
        let n = 4096;
        let x: Vec<f32> = (0..n).map(|_| rng.outlier_normal(0.005, 40.0)).collect();
        let direct = evaluate(&x, Scheme::Int4);
        let mut rot = x.clone();
        fwht_hadacore_f32(&mut rot, n, &FwhtOptions::normalized(n));
        let rotated = evaluate(&rot, Scheme::Int4);
        assert!(
            rotated.rel_l2 < direct.rel_l2 * 0.6,
            "rotation should cut INT4 error: direct {}, rotated {}",
            direct.rel_l2,
            rotated.rel_l2
        );
    }

    #[test]
    fn outlier_mass_detects_tails() {
        let mut rng = Rng::new(15);
        let flat: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let mut heavy = flat.clone();
        heavy[0] = 1000.0;
        assert!(outlier_mass(&heavy, 4.0) > 0.9);
        assert!(outlier_mass(&flat, 4.0) < 0.05);
        assert_eq!(outlier_mass(&[], 4.0), 0.0);
        assert_eq!(outlier_mass(&[1.0, 1.0], 4.0), 0.0); // sd == 0
    }

    #[test]
    fn quant_mse_basics() {
        assert_eq!(quant_mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((quant_mse(&[1.0, 2.0], &[1.5, 2.0]) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn quant_snr_basics() {
        // exact reconstruction: infinite SNR; zero signal: 0
        assert_eq!(quant_snr(&[1.0, -2.0], &[1.0, -2.0]), f64::INFINITY);
        assert_eq!(quant_snr(&[0.0, 0.0], &[0.1, 0.0]), 0.0);
        // signal 100, noise 1 -> exactly 20 dB
        let snr = quant_snr(&[10.0], &[9.0]);
        assert!((snr - 20.0).abs() < 1e-9, "got {snr}");
        // halving the noise power adds ~3.01 dB
        let better = quant_snr(&[10.0, 10.0], &[9.0, 10.0]);
        assert!((better - snr - 10.0 * 2.0f64.log10()).abs() < 1e-9);
    }

    #[test]
    fn rel_to_amax_basics() {
        assert_eq!(rel_to_amax(&[4.0, -2.0], &[4.0, -2.0]), 0.0);
        assert_eq!(rel_to_amax(&[0.0; 4], &[1.0; 4]), 0.0);
        // max error 0.5 against amax 4
        let r = rel_to_amax(&[4.0, -2.0], &[4.0, -2.5]);
        assert!((r - 0.125).abs() < 1e-12, "got {r}");
    }

    #[test]
    fn rotation_improves_fp8_snr_on_outlier_activations() {
        // the tentpole claim at unit scale: rotate → quantize beats
        // quantize alone on heavy-tailed activations
        let mut rng = Rng::new(17);
        let n = 4096;
        let x: Vec<f32> = (0..n).map(|_| rng.outlier_normal(0.005, 40.0)).collect();
        let mut q = x.clone();
        crate::quant::fake_quantize(&mut q, Scheme::Fp8E4m3);
        let plain = quant_snr(&x, &q);

        let mut rot = x.clone();
        fwht_hadacore_f32(&mut rot, n, &FwhtOptions::normalized(n));
        let mut rq = rot.clone();
        crate::quant::fake_quantize(&mut rq, Scheme::Fp8E4m3);
        let rotated = quant_snr(&rot, &rq);
        assert!(
            rotated > plain,
            "rotation should raise FP8 SNR: plain {plain:.2} dB, rotated {rotated:.2} dB"
        );
    }
}
