//! Simulated low-precision quantisation + the error metrics that motivate
//! Hadamard rotations (QuaRot / SpinQuant / QuIP#, paper §1).
//!
//! The paper's end-to-end evaluation runs Llama-3.1 with FP8 attention and
//! measures MMLU accuracy with/without rotation. This module provides the
//! numerical substrate for the analogous experiment in this repo: bit-exact
//! software emulation of FP8 (e4m3/e5m2) and symmetric INT8/INT4
//! round-to-nearest quantisation, plus the statistics (outlier mass,
//! incoherence, quantisation MSE) that explain *why* rotation helps.

pub mod fp8;
pub mod group;
pub mod int;
pub mod metrics;

pub use fp8::{fp8_quantize_slice, Fp8Format};
pub use group::{group_size_sweep, int_quantize_grouped};
pub use int::{int_quantize_slice, IntBits};
pub use metrics::{incoherence, outlier_mass, quant_mse, QuantReport};

/// A quantisation scheme applied per-tensor with a symmetric scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// FP8 e4m3 (the FlashAttention-3 / paper FP8-attention format).
    Fp8E4m3,
    /// FP8 e5m2.
    Fp8E5m2,
    /// INT8 symmetric round-to-nearest.
    Int8,
    /// INT4 symmetric round-to-nearest (QuaRot's headline precision).
    Int4,
}

impl Scheme {
    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp8E4m3 => "fp8_e4m3",
            Scheme::Fp8E5m2 => "fp8_e5m2",
            Scheme::Int8 => "int8",
            Scheme::Int4 => "int4",
        }
    }

    /// Parse a scheme name.
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "fp8_e4m3" | "fp8" => Some(Scheme::Fp8E4m3),
            "fp8_e5m2" => Some(Scheme::Fp8E5m2),
            "int8" => Some(Scheme::Int8),
            "int4" => Some(Scheme::Int4),
            _ => None,
        }
    }
}

/// Fake-quantise `x` in place under `scheme` with a per-tensor symmetric
/// scale chosen from the max-abs value (the paper's setting: per-tensor
/// FP8 attention). Returns the scale used.
///
/// Rotation pairing: QuaRot-style experiments wrap this call in the
/// **orthonormal** transform (`FwhtOptions::normalized`, i.e.
/// `x <- (x @ H_n) / sqrt(n)`), quantise, then apply the same transform
/// again to rotate back — orthonormality is what makes the transform its
/// own inverse, so any other scale would change the tensor's magnitude
/// and corrupt the comparison.
pub fn fake_quantize(x: &mut [f32], scheme: Scheme) -> f32 {
    match scheme {
        Scheme::Fp8E4m3 => fp8_quantize_slice(x, Fp8Format::E4M3),
        Scheme::Fp8E5m2 => fp8_quantize_slice(x, Fp8Format::E5M2),
        Scheme::Int8 => int_quantize_slice(x, IntBits::Int8),
        Scheme::Int4 => int_quantize_slice(x, IntBits::Int4),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for s in [Scheme::Fp8E4m3, Scheme::Fp8E5m2, Scheme::Int8, Scheme::Int4] {
            assert_eq!(Scheme::parse(s.name()), Some(s));
        }
        assert_eq!(Scheme::parse("fp8"), Some(Scheme::Fp8E4m3));
        assert_eq!(Scheme::parse("fp7"), None);
    }

    #[test]
    fn fake_quantize_reduces_precision_but_preserves_scale() {
        let mut rng = crate::util::rng::Rng::new(1);
        for scheme in [Scheme::Fp8E4m3, Scheme::Int8, Scheme::Int4] {
            let x = rng.normal_vec(4096);
            let mut q = x.clone();
            fake_quantize(&mut q, scheme);
            let err = crate::util::prop::rel_l2(&q, &x);
            assert!(err > 1e-5, "{scheme:?} should not be lossless: {err}");
            assert!(err < 0.3, "{scheme:?} error too large: {err}");
        }
    }
}
