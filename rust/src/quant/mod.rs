//! Simulated low-precision quantisation + the error metrics that motivate
//! Hadamard rotations (QuaRot / SpinQuant / QuIP#, paper §1).
//!
//! The paper's end-to-end evaluation runs Llama-3.1 with FP8 attention and
//! measures MMLU accuracy with/without rotation. This module provides the
//! numerical substrate for the analogous experiment in this repo: bit-exact
//! software emulation of FP8 (e4m3/e5m2) and symmetric INT8/INT4
//! round-to-nearest quantisation, plus the statistics (outlier mass,
//! incoherence, quantisation MSE) that explain *why* rotation helps.

pub mod fp8;
pub mod group;
pub mod int;
pub mod metrics;

pub use fp8::{fp8_apply_slice, fp8_quantize_slice, Fp8Format};
pub use group::{group_size_sweep, int_group_apply_slice, int_quantize_grouped};
pub use int::{int_quantize_slice, IntBits};
pub use metrics::{incoherence, outlier_mass, quant_mse, quant_snr, rel_to_amax, QuantReport};

/// Max-abs over a slice, widening 16-bit storage through
/// [`crate::util::f16::Element`]. NaNs are ignored (`f32::max`
/// semantics), and `max` over finite nonnegative values is exact under
/// any association — per-chunk maxima merged by the execution engine's
/// sharded epilogue equal this sequential fold bit-for-bit.
pub fn amax_slice<E: crate::util::f16::Element>(data: &[E]) -> f32 {
    data.iter().fold(0.0f32, |m, v| m.max(v.to_f32().abs()))
}

/// A quantisation step fused into the transform as an epilogue: the
/// [`crate::exec`] engine rotates each chunk and quantises it in the same
/// working-set traversal, instead of callers making a second full pass
/// over the rotated rows (the avoidable data-exchange overhead the paper
/// restructures the transform to remove).
///
/// Semantics match the unfused reference exactly (bit-for-bit, enforced
/// by `rust/tests/epilogue_parity.rs`):
///
/// * [`Epilogue::QuantFp8`] == transform then [`fp8_quantize_slice`]
///   (per-tensor symmetric max-abs scale);
/// * [`Epilogue::QuantInt8`] == transform then [`int_quantize_grouped`]
///   (per-group symmetric INT8 scales; `group` must divide `n`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Epilogue {
    /// Plain transform, no fused quantisation.
    #[default]
    None,
    /// Per-tensor FP8 fake-quantisation (two-phase: global amax, then
    /// scale + round-to-nearest-even per chunk).
    QuantFp8 {
        /// FP8 format (e4m3 for the paper's FP8-attention setting).
        fmt: Fp8Format,
    },
    /// Per-group symmetric INT8 fake-quantisation (single-phase: group
    /// scales never cross a chunk boundary because `group` divides `n`).
    QuantInt8 {
        /// Contiguous elements sharing one max-abs scale.
        group: usize,
    },
}

impl Epilogue {
    /// True for the plain (no-quantisation) epilogue.
    pub fn is_none(self) -> bool {
        matches!(self, Epilogue::None)
    }

    /// Admission-time validation against a transform size.
    pub fn validate(self, n: usize) -> Result<(), String> {
        match self {
            Epilogue::QuantInt8 { group } if group == 0 || n % group != 0 => {
                Err(format!(
                    "int8 epilogue group {group} must be a nonzero divisor of n={n}"
                ))
            }
            _ => Ok(()),
        }
    }
}

/// The scale(s) an [`Epilogue`] produced, carried back to the caller so
/// dequantisation needs no recomputation.
#[derive(Clone, Debug, PartialEq)]
pub enum QuantScales {
    /// No epilogue ran.
    None,
    /// One symmetric per-tensor scale (`x_q = fp8(x / scale) * scale`).
    PerTensor(f32),
    /// One scale per contiguous group, in element order.
    PerGroup(Vec<f32>),
}

impl QuantScales {
    /// The per-tensor scale, if that is what the epilogue produced.
    pub fn per_tensor(&self) -> Option<f32> {
        match self {
            QuantScales::PerTensor(s) => Some(*s),
            _ => None,
        }
    }
}

/// A quantisation scheme applied per-tensor with a symmetric scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// FP8 e4m3 (the FlashAttention-3 / paper FP8-attention format).
    Fp8E4m3,
    /// FP8 e5m2.
    Fp8E5m2,
    /// INT8 symmetric round-to-nearest.
    Int8,
    /// INT4 symmetric round-to-nearest (QuaRot's headline precision).
    Int4,
}

impl Scheme {
    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp8E4m3 => "fp8_e4m3",
            Scheme::Fp8E5m2 => "fp8_e5m2",
            Scheme::Int8 => "int8",
            Scheme::Int4 => "int4",
        }
    }

    /// Parse a scheme name.
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "fp8_e4m3" | "fp8" => Some(Scheme::Fp8E4m3),
            "fp8_e5m2" => Some(Scheme::Fp8E5m2),
            "int8" => Some(Scheme::Int8),
            "int4" => Some(Scheme::Int4),
            _ => None,
        }
    }
}

/// Fake-quantise `x` in place under `scheme` with a per-tensor symmetric
/// scale chosen from the max-abs value (the paper's setting: per-tensor
/// FP8 attention). Returns the scale used.
///
/// Rotation pairing: QuaRot-style experiments wrap this call in the
/// **orthonormal** transform (`FwhtOptions::normalized`, i.e.
/// `x <- (x @ H_n) / sqrt(n)`), quantise, then apply the same transform
/// again to rotate back — orthonormality is what makes the transform its
/// own inverse, so any other scale would change the tensor's magnitude
/// and corrupt the comparison.
pub fn fake_quantize(x: &mut [f32], scheme: Scheme) -> f32 {
    match scheme {
        Scheme::Fp8E4m3 => fp8_quantize_slice(x, Fp8Format::E4M3),
        Scheme::Fp8E5m2 => fp8_quantize_slice(x, Fp8Format::E5M2),
        Scheme::Int8 => int_quantize_slice(x, IntBits::Int8),
        Scheme::Int4 => int_quantize_slice(x, IntBits::Int4),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names_roundtrip() {
        for s in [Scheme::Fp8E4m3, Scheme::Fp8E5m2, Scheme::Int8, Scheme::Int4] {
            assert_eq!(Scheme::parse(s.name()), Some(s));
        }
        assert_eq!(Scheme::parse("fp8"), Some(Scheme::Fp8E4m3));
        assert_eq!(Scheme::parse("fp7"), None);
    }

    #[test]
    fn epilogue_validation() {
        assert!(Epilogue::None.validate(256).is_ok());
        assert!(Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 }.validate(256).is_ok());
        assert!(Epilogue::QuantInt8 { group: 32 }.validate(256).is_ok());
        assert!(Epilogue::QuantInt8 { group: 256 }.validate(256).is_ok());
        assert!(Epilogue::QuantInt8 { group: 0 }.validate(256).is_err());
        assert!(Epilogue::QuantInt8 { group: 48 }.validate(256).is_err());
        assert!(Epilogue::None.is_none());
        assert!(!Epilogue::QuantInt8 { group: 32 }.is_none());
        assert_eq!(QuantScales::PerTensor(0.5).per_tensor(), Some(0.5));
        assert_eq!(QuantScales::None.per_tensor(), None);
    }

    #[test]
    fn fake_quantize_reduces_precision_but_preserves_scale() {
        let mut rng = crate::util::rng::Rng::new(1);
        for scheme in [Scheme::Fp8E4m3, Scheme::Int8, Scheme::Int4] {
            let x = rng.normal_vec(4096);
            let mut q = x.clone();
            fake_quantize(&mut q, scheme);
            let err = crate::util::prop::rel_l2(&q, &x);
            assert!(err > 1e-5, "{scheme:?} should not be lossless: {err}");
            assert!(err < 0.3, "{scheme:?} error too large: {err}");
        }
    }
}
