//! Request admission and backend routing.
//!
//! A request for `(kernel, n)` routes to:
//! * the **PJRT backend** when a matching AOT artifact exists and the
//!   request doesn't force native execution — the production path of the
//!   three-layer architecture. PJRT executables are `Rc`-based (not
//!   `Send`), so the route carries the artifact *name*; a dedicated
//!   executor thread owns the `Runtime` and resolves names locally.
//! * the **native backend** (in-process Rust kernel) otherwise — the
//!   substrate path, also used by benchmarks to measure kernel cost
//!   without PJRT dispatch overhead.
//!
//! Admission accepts the full `B * 2^k` size family
//! (`B ∈ {1, 12, 20, 28, 40}`, see [`crate::hadamard::split_base`]);
//! non-power-of-two sizes always route native because the AOT lowering
//! only emits power-of-two modules.

use std::collections::HashMap;
use std::sync::Arc;

use crate::hadamard::{is_pow2, is_supported_size, KernelKind};
use crate::runtime::Manifest;
use crate::MAX_HADAMARD_SIZE;

use super::TransformRequest;

/// A PJRT bucket descriptor (artifact identity + fixed shape).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PjrtBucket {
    /// Manifest artifact name.
    pub artifact: Arc<str>,
    /// Fixed row count of the compiled module.
    pub rows: usize,
}

/// Execution backend chosen for a bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// In-process Rust kernel.
    Native,
    /// Compiled PJRT executable with a fixed `(rows, n)` shape.
    Pjrt(PjrtBucket),
}

impl Backend {
    /// Short label for metrics/responses.
    pub fn label(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }
}

/// The routing decision for a request.
#[derive(Clone, Debug)]
pub struct Route {
    /// Backend to execute on.
    pub backend: Backend,
    /// Row capacity of the bucket (PJRT: the artifact's fixed rows;
    /// native: the configured max batch rows).
    pub capacity_rows: usize,
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Max rows per native batch (PJRT batches are fixed by the artifact).
    pub native_batch_rows: usize,
    /// Reject requests with more rows than this.
    pub max_request_rows: usize,
    /// Disable the PJRT backend entirely (native-only serving).
    pub native_only: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            native_batch_rows: 64,
            max_request_rows: 1 << 16,
            native_only: false,
        }
    }
}

/// Admission + dispatch table. Built once at server start from the
/// manifest (no PJRT handles held here — names only).
pub struct Router {
    cfg: RouterConfig,
    pjrt: HashMap<(KernelKind, usize), PjrtBucket>,
}

impl Router {
    /// Build a router over the manifest's fwht artifacts. Pass `None` to
    /// run native-only (no artifacts needed).
    ///
    /// Only power-of-two artifact sizes are bucketed: the AOT lowering
    /// (and the PJRT stub's manifests) emit power-of-two modules only,
    /// so non-power-of-two `B * 2^k` sizes always serve on the native
    /// engine — a manifest entry claiming such a size is ignored rather
    /// than routed to a module that cannot exist.
    pub fn new(manifest: Option<&Manifest>, cfg: RouterConfig) -> Router {
        let mut pjrt = HashMap::new();
        if let Some(m) = manifest {
            if !cfg.native_only {
                for e in m.artifacts.iter().filter(|e| e.op == "fwht") {
                    let kernel = e
                        .kernel
                        .as_deref()
                        .and_then(KernelKind::parse)
                        .unwrap_or(KernelKind::HadaCore);
                    let n = e.n.unwrap_or(0);
                    if !is_pow2(n) {
                        continue;
                    }
                    pjrt.insert(
                        (kernel, n),
                        PjrtBucket {
                            artifact: Arc::from(e.name.as_str()),
                            rows: e.rows.unwrap_or(1),
                        },
                    );
                }
            }
        }
        Router { cfg, pjrt }
    }

    /// Validate a request; `Err` carries the rejection reason.
    ///
    /// Accepted transform sizes are `B * 2^k` with
    /// `B ∈ {1, 12, 20, 28, 40}` — the fast-hadamard-transform base
    /// family, which admits the Llama hidden dims (14336 = 28·512,
    /// 28672 = 28·1024) alongside the plain powers of two.
    ///
    /// # Examples
    ///
    /// ```
    /// use hadacore::coordinator::{Router, RouterConfig, TransformRequest};
    ///
    /// let router = Router::new(None, RouterConfig::default());
    /// // 768 = 12 * 2^6 — a non-power-of-two size in the family
    /// assert!(router.admit(&TransformRequest::new(1, 768, vec![0.0; 768])).is_ok());
    /// // rejections name the accepted family, not just "not a power of 2"
    /// let err = router
    ///     .admit(&TransformRequest::new(2, 10, vec![0.0; 10]))
    ///     .unwrap_err();
    /// assert!(err.contains("12, 20, 28, 40"));
    /// ```
    pub fn admit(&self, req: &TransformRequest) -> Result<(), String> {
        if !is_supported_size(req.n) {
            return Err(format!(
                "n={} is not a supported transform size; accepted sizes are \
                 B * 2^k with B in {{1, 12, 20, 28, 40}} (e.g. 1024, \
                 768 = 12*64, 5120 = 20*256, 14336 = 28*512, 40960 = 40*1024)",
                req.n
            ));
        }
        if req.n > MAX_HADAMARD_SIZE {
            return Err(format!(
                "n={} exceeds max supported size {}",
                req.n, MAX_HADAMARD_SIZE
            ));
        }
        if req.data.len() != req.rows * req.n {
            return Err(format!(
                "payload length {} != rows {} * n {}",
                req.data.len(),
                req.rows,
                req.n
            ));
        }
        if req.rows == 0 {
            return Err("empty request".to_string());
        }
        if req.rows > self.cfg.max_request_rows {
            return Err(format!(
                "rows {} exceeds per-request limit {}",
                req.rows, self.cfg.max_request_rows
            ));
        }
        if let Some(s) = req.scale {
            // a NaN scale would alias the batcher's no-scale bucket
            // sentinel (a NaN bit pattern) and batchmates would then be
            // executed under this request's scale; infinities produce
            // garbage rows. Reject both outright.
            if !s.is_finite() {
                return Err(format!("scale {s} is not finite"));
            }
        }
        req.prologue.validate(req.n)?;
        req.epilogue.validate(req.n)?;
        Ok(())
    }

    /// Choose the backend + bucket for an admitted request.
    ///
    /// PJRT buckets are only usable when the request's scale is the
    /// artifact's baked-in orthonormal scale, it carries no fused
    /// prologue or epilogue (artifacts have neither a sign-flip nor a
    /// quantise stage), and its rows fit the bucket.
    pub fn route(&self, req: &TransformRequest) -> Route {
        if !req.force_native
            && req.scale.is_none()
            && req.prologue.is_none()
            && req.epilogue.is_none()
        {
            if let Some(bucket) = self.pjrt.get(&(req.kernel, req.n)) {
                if req.rows <= bucket.rows {
                    return Route {
                        backend: Backend::Pjrt(bucket.clone()),
                        capacity_rows: bucket.rows,
                    };
                }
            }
        }
        Route {
            backend: Backend::Native,
            capacity_rows: self.cfg.native_batch_rows.max(req.rows),
        }
    }

    /// Number of PJRT-backed (kernel, n) buckets.
    pub fn pjrt_bucket_count(&self) -> usize {
        self.pjrt.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TransformRequest;
    use crate::runtime::Manifest;

    fn native_router() -> Router {
        Router::new(None, RouterConfig::default())
    }

    fn manifest_router() -> Router {
        let m = Manifest::parse(
            r#"{"artifacts": [
                {"name": "fwht_hadacore_256x128", "op": "fwht",
                 "kernel": "hadacore", "file": "x.hlo.txt",
                 "n": 256, "rows": 128,
                 "inputs": [{"shape": [128, 256], "dtype": "float32"}],
                 "outputs": [{"shape": [128, 256], "dtype": "float32"}]}
               ],
               "weights": [], "model": {}}"#,
        )
        .unwrap();
        Router::new(Some(&m), RouterConfig::default())
    }

    #[test]
    fn admits_valid_rejects_invalid() {
        let r = native_router();
        let ok = TransformRequest::new(1, 256, vec![0.0; 256 * 2]);
        assert!(r.admit(&ok).is_ok());

        let bad_n = TransformRequest::new(2, 100, vec![0.0; 100]);
        assert!(r.admit(&bad_n).is_err());

        let too_big = TransformRequest::new(3, 1 << 17, vec![0.0; 1 << 17]);
        assert!(r.admit(&too_big).is_err());

        let mut mismatched = TransformRequest::new(4, 256, vec![0.0; 256]);
        mismatched.rows = 7;
        assert!(r.admit(&mismatched).is_err());

        let mut empty = TransformRequest::new(5, 256, vec![]);
        empty.rows = 0;
        assert!(r.admit(&empty).is_err());
    }

    #[test]
    fn non_pow2_family_admission_and_rejection_message() {
        let r = native_router();
        // every base at a couple of 2^k, including the Llama dims
        for n in [12usize, 24, 768, 5120, 14336, 28672, 40960] {
            let req = TransformRequest::new(1, n, vec![0.0; n]);
            assert!(r.admit(&req).is_ok(), "n={n} must be admitted");
        }
        // rejection enumerates the accepted family instead of the old
        // bare "not a power of 2" string
        for n in [10usize, 36, 44, 11008] {
            let err = r
                .admit(&TransformRequest::new(2, n, vec![0.0; n]))
                .unwrap_err();
            assert!(
                err.contains("B * 2^k") && err.contains("12, 20, 28, 40"),
                "n={n}: message must enumerate the size family, got: {err}"
            );
        }
    }

    #[test]
    fn non_pow2_sizes_always_route_native() {
        // a manifest that (incorrectly) claims a non-power-of-two
        // artifact: the router must ignore it — the AOT lowering only
        // emits power-of-two modules
        let m = Manifest::parse(
            r#"{"artifacts": [
                {"name": "fwht_hadacore_768x64", "op": "fwht",
                 "kernel": "hadacore", "file": "x.hlo.txt",
                 "n": 768, "rows": 64,
                 "inputs": [{"shape": [64, 768], "dtype": "float32"}],
                 "outputs": [{"shape": [64, 768], "dtype": "float32"}]},
                {"name": "fwht_hadacore_256x128", "op": "fwht",
                 "kernel": "hadacore", "file": "x.hlo.txt",
                 "n": 256, "rows": 128,
                 "inputs": [{"shape": [128, 256], "dtype": "float32"}],
                 "outputs": [{"shape": [128, 256], "dtype": "float32"}]}
               ],
               "weights": [], "model": {}}"#,
        )
        .unwrap();
        let r = Router::new(Some(&m), RouterConfig::default());
        assert_eq!(r.pjrt_bucket_count(), 1, "non-pow2 artifact must be dropped");
        let req = TransformRequest::new(1, 768, vec![0.0; 768]);
        assert!(r.admit(&req).is_ok());
        assert!(matches!(r.route(&req).backend, Backend::Native));
        // the pow2 sibling still routes to its module
        let pow2 = TransformRequest::new(2, 256, vec![0.0; 256]);
        assert!(matches!(r.route(&pow2).backend, Backend::Pjrt(_)));
    }

    #[test]
    fn native_only_routes_native() {
        let r = native_router();
        let req = TransformRequest::new(1, 512, vec![0.0; 512]);
        let route = r.route(&req);
        assert!(matches!(route.backend, Backend::Native));
        assert_eq!(route.capacity_rows, 64);
        assert_eq!(r.pjrt_bucket_count(), 0);
    }

    #[test]
    fn manifest_buckets_route_to_pjrt() {
        let r = manifest_router();
        assert_eq!(r.pjrt_bucket_count(), 1);
        let req = TransformRequest::new(1, 256, vec![0.0; 256 * 4]);
        let route = r.route(&req);
        match route.backend {
            Backend::Pjrt(b) => {
                assert_eq!(&*b.artifact, "fwht_hadacore_256x128");
                assert_eq!(b.rows, 128);
            }
            Backend::Native => panic!("expected pjrt route"),
        }
        // unmatched size falls back to native
        let other = TransformRequest::new(2, 64, vec![0.0; 64]);
        assert!(matches!(r.route(&other).backend, Backend::Native));
    }

    #[test]
    fn non_finite_scales_are_rejected_at_admission() {
        let r = native_router();
        // the exact bit pattern of the batcher's no-scale sentinel: if it
        // were admitted it would land in the None-scale bucket and
        // batchmates would execute under this request's "scale"
        let mut sentinel = TransformRequest::new(1, 256, vec![0.0; 256]);
        sentinel.scale = Some(f32::from_bits(0x7fc0_0001));
        assert!(r.admit(&sentinel).is_err());

        let mut nan = TransformRequest::new(2, 256, vec![0.0; 256]);
        nan.scale = Some(f32::NAN);
        assert!(r.admit(&nan).is_err());

        let mut inf = TransformRequest::new(3, 256, vec![0.0; 256]);
        inf.scale = Some(f32::INFINITY);
        assert!(r.admit(&inf).is_err());

        let mut finite = TransformRequest::new(4, 256, vec![0.0; 256]);
        finite.scale = Some(2.5);
        assert!(r.admit(&finite).is_ok());
    }

    #[test]
    fn epilogue_admission_and_native_routing() {
        use crate::quant::{Epilogue, Fp8Format};
        let r = manifest_router();

        // a bad int8 group is rejected outright
        let mut bad = TransformRequest::new(1, 256, vec![0.0; 256]);
        bad.epilogue = Epilogue::QuantInt8 { group: 48 };
        assert!(r.admit(&bad).is_err());

        // a valid epilogue admits but always routes native, even when a
        // matching artifact exists
        let mut fp8 = TransformRequest::new(2, 256, vec![0.0; 256]);
        fp8.epilogue = Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 };
        assert!(r.admit(&fp8).is_ok());
        assert!(matches!(r.route(&fp8).backend, Backend::Native));

        // the same request without the epilogue goes to pjrt
        let plain = TransformRequest::new(3, 256, vec![0.0; 256]);
        assert!(matches!(r.route(&plain).backend, Backend::Pjrt(_)));
    }

    #[test]
    fn prologue_admission_and_native_routing() {
        use crate::hadamard::Prologue;
        let r = manifest_router();

        // a rotation request admits but always routes native, even when
        // a matching artifact exists — PJRT modules have no sign-flip
        let mut rot = TransformRequest::new(1, 256, vec![0.0; 256]);
        rot.prologue = Prologue::SignFlip { seed: 7 };
        assert!(r.admit(&rot).is_ok());
        assert!(matches!(r.route(&rot).backend, Backend::Native));

        // the same request without the prologue goes to pjrt
        let plain = TransformRequest::new(2, 256, vec![0.0; 256]);
        assert!(matches!(r.route(&plain).backend, Backend::Pjrt(_)));
    }

    #[test]
    fn custom_scale_or_force_native_bypasses_pjrt() {
        let r = manifest_router();
        let mut req = TransformRequest::new(1, 256, vec![0.0; 256]);
        req.scale = Some(2.0);
        assert!(matches!(r.route(&req).backend, Backend::Native));

        let mut req2 = TransformRequest::new(2, 256, vec![0.0; 256]);
        req2.force_native = true;
        assert!(matches!(r.route(&req2).backend, Backend::Native));
    }

    #[test]
    fn rows_exceeding_bucket_fall_back_to_native() {
        let r = manifest_router();
        let req = TransformRequest::new(1, 256, vec![0.0; 256 * 500]);
        let route = r.route(&req);
        assert!(matches!(route.backend, Backend::Native));
        assert_eq!(route.capacity_rows, 500);
    }

    #[test]
    fn native_only_flag_disables_pjrt() {
        let m = Manifest::parse(
            r#"{"artifacts": [], "weights": [], "model": {}}"#,
        )
        .unwrap();
        let r = Router::new(
            Some(&m),
            RouterConfig { native_only: true, ..Default::default() },
        );
        assert_eq!(r.pjrt_bucket_count(), 0);
    }
}
