//! Bucketed dynamic batching.
//!
//! Requests are grouped by [`BucketKey`] (kernel, size, backend class).
//! A bucket flushes when its accumulated rows reach the bucket capacity
//! or when the oldest request has waited `max_delay`. Workers block on a
//! condvar whose timeout is the nearest deadline, so flushes happen
//! within one scheduler quantum of the deadline without busy-waiting.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::hadamard::{KernelKind, Prologue};
use crate::quant::Epilogue;

use super::router::Route;
use super::Pending;

/// Batch grouping key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    /// Kernel implementation.
    pub kernel: KernelKind,
    /// Hadamard size.
    pub n: usize,
    /// Whether this bucket executes on PJRT (fixed shape) or native.
    pub pjrt: bool,
    /// Scale bits (None-scale buckets batch together; custom scales are
    /// per-value buckets so one batch has one scale). The `None` sentinel
    /// is a NaN bit pattern, which cannot collide with an admitted
    /// custom scale: the router rejects non-finite scales.
    pub scale_bits: u32,
    /// Fused sign-flip prologue — the sign vector is a pure function of
    /// `(seed, n)`, so rows of same-seed requests may share a batch; a
    /// different seed (or no prologue) is a different bucket.
    pub prologue: Prologue,
    /// Fused quantize epilogue — epilogue buckets never mix with plain
    /// ones (their responses carry scales and they always route native).
    pub epilogue: Epilogue,
}

impl BucketKey {
    /// Build a key from a request + its route.
    pub fn of(req: &super::TransformRequest, route: &Route) -> BucketKey {
        BucketKey {
            kernel: req.kernel,
            n: req.n,
            pjrt: matches!(route.backend, super::Backend::Pjrt(_)),
            scale_bits: req.scale.map(f32::to_bits).unwrap_or(0x7fc0_0001),
            prologue: req.prologue,
            epilogue: req.epilogue,
        }
    }
}

/// A flushed batch ready for execution.
pub struct Batch {
    /// Grouping key.
    pub key: BucketKey,
    /// The route shared by every request in the batch.
    pub route: Route,
    /// Requests, in arrival order.
    pub items: Vec<Pending>,
    /// Total data rows (<= route.capacity_rows).
    pub rows: usize,
}

struct Bucket {
    route: Route,
    items: Vec<Pending>,
    rows: usize,
    oldest: Instant,
}

/// Batcher configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max time the oldest request may wait before a partial flush.
    pub max_delay: Duration,
    /// Work-conserving mode (§Perf): an idle worker flushes a non-empty
    /// *native* bucket immediately instead of sleeping on the deadline.
    /// Under load, batches still form naturally (requests accumulate
    /// while workers execute — vLLM-style continuous batching); at low
    /// load, requests stop paying the deadline as pure latency. PJRT
    /// buckets keep the deadline: their fixed shapes only pay off when
    /// reasonably filled.
    pub work_conserving: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_delay: Duration::from_micros(500),
            work_conserving: true,
        }
    }
}

/// The shared batching state.
pub struct Batcher {
    cfg: BatcherConfig,
    state: Mutex<State>,
    ready: Condvar,
}

struct State {
    buckets: HashMap<BucketKey, Bucket>,
    shutdown: bool,
    /// Emptied batch `items` vectors handed back by workers via
    /// [`Batcher::recycle`]; a flush pops one instead of allocating a
    /// fresh `Vec<Pending>` per batch (zero-copy serve path).
    spare: Vec<Vec<Pending>>,
}

/// Most spare batch vectors retained; beyond this they drop normally
/// (bounds idle memory — one per worker is plenty in steady state).
const SPARE_CAP: usize = 32;

impl Batcher {
    /// Empty batcher.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            state: Mutex::new(State {
                buckets: HashMap::new(),
                shutdown: false,
                spare: Vec::with_capacity(SPARE_CAP),
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a pending request under its route. Returns `false` (and
    /// drops the item) once [`Batcher::shutdown`] has been called: the
    /// decision is made under the same lock that guards the shutdown
    /// flag, so no item can slip in behind the draining workers and
    /// strand its response channel.
    #[must_use]
    pub fn push(&self, key: BucketKey, route: Route, item: Pending) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return false;
        }
        let rows = item.req.rows;
        let bucket = st.buckets.entry(key).or_insert_with(|| Bucket {
            route: route.clone(),
            items: Vec::new(),
            rows: 0,
            oldest: Instant::now(),
        });
        if bucket.items.is_empty() {
            bucket.oldest = item.enqueued;
        }
        bucket.items.push(item);
        bucket.rows += rows;
        let full = bucket.rows >= bucket.route.capacity_rows;
        drop(st);
        if full {
            self.ready.notify_all();
        } else {
            // a worker may be sleeping until an earlier deadline; waking one
            // lets it recompute (cheap, and only on request arrival)
            self.ready.notify_one();
        }
        true
    }

    /// Worker call: block until a batch is ready (full or expired), the
    /// shutdown flag is set (returns remaining batches until drained, then
    /// `None`), or `idle_timeout` passes with nothing to do.
    pub fn next_batch(&self, idle_timeout: Duration) -> Option<Batch> {
        let deadline_cap = Instant::now() + idle_timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // pick: any full/expired bucket; else (work-conserving) the
            // fullest native bucket; else wait until the nearest deadline
            let mut chosen: Option<BucketKey> = None;
            let mut nearest: Option<Instant> = None;
            let mut fallback: Option<(BucketKey, usize)> = None;
            for (k, b) in st.buckets.iter() {
                if b.items.is_empty() {
                    continue;
                }
                let expires = b.oldest + self.cfg.max_delay;
                if b.rows >= b.route.capacity_rows || expires <= now || st.shutdown {
                    chosen = Some(*k);
                    break;
                }
                if self.cfg.work_conserving && !k.pjrt {
                    match fallback {
                        Some((_, rows)) if rows >= b.rows => {}
                        _ => fallback = Some((*k, b.rows)),
                    }
                }
                nearest = Some(match nearest {
                    Some(t) if t < expires => t,
                    _ => expires,
                });
            }
            let chosen = chosen.or(fallback.map(|(k, _)| k));
            if let Some(key) = chosen {
                // recycled batch vector: retained capacity means no
                // allocation per flush in steady state
                let mut items = st.spare.pop().unwrap_or_default();
                let bucket = st.buckets.get_mut(&key).unwrap();
                // flush up to capacity rows, keeping arrival order; requests
                // beyond capacity stay queued for the next batch
                let cap = bucket.route.capacity_rows;
                let mut rows = 0;
                let mut take = 0;
                for p in bucket.items.iter() {
                    if take > 0 && rows + p.req.rows > cap {
                        break;
                    }
                    rows += p.req.rows;
                    take += 1;
                }
                items.extend(bucket.items.drain(..take));
                bucket.rows -= rows;
                if !bucket.items.is_empty() {
                    bucket.oldest = items
                        .last()
                        .map(|_| bucket.items[0].enqueued)
                        .unwrap_or_else(Instant::now);
                }
                let route = bucket.route.clone();
                return Some(Batch { key, route, items, rows });
            }
            if st.shutdown {
                return None;
            }
            let wait_until = nearest.unwrap_or(deadline_cap).min(deadline_cap);
            let now = Instant::now();
            if wait_until <= now {
                match nearest {
                    // a bucket deadline has expired; rescan chooses it
                    Some(t) if t <= now => continue,
                    // idle timeout: queues empty, or nothing due before
                    // the cap — return to the caller instead of spinning
                    // until the nearest deadline
                    _ => return None,
                }
            }
            let (guard, _timeout) =
                self.ready.wait_timeout(st, wait_until - now).unwrap();
            st = guard;
            if st.shutdown && st.buckets.values().all(|b| b.items.is_empty()) {
                return None;
            }
        }
    }

    /// Hand an emptied [`Batch::items`] vector back for reuse by a later
    /// flush. Clears it defensively; keeps at most [`SPARE_CAP`] spares.
    pub fn recycle(&self, mut v: Vec<Pending>) {
        v.clear(); // drop any stragglers outside the lock
        let mut st = self.state.lock().unwrap();
        if st.spare.len() < SPARE_CAP {
            st.spare.push(v);
        }
    }

    /// Signal shutdown; workers drain remaining items then return `None`.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }

    /// Rows currently queued across all buckets.
    pub fn queued_rows(&self) -> usize {
        self.state.lock().unwrap().buckets.values().map(|b| b.rows).sum()
    }

    /// True once [`Batcher::shutdown`] has been called.
    pub fn is_shutdown(&self) -> bool {
        self.state.lock().unwrap().shutdown
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, TransformRequest};
    use crate::util::error as anyhow;
    use std::sync::mpsc;

    fn pending(id: u64, n: usize, rows: usize) -> (Pending, mpsc::Receiver<anyhow::Result<crate::coordinator::TransformResponse>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: TransformRequest::new(id, n, vec![0.0; n * rows]),
                tx: crate::coordinator::ResponseTx::Oneshot(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn key_route(n: usize, cap: usize) -> (BucketKey, Route) {
        let route = Route { backend: Backend::Native, capacity_rows: cap };
        let req = TransformRequest::new(0, n, vec![0.0; n]);
        (BucketKey::of(&req, &route), route)
    }

    #[test]
    fn full_bucket_flushes_immediately() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_secs(10), work_conserving: false });
        let (key, route) = key_route(64, 4);
        for i in 0..4 {
            let (p, _rx) = pending(i, 64, 1);
            assert!(b.push(key, route.clone(), p));
        }
        let batch = b.next_batch(Duration::from_millis(100)).expect("batch");
        assert_eq!(batch.rows, 4);
        assert_eq!(batch.items.len(), 4);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_millis(5), work_conserving: false });
        let (key, route) = key_route(64, 100);
        let (p, _rx) = pending(1, 64, 2);
        assert!(b.push(key, route, p));
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert_eq!(batch.rows, 2);
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert!(t0.elapsed() < Duration::from_millis(300), "flushed too late");
    }

    #[test]
    fn capacity_splits_across_batches() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_millis(1), work_conserving: false });
        let (key, route) = key_route(32, 4);
        for i in 0..3 {
            let (p, _rx) = pending(i, 32, 3); // 3 rows each, cap 4
            assert!(b.push(key, route.clone(), p));
        }
        // each batch takes one 3-row request (3+3 > 4)... first batch takes
        // request 0 only (3 rows); adding request 1 would exceed cap.
        let b1 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b1.rows, 3);
        let b2 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b2.rows, 3);
        let b3 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b3.rows, 3);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn oversized_request_flushes_alone() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_secs(1), work_conserving: false });
        let (key, route) = key_route(32, 4);
        let (p, _rx) = pending(9, 32, 10); // exceeds capacity
        assert!(b.push(key, route, p));
        let batch = b.next_batch(Duration::from_millis(200)).unwrap();
        assert_eq!(batch.rows, 10);
        assert_eq!(batch.items.len(), 1);
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_secs(10), work_conserving: false });
        let (key, route) = key_route(16, 100);
        let (p, _rx) = pending(1, 16, 1);
        assert!(b.push(key, route, p));
        b.shutdown();
        assert!(b.next_batch(Duration::from_millis(50)).is_some());
        assert!(b.next_batch(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn push_after_shutdown_is_refused() {
        // the submit-vs-drain race: once shutdown is set, no item may
        // enter the queue (it would sit behind already-exited workers)
        let b = Batcher::new(BatcherConfig::default());
        b.shutdown();
        let (key, route) = key_route(64, 4);
        let (p, rx) = pending(1, 64, 1);
        assert!(!b.push(key, route, p), "post-shutdown push must be refused");
        assert_eq!(b.queued_rows(), 0);
        // the dropped Pending closes its response channel: a waiting
        // caller observes a disconnect, not an eternal hang
        assert!(rx.recv().is_err());
    }

    #[test]
    fn idle_timeout_returns_none() {
        let b = Batcher::new(BatcherConfig::default());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn epilogue_buckets_never_mix_with_plain() {
        use crate::quant::{Epilogue, Fp8Format};
        let route = Route { backend: Backend::Native, capacity_rows: 8 };
        let plain = TransformRequest::new(1, 256, vec![0.0; 256]);
        let mut fp8 = TransformRequest::new(2, 256, vec![0.0; 256]);
        fp8.epilogue = Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 };
        let mut int8 = TransformRequest::new(3, 256, vec![0.0; 256]);
        int8.epilogue = Epilogue::QuantInt8 { group: 64 };
        let kp = BucketKey::of(&plain, &route);
        let kf = BucketKey::of(&fp8, &route);
        let ki = BucketKey::of(&int8, &route);
        assert_ne!(kp, kf);
        assert_ne!(kp, ki);
        assert_ne!(kf, ki);
        // distinct int8 groups are distinct buckets too
        let mut int8b = TransformRequest::new(4, 256, vec![0.0; 256]);
        int8b.epilogue = Epilogue::QuantInt8 { group: 32 };
        assert_ne!(ki, BucketKey::of(&int8b, &route));
    }

    #[test]
    fn prologue_buckets_separate_by_seed() {
        use crate::hadamard::Prologue;
        let route = Route { backend: Backend::Native, capacity_rows: 8 };
        let plain = TransformRequest::new(1, 256, vec![0.0; 256]);
        let mut rot_a = TransformRequest::new(2, 256, vec![0.0; 256]);
        rot_a.prologue = Prologue::SignFlip { seed: 1 };
        let mut rot_b = TransformRequest::new(3, 256, vec![0.0; 256]);
        rot_b.prologue = Prologue::SignFlip { seed: 2 };
        let kp = BucketKey::of(&plain, &route);
        let ka = BucketKey::of(&rot_a, &route);
        let kb = BucketKey::of(&rot_b, &route);
        assert_ne!(kp, ka, "rotated must not batch with plain");
        assert_ne!(ka, kb, "different seeds must not share a batch");
        // same seed → same bucket: rows may share one engine call
        let mut rot_c = TransformRequest::new(4, 256, vec![0.0; 256]);
        rot_c.prologue = Prologue::SignFlip { seed: 1 };
        assert_eq!(ka, BucketKey::of(&rot_c, &route));
    }

    fn pjrt_key_route(n: usize, cap: usize) -> (BucketKey, Route) {
        use crate::coordinator::router::PjrtBucket;
        use std::sync::Arc;
        let route = Route {
            backend: Backend::Pjrt(PjrtBucket {
                artifact: Arc::from("fwht_test"),
                rows: cap,
            }),
            capacity_rows: cap,
        };
        let req = TransformRequest::new(0, n, vec![0.0; n]);
        (BucketKey::of(&req, &route), route)
    }

    #[test]
    fn work_conserving_flushes_native_immediately() {
        // an idle worker must not sleep out the 10s deadline on a
        // non-empty native bucket
        let b = Batcher::new(BatcherConfig {
            max_delay: Duration::from_secs(10),
            work_conserving: true,
        });
        let (key, route) = key_route(64, 100);
        let (p, _rx) = pending(1, 64, 2);
        assert!(b.push(key, route, p));
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(5)).expect("batch");
        assert_eq!(batch.rows, 2);
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "work-conserving flush waited {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn work_conserving_picks_the_fullest_native_bucket() {
        let b = Batcher::new(BatcherConfig {
            max_delay: Duration::from_secs(10),
            work_conserving: true,
        });
        let (k1, r1) = key_route(64, 100);
        let (k2, r2) = key_route(128, 100);
        let (p1, _rx1) = pending(1, 64, 1);
        assert!(b.push(k1, r1, p1));
        let (p2, _rx2) = pending(2, 128, 3);
        assert!(b.push(k2, r2, p2));
        let batch = b.next_batch(Duration::from_secs(5)).expect("batch");
        assert_eq!(batch.key.n, 128, "fullest bucket (3 rows) flushes first");
        assert_eq!(batch.rows, 3);
        let batch = b.next_batch(Duration::from_secs(5)).expect("batch");
        assert_eq!(batch.key.n, 64);
    }

    #[test]
    fn work_conserving_pjrt_buckets_still_honor_the_deadline() {
        let b = Batcher::new(BatcherConfig {
            max_delay: Duration::from_millis(40),
            work_conserving: true,
        });
        let (key, route) = pjrt_key_route(64, 128);
        let (p, _rx) = pending(1, 64, 2);
        assert!(b.push(key, route, p));
        // an idle cap shorter than the deadline returns None (no flush,
        // no busy spin) ...
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(5)).is_none());
        assert!(t0.elapsed() < Duration::from_millis(35), "returned late");
        // ... and a longer wait flushes only once the deadline expires
        let batch = b.next_batch(Duration::from_secs(2)).expect("batch");
        assert_eq!(batch.rows, 2);
        assert!(
            t0.elapsed() >= Duration::from_millis(38),
            "pjrt bucket flushed before its deadline: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn recycled_vectors_are_reused_by_later_flushes() {
        let b = Batcher::new(BatcherConfig {
            max_delay: Duration::from_millis(1),
            work_conserving: true,
        });
        let (key, route) = key_route(64, 4);
        let (p, _rx) = pending(1, 64, 1);
        assert!(b.push(key, route.clone(), p));
        let batch = b.next_batch(Duration::from_millis(100)).unwrap();
        let mut items = batch.items;
        items.clear();
        items.reserve(16);
        let ptr = items.as_ptr();
        b.recycle(items);
        // the next flush must pop the recycled storage, not allocate
        let (p, _rx2) = pending(2, 64, 1);
        assert!(b.push(key, route, p));
        let batch = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(batch.items.as_ptr(), ptr, "flush must reuse the spare");
    }

    #[test]
    fn distinct_buckets_do_not_mix() {
        let b = Batcher::new(BatcherConfig { max_delay: Duration::from_millis(1), work_conserving: false });
        let (k1, r1) = key_route(64, 8);
        let (k2, r2) = key_route(128, 8);
        assert_ne!(k1, k2);
        let (p1, _rx1) = pending(1, 64, 1);
        let (p2, _rx2) = pending(2, 128, 1);
        assert!(b.push(k1, r1, p1));
        assert!(b.push(k2, r2, p2));
        let b1 = b.next_batch(Duration::from_millis(100)).unwrap();
        let b2 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_ne!(b1.key.n, b2.key.n);
        assert_eq!(b1.items.len(), 1);
        assert_eq!(b2.items.len(), 1);
    }
}
