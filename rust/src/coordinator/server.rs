//! The coordinator server: worker pool + dedicated PJRT executor thread.
//!
//! `Coordinator::submit` is the client API: admission via the router,
//! enqueue into the batcher, and a receiver handle for the response.
//!
//! Threading model: PJRT executables are `Rc`-based (not `Send`), so one
//! **executor thread** owns the `Runtime` and performs every PJRT
//! execution (the CPU analogue of a GPU-owning executor). The worker pool
//! drains the batcher; native batches execute on the shared
//! [`ExecEngine`], which shards each batch's rows across *its* worker
//! pool — batcher workers handle assembly/completion concurrency, the
//! engine handles compute parallelism. PJRT batches are forwarded to the
//! executor over a channel. Responses complete per-request channels
//! either way.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::exec::{ExecConfig, ExecEngine};
use crate::hadamard::FwhtOptions;
use crate::runtime::{literal_f32, literal_to_f32, Manifest, Runtime};
use crate::util::error::{self as anyhow, anyhow};

use super::batcher::{Batch, Batcher, BatcherConfig, BucketKey};
use super::metrics::Metrics;
use super::router::{Backend, Router, RouterConfig};
use super::{Pending, TransformRequest, TransformResponse};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker thread count (batch assembly + response completion; the
    /// compute itself parallelises on the [`ExecEngine`] lanes).
    pub workers: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Routing policy.
    pub router: RouterConfig,
    /// Execution-engine geometry (compute lanes, chunking).
    pub exec: ExecConfig,
    /// Worker idle poll interval (shutdown latency bound).
    pub idle_timeout: Duration,
    /// Compile all fwht artifacts at startup (vs lazily on first use).
    /// Keeps compile stalls off the serving hot path.
    pub preload_pjrt: bool,
    /// Deadline-flushed PJRT batches whose fill fraction is below this
    /// threshold execute on the native kernel instead — padding a 128-row
    /// module to transform 4 rows costs more than doing the 4 rows on the
    /// CPU kernel directly.
    pub min_pjrt_fill: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            exec: ExecConfig::default(),
            idle_timeout: Duration::from_millis(50),
            preload_pjrt: true,
            min_pjrt_fill: 0.25,
        }
    }
}

/// Submission failure (admission rejection).
#[derive(Debug)]
pub struct SubmitError(pub String);

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request rejected: {}", self.0)
    }
}

impl std::error::Error for SubmitError {}

/// Response receiver handle.
pub type ResponseRx = mpsc::Receiver<anyhow::Result<TransformResponse>>;

/// The running coordinator.
pub struct Coordinator {
    router: Arc<Router>,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    engine: Arc<ExecEngine>,
    workers: Vec<JoinHandle<()>>,
    pjrt_tx: Option<mpsc::Sender<Batch>>,
    pjrt_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the coordinator. `artifact_dir` enables the PJRT backend
    /// (the executor thread opens the `Runtime` there); `None` runs
    /// native-only.
    pub fn start(
        artifact_dir: Option<PathBuf>,
        cfg: CoordinatorConfig,
    ) -> anyhow::Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        let engine = Arc::new(ExecEngine::new(cfg.exec));

        // PJRT executor thread (owns the non-Send Runtime)
        let mut pjrt_tx = None;
        let mut pjrt_thread = None;
        let mut manifest: Option<Manifest> = None;
        if let Some(dir) = artifact_dir {
            manifest = Some(Manifest::load(&dir.join("manifest.json"))?);
            let (tx, rx) = mpsc::channel::<Batch>();
            let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
            let m = Arc::clone(&metrics);
            let preload = cfg.preload_pjrt;
            let handle = std::thread::Builder::new()
                .name("hadacore-pjrt-executor".to_string())
                .spawn(move || pjrt_executor_loop(dir, rx, ready_tx, &m, preload))
                .expect("spawn pjrt executor");
            ready_rx
                .recv()
                .map_err(|_| anyhow!("pjrt executor died during startup"))??;
            pjrt_tx = Some(tx);
            pjrt_thread = Some(handle);
        }

        let router = Arc::new(Router::new(manifest.as_ref(), cfg.router.clone()));

        let mut workers = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            let fwd = pjrt_tx.clone();
            let idle = cfg.idle_timeout;
            let min_fill = cfg.min_pjrt_fill;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hadacore-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(&batcher, &metrics, &engine, fwd, idle, min_fill)
                    })
                    .expect("spawn worker"),
            );
        }
        Ok(Coordinator {
            router,
            batcher,
            metrics,
            engine,
            workers,
            pjrt_tx,
            pjrt_thread,
        })
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, req: TransformRequest) -> Result<ResponseRx, SubmitError> {
        if let Err(reason) = self.router.admit(&req) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError(reason));
        }
        let route = self.router.route(&req);
        let key = BucketKey::of(&req, &route);
        let (tx, rx) = mpsc::channel();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.batcher.push(key, route, Pending { req, tx, enqueued: Instant::now() });
        Ok(rx)
    }

    /// Convenience: submit and block for the response.
    pub fn transform(
        &self,
        req: TransformRequest,
    ) -> anyhow::Result<TransformResponse> {
        let rx = self.submit(req).map_err(|e| anyhow!(e.to_string()))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped response"))?
    }

    /// Metrics handle.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Router handle (for observability).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The shared execution engine (for observability — lane count,
    /// sharding and workspace counters).
    pub fn exec_engine(&self) -> &ExecEngine {
        &self.engine
    }

    /// Drain queues and stop all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // workers have drained the batcher; closing the channel stops the
        // executor after it finishes forwarded batches
        self.pjrt_tx = None;
        if let Some(h) = self.pjrt_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(
    batcher: &Batcher,
    metrics: &Metrics,
    engine: &ExecEngine,
    pjrt_tx: Option<mpsc::Sender<Batch>>,
    idle: Duration,
    min_pjrt_fill: f64,
) {
    loop {
        match batcher.next_batch(idle) {
            Some(batch) => match &batch.route.backend {
                Backend::Native => execute_native_batch(batch, metrics, engine),
                Backend::Pjrt(_) => {
                    // under-filled deadline flush: padding a fixed-shape
                    // module costs more than running the rows natively
                    let fill =
                        batch.rows as f64 / batch.route.capacity_rows.max(1) as f64;
                    if fill < min_pjrt_fill || pjrt_tx.is_none() {
                        execute_native_batch(batch, metrics, engine);
                    } else if let Some(tx) = &pjrt_tx {
                        if let Err(mpsc::SendError(batch)) = tx.send(batch) {
                            fail_batch(batch, "pjrt executor unavailable");
                        }
                    }
                }
            },
            // None = idle timeout (keep polling) or shutdown (exit)
            None if batcher.is_shutdown() => return,
            None => {}
        }
    }
}

/// The PJRT executor: opens the Runtime, signals readiness, then executes
/// forwarded batches until every sender is dropped.
fn pjrt_executor_loop(
    dir: PathBuf,
    rx: mpsc::Receiver<Batch>,
    ready_tx: mpsc::Sender<anyhow::Result<()>>,
    metrics: &Metrics,
    preload: bool,
) {
    let runtime = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    if preload {
        // compile every fwht module now so no request pays the compile
        let names: Vec<String> = runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|e| e.op == "fwht")
            .map(|e| e.name.clone())
            .collect();
        for name in names {
            if let Err(e) = runtime.load(&name) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
    }
    let _ = ready_tx.send(Ok(()));
    while let Ok(batch) = rx.recv() {
        execute_pjrt_batch(batch, &runtime, metrics);
    }
}

fn gather(items: &[Pending], rows: usize, n: usize) -> Vec<f32> {
    let mut data = Vec::with_capacity(rows * n);
    for p in items {
        data.extend_from_slice(&p.req.data);
    }
    data
}

fn complete(
    items: Vec<Pending>,
    out: &[f32],
    n: usize,
    exec_start: Instant,
    exec_us: u64,
    batch_rows: usize,
    backend: &'static str,
    metrics: &Metrics,
) {
    let mut offset = 0;
    for p in items {
        let len = p.req.rows * n;
        let queue_us = exec_start
            .saturating_duration_since(p.enqueued)
            .as_micros() as u64;
        let resp = TransformResponse {
            id: p.req.id,
            data: out[offset..offset + len].to_vec(),
            queue_us,
            exec_us,
            batch_rows,
            backend,
        };
        offset += len;
        metrics.queue.record(queue_us);
        metrics.e2e.record(p.enqueued.elapsed().as_micros() as u64);
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        let _ = p.tx.send(Ok(resp));
    }
}

fn fail_batch(batch: Batch, msg: &str) {
    for p in batch.items {
        let _ = p.tx.send(Err(anyhow!("{msg}")));
    }
}

fn execute_native_batch(batch: Batch, metrics: &Metrics, engine: &ExecEngine) {
    let Batch { key, items, rows, .. } = batch;
    let n = key.n;
    let t0 = Instant::now();
    let mut data = gather(&items, rows, n);
    let opts = match items[0].req.scale {
        Some(s) => FwhtOptions::with_scale(s),
        None => FwhtOptions::normalized(n),
    };
    engine.run_f32(key.kernel, &mut data, n, &opts);
    let exec_us = t0.elapsed().as_micros() as u64;

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.native_batches.fetch_add(1, Ordering::Relaxed);
    metrics.rows.fetch_add(rows as u64, Ordering::Relaxed);
    metrics.exec.record(exec_us);
    complete(items, &data, n, t0, exec_us, rows, "native", metrics);
}

fn execute_pjrt_batch(batch: Batch, runtime: &Runtime, metrics: &Metrics) {
    let Batch { key, route, items, rows } = batch;
    let n = key.n;
    let Backend::Pjrt(bucket) = &route.backend else {
        fail_batch(Batch { key, route: route.clone(), items, rows }, "route mismatch");
        return;
    };
    let t0 = Instant::now();
    let result: anyhow::Result<Vec<f32>> = (|| {
        let art = runtime.load(&bucket.artifact)?;
        let cap = art.entry.rows.unwrap_or(rows);
        let mut data = gather(&items, rows, n);
        data.resize(cap * n, 0.0);
        let lit = literal_f32(&data, &[cap, n])?;
        let outs = art.execute(&[lit])?;
        let mut out = literal_to_f32(&outs[0])?;
        out.truncate(rows * n);
        Ok(out)
    })();
    let exec_us = t0.elapsed().as_micros() as u64;

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed);
    metrics.rows.fetch_add(rows as u64, Ordering::Relaxed);
    metrics
        .padded_rows
        .fetch_add(bucket.rows.saturating_sub(rows) as u64, Ordering::Relaxed);
    metrics.exec.record(exec_us);

    match result {
        Ok(out) => complete(
            items,
            &out,
            n,
            t0,
            exec_us,
            bucket.rows,
            "pjrt",
            metrics,
        ),
        Err(e) => {
            let msg = e.to_string();
            for p in items {
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(Err(anyhow!("batch execution failed: {msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::{fwht_scalar_f32, KernelKind};
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn native_coordinator(workers: usize) -> Coordinator {
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers,
                batcher: BatcherConfig { max_delay: Duration::from_micros(200), work_conserving: false },
                router: RouterConfig::default(),
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let c = native_coordinator(2);
        let mut rng = Rng::new(1);
        let n = 256;
        let x = rng.normal_vec(n);
        let resp = c.transform(TransformRequest::new(7, n, x.clone())).unwrap();
        assert_eq!(resp.id, 7);
        let mut want = x;
        fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
        assert_close(&resp.data, &want, 1e-3, 1e-3);
        assert_eq!(resp.backend, "native");
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete_correctly() {
        let c = native_coordinator(4);
        let mut rng = Rng::new(2);
        let n = 128;
        let mut handles = Vec::new();
        let mut expected = Vec::new();
        for id in 0..50u64 {
            let rows = rng.range(1, 3);
            let x = rng.normal_vec(rows * n);
            let mut want = x.clone();
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            expected.push(want);
            handles.push(c.submit(TransformRequest::new(id, n, x)).unwrap());
        }
        for (id, (h, want)) in handles.into_iter().zip(expected.iter()).enumerate() {
            let resp = h.recv().unwrap().unwrap();
            assert_eq!(resp.id, id as u64);
            assert_close(&resp.data, want, 1e-3, 1e-3);
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 50);
        assert!(snap.batches <= 50);
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_requests() {
        let c = native_coordinator(1);
        let err = c.submit(TransformRequest::new(1, 100, vec![0.0; 100]));
        assert!(err.is_err());
        assert_eq!(c.metrics().snapshot().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn custom_scale_respected() {
        let c = native_coordinator(2);
        let n = 64;
        let mut req = TransformRequest::new(3, n, vec![1.0; n]);
        req.scale = Some(1.0);
        req.kernel = KernelKind::Dao;
        let resp = c.transform(req).unwrap();
        // raw transform of all-ones: first element = n, rest 0
        assert!((resp.data[0] - n as f32).abs() < 1e-3);
        assert!(resp.data[1..].iter().all(|v| v.abs() < 1e-3));
        c.shutdown();
    }

    #[test]
    fn different_kernels_agree_through_server() {
        let c = native_coordinator(2);
        let mut rng = Rng::new(5);
        let n = 2048;
        let x = rng.normal_vec(n);
        let mut a = TransformRequest::new(1, n, x.clone());
        a.kernel = KernelKind::HadaCore;
        let mut b = TransformRequest::new(2, n, x);
        b.kernel = KernelKind::Dao;
        let ra = c.transform(a).unwrap();
        let rb = c.transform(b).unwrap();
        assert_close(&ra.data, &rb.data, 1e-3, 1e-3);
        c.shutdown();
    }

    #[test]
    fn shutdown_completes_inflight() {
        let c = native_coordinator(2);
        let n = 512;
        let mut rxs = Vec::new();
        for id in 0..20 {
            rxs.push(c.submit(TransformRequest::new(id, n, vec![1.0; n])).unwrap());
        }
        c.shutdown();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn native_batches_execute_on_the_engine() {
        let c = native_coordinator(2);
        for id in 0..5 {
            let rows = 4;
            let n = 2048;
            c.transform(TransformRequest::new(id, n, vec![1.0; rows * n]))
                .unwrap();
        }
        let s = c.exec_engine().stats();
        assert!(
            s.jobs + s.inline_runs >= 5,
            "every native batch must go through the engine: {s:?}"
        );
        c.shutdown();
    }

    #[test]
    fn metrics_track_latency() {
        let c = native_coordinator(2);
        for id in 0..10 {
            c.transform(TransformRequest::new(id, 64, vec![1.0; 64])).unwrap();
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 10);
        assert!(snap.e2e_p50_us > 0);
        assert!(snap.e2e_p99_us >= snap.e2e_p50_us);
        c.shutdown();
    }
}
