//! The coordinator server: worker pool + dedicated PJRT executor thread.
//!
//! `Coordinator::submit` is the client API: admission via the router,
//! enqueue into the batcher, and a receiver handle for the response.
//!
//! Threading model: PJRT executables are `Rc`-based (not `Send`), so one
//! **executor thread** owns the `Runtime` and performs every PJRT
//! execution (the CPU analogue of a GPU-owning executor). The worker pool
//! drains the batcher; native batches execute on the shared
//! [`ExecEngine`], which shards each batch's rows across *its* worker
//! pool — batcher workers handle assembly/completion concurrency, the
//! engine handles compute parallelism. PJRT batches are forwarded to the
//! executor over a channel. Responses complete per-request channels
//! either way.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::exec::{ExecConfig, ExecEngine, RowRegion};
use crate::hadamard::{FwhtOptions, KernelKind, Prologue};
use crate::quant::{Epilogue, QuantScales};
use crate::runtime::{literal_f32, literal_to_f32, Manifest, Runtime};
use crate::util::error::{self as anyhow, anyhow};

use super::batcher::{Batch, Batcher, BatcherConfig, BucketKey};
use super::metrics::Metrics;
use super::router::{Backend, Router, RouterConfig};
use super::{Pending, ResponseTx, TransformRequest, TransformResponse};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker thread count (batch assembly + response completion; the
    /// compute itself parallelises on the [`ExecEngine`] lanes).
    pub workers: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
    /// Routing policy.
    pub router: RouterConfig,
    /// Execution-engine geometry (compute lanes, chunking) and tuning
    /// policy: the engine's roofline-guided autotuner
    /// ([`crate::exec::tune`]) picks the round-fusion depth and chunk
    /// refinement per batch shape; pin [`crate::exec::TunePolicy`] (or
    /// set `HADACORE_TUNE=off|model` / `HADACORE_FUSION_DEPTH`) for
    /// bit-reproducible scheduling across hosts — responses are
    /// bit-identical either way, only throughput changes.
    pub exec: ExecConfig,
    /// Worker idle poll interval (shutdown latency bound).
    pub idle_timeout: Duration,
    /// Compile all fwht artifacts at startup (vs lazily on first use).
    /// Keeps compile stalls off the serving hot path.
    pub preload_pjrt: bool,
    /// Deadline-flushed PJRT batches whose fill fraction is below this
    /// threshold execute on the native kernel instead — padding a 128-row
    /// module to transform 4 rows costs more than doing the 4 rows on the
    /// CPU kernel directly.
    pub min_pjrt_fill: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            exec: ExecConfig::default(),
            idle_timeout: Duration::from_millis(50),
            preload_pjrt: true,
            min_pjrt_fill: 0.25,
        }
    }
}

/// Submission failure (admission rejection).
#[derive(Debug)]
pub struct SubmitError(pub String);

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request rejected: {}", self.0)
    }
}

impl std::error::Error for SubmitError {}

/// Response receiver handle.
pub type ResponseRx = mpsc::Receiver<anyhow::Result<TransformResponse>>;

/// The multiplexed response sender the serving layer passes to
/// [`Coordinator::submit_with`]: every response (or error) arrives tagged
/// with the request id so one channel can carry a whole connection's
/// traffic, out of order.
pub type TaggedResponseTx = mpsc::Sender<(u64, anyhow::Result<TransformResponse>)>;

/// The running coordinator.
///
/// Teardown paths (all idempotent, all drain in-flight work):
///
/// * [`Coordinator::shutdown`] — consume the owned value and stop.
/// * [`Coordinator::drain`] — `&self` graceful shutdown for shared
///   (`Arc`) coordinators: stop admitting (`submit` returns a retriable
///   rejection), complete everything already queued, then join threads.
/// * `Drop` — same as `shutdown`.
pub struct Coordinator {
    router: Arc<Router>,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    engine: Arc<ExecEngine>,
    draining: AtomicBool,
    /// Serialises [`Coordinator::drain`]: a second caller blocks here
    /// until the first has finished joining, so "drain returned" always
    /// means "all threads are stopped" — for every caller.
    drain_lock: Mutex<()>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    pjrt_tx: Mutex<Option<mpsc::Sender<Batch>>>,
    pjrt_thread: Mutex<Option<JoinHandle<()>>>,
}

impl Coordinator {
    /// Start the coordinator. `artifact_dir` enables the PJRT backend
    /// (the executor thread opens the `Runtime` there); `None` runs
    /// native-only.
    pub fn start(
        artifact_dir: Option<PathBuf>,
        cfg: CoordinatorConfig,
    ) -> anyhow::Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        let engine = Arc::new(ExecEngine::new(cfg.exec));

        // PJRT executor thread (owns the non-Send Runtime; carries an
        // engine handle so overfull batches can fall back to native
        // execution instead of being truncated by the pad step)
        let mut pjrt_tx = None;
        let mut pjrt_thread = None;
        let mut manifest: Option<Manifest> = None;
        if let Some(dir) = artifact_dir {
            manifest = Some(Manifest::load(&dir.join("manifest.json"))?);
            let (tx, rx) = mpsc::channel::<Batch>();
            let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
            let m = Arc::clone(&metrics);
            let eng = Arc::clone(&engine);
            let preload = cfg.preload_pjrt;
            let handle = std::thread::Builder::new()
                .name("hadacore-pjrt-executor".to_string())
                .spawn(move || {
                    pjrt_executor_loop(dir, rx, ready_tx, &m, preload, &eng)
                })
                .expect("spawn pjrt executor");
            ready_rx
                .recv()
                .map_err(|_| anyhow!("pjrt executor died during startup"))??;
            pjrt_tx = Some(tx);
            pjrt_thread = Some(handle);
        }

        let router = Arc::new(Router::new(manifest.as_ref(), cfg.router.clone()));

        let mut workers = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            let fwd = pjrt_tx.clone();
            let idle = cfg.idle_timeout;
            let min_fill = cfg.min_pjrt_fill;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hadacore-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(&batcher, &metrics, &engine, fwd, idle, min_fill)
                    })
                    .expect("spawn worker"),
            );
        }
        Ok(Coordinator {
            router,
            batcher,
            metrics,
            engine,
            draining: AtomicBool::new(false),
            drain_lock: Mutex::new(()),
            workers: Mutex::new(workers),
            pjrt_tx: Mutex::new(pjrt_tx),
            pjrt_thread: Mutex::new(pjrt_thread),
        })
    }

    /// Shared admission + enqueue path behind both submit flavours.
    fn submit_inner(
        &self,
        req: TransformRequest,
        tx: ResponseTx,
    ) -> Result<(), SubmitError> {
        if self.draining.load(Ordering::Acquire) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError(
                "coordinator is draining (retriable)".to_string(),
            ));
        }
        if let Err(reason) = self.router.admit(&req) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError(reason));
        }
        let route = self.router.route(&req);
        let key = BucketKey::of(&req, &route);
        crate::obs::trace::event(req.trace, crate::obs::Stage::Enqueued, req.rows as u32);
        // the batcher itself refuses work once shutdown has begun (the
        // check is atomic with the flag), so a submit racing drain() can
        // never strand a Pending behind the already-exited workers
        let pushed =
            self.batcher.push(key, route, Pending { req, tx, enqueued: Instant::now() });
        if !pushed {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError(
                "coordinator is draining (retriable)".to_string(),
            ));
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, req: TransformRequest) -> Result<ResponseRx, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_inner(req, ResponseTx::Oneshot(tx))?;
        Ok(rx)
    }

    /// Submit a request whose response is delivered on a caller-owned
    /// multiplexed channel, tagged with the request id. This is the
    /// serving-layer path: one channel per connection, responses stream
    /// back in completion order (not submission order).
    pub fn submit_with(
        &self,
        req: TransformRequest,
        tx: TaggedResponseTx,
    ) -> Result<(), SubmitError> {
        self.submit_inner(req, ResponseTx::Tagged(tx))
    }

    /// Submit a request with an explicit response channel. The TCP
    /// serving layer uses this with [`ResponseTx::Ring`]: the reply
    /// queue's storage is pre-reserved per connection, so completing a
    /// request allocates nothing (unlike `mpsc`, which allocates a node
    /// per message).
    pub fn submit_to(
        &self,
        req: TransformRequest,
        tx: ResponseTx,
    ) -> Result<(), SubmitError> {
        self.submit_inner(req, tx)
    }

    /// Convenience: submit and block for the response.
    pub fn transform(
        &self,
        req: TransformRequest,
    ) -> anyhow::Result<TransformResponse> {
        let rx = self.submit(req).map_err(|e| anyhow!(e.to_string()))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped response"))?
    }

    /// Metrics handle.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Router handle (for observability).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The shared execution engine (for observability — lane count,
    /// sharding and workspace counters).
    pub fn exec_engine(&self) -> &ExecEngine {
        &self.engine
    }

    /// Rows currently queued in the batcher (admission-control signal for
    /// the serving layer's load shedding).
    pub fn queued_rows(&self) -> usize {
        self.batcher.queued_rows()
    }

    /// True once [`Coordinator::drain`] (or shutdown) has begun: new
    /// submissions are rejected with a retriable error.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Drain queues and stop all threads.
    pub fn shutdown(self) {
        self.drain();
    }

    /// Graceful `&self` shutdown: stop admitting new requests, let the
    /// workers complete everything already queued (every pending request
    /// receives its response — never an error caused by the shutdown
    /// itself), then join the worker and executor threads. Idempotent;
    /// concurrent callers block until the first drain finishes joining.
    pub fn drain(&self) {
        // hold for the whole teardown: a concurrent drain (or Drop)
        // must not observe half-joined state and return early
        let _serialise = self.drain_lock.lock().unwrap();
        self.draining.store(true, Ordering::Release);
        self.batcher.shutdown();
        let workers: Vec<JoinHandle<()>> =
            self.workers.lock().unwrap().drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
        // belt-and-suspenders: `Batcher::push` refuses items once the
        // shutdown flag is set (atomically, under the same lock), so
        // nothing can land behind the joined workers — but if a future
        // change ever broke that invariant, executing stragglers inline
        // here keeps "no pending request is ever stranded" true
        let mut scratch = NativeScratch::default();
        while let Some(batch) = self.batcher.next_batch(Duration::from_millis(1)) {
            let _ = execute_native_batch(batch, &self.metrics, &self.engine, &mut scratch);
        }
        // workers have drained the batcher; closing the channel stops the
        // executor after it finishes forwarded batches
        *self.pjrt_tx.lock().unwrap() = None;
        let pjrt = self.pjrt_thread.lock().unwrap().take();
        if let Some(h) = pjrt {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Worker-owned reusable execution scratch: the scatter-gather region
/// table and the per-item scales vector. Both retain their capacity
/// across batches, so steady-state native dispatch allocates nothing.
#[derive(Default)]
struct NativeScratch {
    regions: Vec<RowRegion>,
    scales: Vec<QuantScales>,
}

fn worker_loop(
    batcher: &Batcher,
    metrics: &Metrics,
    engine: &ExecEngine,
    pjrt_tx: Option<mpsc::Sender<Batch>>,
    idle: Duration,
    min_pjrt_fill: f64,
) {
    // coordinator workers execute serving batches: count their
    // allocations when the count-alloc gate is measuring
    crate::util::alloc::track_current_thread(true);
    let mut scratch = NativeScratch::default();
    loop {
        match batcher.next_batch(idle) {
            Some(batch) => {
                if let Some(spent) = dispatch_batch(
                    batch,
                    metrics,
                    engine,
                    pjrt_tx.as_ref(),
                    min_pjrt_fill,
                    &mut scratch,
                ) {
                    // hand the emptied items vector back for the next flush
                    batcher.recycle(spent);
                }
            }
            // None = idle timeout (keep polling) or shutdown (exit)
            None if batcher.is_shutdown() => return,
            None => {}
        }
    }
}

/// Route one flushed batch to its executor. PJRT batches divert to the
/// native engine when the executor is missing or the fill policy says so
/// ([`pjrt_needs_native_fallback`]). Returns the batch's emptied `items`
/// vector when it was consumed locally, so the caller can recycle its
/// storage into the batcher.
fn dispatch_batch(
    batch: Batch,
    metrics: &Metrics,
    engine: &ExecEngine,
    pjrt_tx: Option<&mpsc::Sender<Batch>>,
    min_pjrt_fill: f64,
    scratch: &mut NativeScratch,
) -> Option<Vec<Pending>> {
    match &batch.route.backend {
        Backend::Native => {
            Some(execute_native_batch(batch, metrics, engine, scratch))
        }
        Backend::Pjrt(_) => {
            let Some(tx) = pjrt_tx else {
                return Some(execute_native_batch(batch, metrics, engine, scratch));
            };
            if pjrt_needs_native_fallback(
                batch.rows,
                batch.route.capacity_rows,
                min_pjrt_fill,
            ) {
                return Some(execute_native_batch(batch, metrics, engine, scratch));
            }
            if let Err(mpsc::SendError(batch)) = tx.send(batch) {
                fail_batch(batch, "pjrt executor unavailable", metrics);
            }
            None
        }
    }
}

/// True when a PJRT-routed batch must execute natively instead:
///
/// * **over-filled** (`rows > capacity`): the executor pads the gathered
///   buffer to the artifact's fixed shape with `resize`, which would
///   silently *truncate* data rows. Reachable when a manifest's `rows`
///   shrinks across restarts, or if a batcher change overfills a bucket.
/// * **under-filled** deadline flush (`fill < min_fill`): padding a
///   fixed-shape module costs more than running the rows natively.
fn pjrt_needs_native_fallback(
    batch_rows: usize,
    capacity_rows: usize,
    min_fill: f64,
) -> bool {
    let cap = capacity_rows.max(1);
    batch_rows > cap || (batch_rows as f64 / cap as f64) < min_fill
}

/// The PJRT executor: opens the Runtime, signals readiness, then executes
/// forwarded batches until every sender is dropped.
fn pjrt_executor_loop(
    dir: PathBuf,
    rx: mpsc::Receiver<Batch>,
    ready_tx: mpsc::Sender<anyhow::Result<()>>,
    metrics: &Metrics,
    preload: bool,
    engine: &ExecEngine,
) {
    crate::util::alloc::track_current_thread(true);
    let runtime = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    if preload {
        // compile every fwht module now so no request pays the compile
        let names: Vec<String> = runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|e| e.op == "fwht")
            .map(|e| e.name.clone())
            .collect();
        for name in names {
            if let Err(e) = runtime.load(&name) {
                let _ = ready_tx.send(Err(e));
                return;
            }
        }
    }
    let _ = ready_tx.send(Ok(()));
    let mut scratch = NativeScratch::default();
    while let Ok(batch) = rx.recv() {
        execute_pjrt_batch(batch, &runtime, metrics, engine, &mut scratch);
    }
}

fn gather(items: &[Pending], rows: usize, n: usize) -> Vec<f32> {
    let mut data = Vec::with_capacity(rows * n);
    for p in items {
        data.extend_from_slice(&p.req.data);
    }
    data
}

/// Complete every request of a natively-executed batch **in place**: each
/// request's own (transformed) buffer moves into its response — no
/// scatter copy, no allocation. Drains `items` and `scales`, leaving
/// their storage for reuse.
fn complete(
    items: &mut Vec<Pending>,
    scales: &mut Vec<QuantScales>,
    exec_start: Instant,
    exec_us: u64,
    batch_rows: usize,
    backend: &'static str,
    metrics: &Metrics,
) {
    debug_assert_eq!(items.len(), scales.len());
    for (p, scales) in items.drain(..).zip(scales.drain(..)) {
        let Pending { req, tx, enqueued } = p;
        let queue_us =
            exec_start.saturating_duration_since(enqueued).as_micros() as u64;
        let id = req.id;
        let resp = TransformResponse {
            id,
            data: req.data,
            queue_us,
            exec_us,
            batch_rows,
            backend,
            scales,
        };
        metrics.queue.record(queue_us);
        metrics.e2e.record(enqueued.elapsed().as_micros() as u64);
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        tx.send(id, Ok(resp));
    }
}

/// Complete requests of a batch whose output lives in a separate gathered
/// buffer (the PJRT path): each response gets a fresh copy of its row
/// span. The native path never takes this route.
#[allow(clippy::too_many_arguments)]
fn complete_scattered(
    items: Vec<Pending>,
    scales: Vec<QuantScales>,
    out: &[f32],
    n: usize,
    exec_start: Instant,
    exec_us: u64,
    batch_rows: usize,
    backend: &'static str,
    metrics: &Metrics,
) {
    debug_assert_eq!(items.len(), scales.len());
    let mut offset = 0;
    for (p, scales) in items.into_iter().zip(scales) {
        let id = p.req.id;
        let len = p.req.rows * n;
        let queue_us = exec_start
            .saturating_duration_since(p.enqueued)
            .as_micros() as u64;
        let resp = TransformResponse {
            id,
            data: out[offset..offset + len].to_vec().into(),
            queue_us,
            exec_us,
            batch_rows,
            backend,
            scales,
        };
        offset += len;
        metrics.queue.record(queue_us);
        metrics.e2e.record(p.enqueued.elapsed().as_micros() as u64);
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        p.tx.send(id, Ok(resp));
    }
}

/// Deliver an error to every pending request, recording the failure in
/// the metrics: `failed` and `completed` both advance (errors are
/// delivered responses), and the queue/e2e histograms record the latency
/// the requests actually experienced. `exec_start` marks when the batch
/// left the queue (mirroring [`complete`]) so a slow failing execution
/// inflates the e2e histogram, not the queue one; failures that never
/// started executing pass the current instant.
fn fail_items(items: Vec<Pending>, msg: &str, metrics: &Metrics, exec_start: Instant) {
    for p in items {
        let queue_us = exec_start
            .saturating_duration_since(p.enqueued)
            .as_micros() as u64;
        metrics.queue.record(queue_us);
        metrics.e2e.record(p.enqueued.elapsed().as_micros() as u64);
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        p.tx.send(p.req.id, Err(anyhow!("{msg}")));
    }
}

fn fail_batch(batch: Batch, msg: &str, metrics: &Metrics) {
    fail_items(batch.items, msg, metrics, Instant::now());
}

/// Run a native batch on the engine **in the requests' own buffers**,
/// under its bucket's prologue and epilogue, filling `scratch.scales`
/// with one [`QuantScales`] per request in item order.
///
/// Plain batches hand the engine a scatter-gather region table (one
/// [`RowRegion`] per request buffer) via
/// [`ExecEngine::run_f32_regions`] — no gather copy, and the chunking
/// over the logical concatenation matches the old gathered batch, so
/// the bytes are identical. The sign-flip prologue is a pure function of
/// `(seed, n)` applied per row, so it distributes over regions — the
/// bucket key guarantees all items share the seed.
///
/// Epilogue batches run one fused engine call per request: per-tensor
/// FP8 scales are a per-request property (each request is one tensor),
/// and grouped-INT8 scales never cross a request boundary (`group`
/// divides `n` and requests are whole rows), so per-request execution is
/// bit-identical to the whole-batch call while writing each request's
/// scales directly — no batch-wide scale vector to split and copy.
#[allow(clippy::too_many_arguments)]
fn run_native_stages(
    engine: &ExecEngine,
    kernel: KernelKind,
    n: usize,
    opts: &FwhtOptions,
    prologue: Prologue,
    epilogue: Epilogue,
    items: &mut [Pending],
    scratch: &mut NativeScratch,
) {
    scratch.scales.clear();
    match epilogue {
        Epilogue::None => {
            if let [only] = items {
                engine.run_f32_with_stages(
                    kernel,
                    &mut only.req.data,
                    n,
                    opts,
                    prologue,
                    Epilogue::None,
                );
            } else {
                scratch.regions.clear();
                scratch.regions.extend(items.iter_mut().map(|p| RowRegion {
                    ptr: p.req.data.as_mut_ptr(),
                    rows: p.req.rows,
                }));
                // SAFETY: each region is a distinct request's own buffer
                // of exactly `rows * n` elements (router admission), we
                // hold the exclusive borrow of every item for the call,
                // and the engine blocks until all chunks finish.
                unsafe {
                    engine.run_f32_regions(
                        kernel,
                        &scratch.regions,
                        n,
                        opts,
                        prologue,
                    );
                }
            }
            scratch.scales.extend(items.iter().map(|_| QuantScales::None));
        }
        Epilogue::QuantFp8 { .. } | Epilogue::QuantInt8 { .. } => {
            for p in items.iter_mut() {
                let s = engine.run_f32_with_stages(
                    kernel,
                    &mut p.req.data,
                    n,
                    opts,
                    prologue,
                    epilogue,
                );
                scratch.scales.push(s);
            }
        }
    }
}

/// Execute a native batch in place and complete its requests. Returns
/// the emptied `items` vector for recycling into the batcher.
fn execute_native_batch(
    batch: Batch,
    metrics: &Metrics,
    engine: &ExecEngine,
    scratch: &mut NativeScratch,
) -> Vec<Pending> {
    let Batch { key, mut items, rows, .. } = batch;
    let n = key.n;
    let t0 = Instant::now();
    // every member request records the seal; the engine call below runs
    // under the first sampled member's trace so its chunk spans attach
    // to a real request chain (chunks are batch-scoped, not per-item)
    let mut batch_trace = crate::obs::TraceCtx::NONE;
    for p in items.iter() {
        crate::obs::trace::event(
            p.req.trace,
            crate::obs::Stage::BatchSealed,
            rows as u32,
        );
        if batch_trace.0 == 0 && p.req.trace.is_sampled() {
            batch_trace = p.req.trace;
        }
    }
    let opts = match items[0].req.scale {
        Some(s) => FwhtOptions::with_scale(s),
        None => FwhtOptions::normalized(n),
    };
    crate::obs::trace::set_current(batch_trace);
    run_native_stages(
        engine,
        key.kernel,
        n,
        &opts,
        key.prologue,
        key.epilogue,
        &mut items,
        scratch,
    );
    crate::obs::trace::set_current(crate::obs::TraceCtx::NONE);
    let exec_us = t0.elapsed().as_micros() as u64;

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.native_batches.fetch_add(1, Ordering::Relaxed);
    metrics.rows.fetch_add(rows as u64, Ordering::Relaxed);
    metrics.exec.record(exec_us);
    complete(
        &mut items,
        &mut scratch.scales,
        t0,
        exec_us,
        rows,
        "native",
        metrics,
    );
    items
}

fn execute_pjrt_batch(
    batch: Batch,
    runtime: &Runtime,
    metrics: &Metrics,
    engine: &ExecEngine,
    scratch: &mut NativeScratch,
) {
    let bucket = match &batch.route.backend {
        Backend::Pjrt(bucket) => bucket.clone(),
        Backend::Native => {
            fail_batch(batch, "route mismatch", metrics);
            return;
        }
    };
    // queue time ends here: a lazy compile inside `load` is execution
    // cost (it lands in the exec/e2e histograms, not the queue one)
    let t0 = Instant::now();
    // resolve the artifact *before* consuming the batch: if the
    // compiled module's fixed row count is smaller than the batch (a
    // manifest's rows shrank across restarts, or a batcher change
    // overfilled the bucket), the pad `resize` below would silently
    // truncate data rows — fall back to the native engine instead.
    let art = match runtime.load(&bucket.artifact) {
        Ok(a) => a,
        Err(e) => {
            fail_items(
                batch.items,
                &format!("batch execution failed: {e}"),
                metrics,
                t0,
            );
            return;
        }
    };
    let cap = art.entry.rows.unwrap_or(batch.rows);
    if batch.rows > cap {
        let _ = execute_native_batch(batch, metrics, engine, scratch);
        return;
    }

    let Batch { key, items, rows, .. } = batch;
    for p in items.iter() {
        crate::obs::trace::event(
            p.req.trace,
            crate::obs::Stage::BatchSealed,
            rows as u32,
        );
    }
    // the router never routes prologue/epilogue requests to PJRT
    debug_assert!(key.prologue.is_none(), "prologue batch reached pjrt");
    debug_assert!(key.epilogue.is_none(), "epilogue batch reached pjrt");
    let n = key.n;
    let result: anyhow::Result<Vec<f32>> = (|| {
        let mut data = gather(&items, rows, n);
        data.resize(cap * n, 0.0); // rows <= cap: pure padding, no truncation
        let lit = literal_f32(&data, &[cap, n])?;
        let outs = art.execute(&[lit])?;
        let mut out = literal_to_f32(&outs[0])?;
        out.truncate(rows * n);
        Ok(out)
    })();
    let exec_us = t0.elapsed().as_micros() as u64;

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed);
    metrics.rows.fetch_add(rows as u64, Ordering::Relaxed);
    metrics
        .padded_rows
        .fetch_add(cap.saturating_sub(rows) as u64, Ordering::Relaxed);
    metrics.exec.record(exec_us);

    match result {
        Ok(out) => {
            let scales = items.iter().map(|_| QuantScales::None).collect();
            complete_scattered(
                items, scales, &out, n, t0, exec_us, cap, "pjrt", metrics,
            );
        }
        Err(e) => {
            fail_items(items, &format!("batch execution failed: {e}"), metrics, t0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::{fwht_scalar_f32, KernelKind};
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn native_coordinator(workers: usize) -> Coordinator {
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers,
                batcher: BatcherConfig { max_delay: Duration::from_micros(200), work_conserving: false },
                router: RouterConfig::default(),
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let c = native_coordinator(2);
        let mut rng = Rng::new(1);
        let n = 256;
        let x = rng.normal_vec(n);
        let resp = c.transform(TransformRequest::new(7, n, x.clone())).unwrap();
        assert_eq!(resp.id, 7);
        let mut want = x;
        fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
        assert_close(&resp.data, &want, 1e-3, 1e-3);
        assert_eq!(resp.backend, "native");
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete_correctly() {
        let c = native_coordinator(4);
        let mut rng = Rng::new(2);
        let n = 128;
        let mut handles = Vec::new();
        let mut expected = Vec::new();
        for id in 0..50u64 {
            let rows = rng.range(1, 3);
            let x = rng.normal_vec(rows * n);
            let mut want = x.clone();
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            expected.push(want);
            handles.push(c.submit(TransformRequest::new(id, n, x)).unwrap());
        }
        for (id, (h, want)) in handles.into_iter().zip(expected.iter()).enumerate() {
            let resp = h.recv().unwrap().unwrap();
            assert_eq!(resp.id, id as u64);
            assert_close(&resp.data, want, 1e-3, 1e-3);
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 50);
        assert!(snap.batches <= 50);
        c.shutdown();
    }

    #[test]
    fn rejects_invalid_requests() {
        let c = native_coordinator(1);
        let err = c.submit(TransformRequest::new(1, 100, vec![0.0; 100]));
        assert!(err.is_err());
        assert_eq!(c.metrics().snapshot().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn custom_scale_respected() {
        let c = native_coordinator(2);
        let n = 64;
        let mut req = TransformRequest::new(3, n, vec![1.0; n]);
        req.scale = Some(1.0);
        req.kernel = KernelKind::Dao;
        let resp = c.transform(req).unwrap();
        // raw transform of all-ones: first element = n, rest 0
        assert!((resp.data[0] - n as f32).abs() < 1e-3);
        assert!(resp.data[1..].iter().all(|v| v.abs() < 1e-3));
        c.shutdown();
    }

    #[test]
    fn different_kernels_agree_through_server() {
        let c = native_coordinator(2);
        let mut rng = Rng::new(5);
        let n = 2048;
        let x = rng.normal_vec(n);
        let mut a = TransformRequest::new(1, n, x.clone());
        a.kernel = KernelKind::HadaCore;
        let mut b = TransformRequest::new(2, n, x);
        b.kernel = KernelKind::Dao;
        let ra = c.transform(a).unwrap();
        let rb = c.transform(b).unwrap();
        assert_close(&ra.data, &rb.data, 1e-3, 1e-3);
        c.shutdown();
    }

    #[test]
    fn drain_completes_pending_then_rejects_new_submissions() {
        // the serving layer's teardown path: every request admitted
        // before drain() must receive its real response (not an error
        // caused by the shutdown), and submissions after drain() are
        // rejected with a retriable message
        let c = native_coordinator(2);
        let n = 512;
        let mut rxs = Vec::new();
        for id in 0..32 {
            rxs.push(c.submit(TransformRequest::new(id, n, vec![1.0; n])).unwrap());
        }
        c.drain();
        for rx in rxs {
            assert!(
                rx.recv().unwrap().is_ok(),
                "pending requests must complete, not error, on drain"
            );
        }
        assert!(c.is_draining());
        let err = c.submit(TransformRequest::new(99, n, vec![1.0; n])).unwrap_err();
        assert!(err.0.contains("draining"), "got: {}", err.0);
        c.drain(); // idempotent
        c.shutdown();
    }

    #[test]
    fn submit_with_multiplexes_tagged_responses_on_one_channel() {
        let c = native_coordinator(2);
        let (tx, rx) = mpsc::channel();
        let mut rng = Rng::new(11);
        let n = 256;
        let mut want = std::collections::HashMap::new();
        for id in 0..12u64 {
            let x = rng.normal_vec(n);
            let mut w = x.clone();
            fwht_scalar_f32(&mut w, n, &FwhtOptions::normalized(n));
            want.insert(id, w);
            c.submit_with(TransformRequest::new(id, n, x), tx.clone()).unwrap();
        }
        drop(tx); // the coordinator's clones keep the channel open
        let mut seen = 0;
        while let Ok((id, result)) = rx.recv() {
            let resp = result.unwrap();
            assert_eq!(resp.id, id, "tag must match the response id");
            assert_close(&resp.data, &want[&id], 1e-3, 1e-3);
            seen += 1;
        }
        assert_eq!(seen, 12, "every tagged response must arrive");
        c.shutdown();
    }

    #[test]
    fn shutdown_completes_inflight() {
        let c = native_coordinator(2);
        let n = 512;
        let mut rxs = Vec::new();
        for id in 0..20 {
            rxs.push(c.submit(TransformRequest::new(id, n, vec![1.0; n])).unwrap());
        }
        c.shutdown();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn tuned_and_pinned_engines_serve_identical_bytes() {
        // the autotuner (default Measure policy) and every pinned fusion
        // depth must produce the same response bytes through the full
        // dispatch path — fusion is scheduling, never arithmetic
        use crate::exec::{ExecConfig, TunePolicy};
        let mut rng = Rng::new(0x7D);
        let (rows, n) = (6usize, 4096usize);
        let x = rng.normal_vec(rows * n);
        let mut want: Option<Vec<f32>> = None;
        for tune in [
            TunePolicy::Measure,
            TunePolicy::Off,
            TunePolicy::FixedDepth(2),
            TunePolicy::FixedDepth(3),
        ] {
            let c = Coordinator::start(
                None,
                CoordinatorConfig {
                    workers: 2,
                    batcher: BatcherConfig {
                        max_delay: Duration::from_micros(200),
                        work_conserving: false,
                    },
                    exec: ExecConfig { threads: 2, tune, ..ExecConfig::default() },
                    idle_timeout: Duration::from_millis(10),
                    ..Default::default()
                },
            )
            .unwrap();
            let resp = c.transform(TransformRequest::new(1, n, x.clone())).unwrap();
            match &want {
                None => want = Some(resp.data),
                Some(w) => assert_eq!(w, &resp.data, "tune={tune:?} diverged"),
            }
            c.shutdown();
        }
    }

    #[test]
    fn native_batches_execute_on_the_engine() {
        let c = native_coordinator(2);
        for id in 0..5 {
            let rows = 4;
            let n = 2048;
            c.transform(TransformRequest::new(id, n, vec![1.0; rows * n]))
                .unwrap();
        }
        let s = c.exec_engine().stats();
        assert!(
            s.jobs + s.inline_runs >= 5,
            "every native batch must go through the engine: {s:?}"
        );
        c.shutdown();
    }

    #[test]
    fn native_responses_carry_the_request_buffer_through() {
        use crate::util::pool::BufferPool;
        let c = native_coordinator(2);
        let pool = BufferPool::new(4);
        let n = 256;
        let buf = pool.get_copy(&vec![1.0f32; n]);
        let ptr = buf.as_ptr() as usize;
        let resp = c.transform(TransformRequest::new(1, n, buf)).unwrap();
        assert_eq!(
            resp.data.as_ptr() as usize,
            ptr,
            "the response must be the request's own buffer, transformed in place"
        );
        assert!(resp.data.is_pooled());
        drop(resp);
        assert_eq!(pool.outstanding(), 0, "drop must return the buffer to its pool");
        c.shutdown();
    }

    #[test]
    fn fp8_epilogue_roundtrip_bit_identical_to_two_pass() {
        use crate::quant::{fp8_quantize_slice, Fp8Format};
        let c = native_coordinator(2);
        let mut rng = Rng::new(21);
        let (rows, n) = (3usize, 512usize);
        let x = rng.normal_vec(rows * n);
        let mut req = TransformRequest::new(9, n, x.clone());
        req.epilogue = Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 };
        let resp = c.transform(req).unwrap();
        assert_eq!(resp.backend, "native");

        let mut want = x;
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        let scale = fp8_quantize_slice(&mut want, Fp8Format::E4M3);
        assert_eq!(resp.data, want, "fused path must match the two-pass reference");
        assert_eq!(resp.scales, QuantScales::PerTensor(scale));
        c.shutdown();
    }

    #[test]
    fn int8_epilogue_returns_per_group_scales() {
        use crate::quant::{int_quantize_grouped, IntBits};
        let c = native_coordinator(2);
        let mut rng = Rng::new(22);
        let (rows, n, group) = (2usize, 256usize, 64usize);
        let x = rng.normal_vec(rows * n);
        let mut req = TransformRequest::new(4, n, x.clone());
        req.epilogue = Epilogue::QuantInt8 { group };
        let resp = c.transform(req).unwrap();

        let mut want = x;
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        let want_scales = int_quantize_grouped(&mut want, group, IntBits::Int8);
        assert_eq!(want_scales.len(), rows * n / group);
        assert_eq!(resp.data, want);
        assert_eq!(resp.scales, QuantScales::PerGroup(want_scales));
        c.shutdown();
    }

    #[test]
    fn fp8_scales_never_couple_across_batchmates() {
        use crate::quant::{fp8_quantize_slice, Fp8Format};
        // two requests with wildly different magnitudes, submitted
        // back-to-back so they likely share a batch: each response must
        // carry the scale of *its own* tensor, not the batch's
        let c = native_coordinator(1);
        let mut rng = Rng::new(23);
        let n = 256;
        let small = rng.normal_vec(n);
        let big: Vec<f32> = rng.normal_vec(n).iter().map(|v| v * 1000.0).collect();
        let mut reqs = Vec::new();
        for (id, data) in [(1u64, small.clone()), (2, big.clone())] {
            let mut req = TransformRequest::new(id, n, data);
            req.epilogue = Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 };
            reqs.push(c.submit(req).unwrap());
        }
        for (rx, data) in reqs.into_iter().zip([small, big]) {
            let resp = rx.recv().unwrap().unwrap();
            let mut want = data;
            crate::hadamard::fwht_f32(
                KernelKind::HadaCore,
                &mut want,
                n,
                &FwhtOptions::normalized(n),
            );
            let scale = fp8_quantize_slice(&mut want, Fp8Format::E4M3);
            assert_eq!(resp.data, want);
            assert_eq!(resp.scales, QuantScales::PerTensor(scale));
        }
        c.shutdown();
    }

    #[test]
    fn prologue_roundtrip_bit_identical_to_premultiplied_reference() {
        use crate::hadamard::{apply_signs, sign_vector};
        let c = native_coordinator(2);
        let mut rng = Rng::new(41);
        let seed = 0x5EED_CAFEu64;
        let (rows, n) = (3usize, 768usize);
        let x = rng.normal_vec(rows * n);
        let mut req = TransformRequest::new(5, n, x.clone());
        req.prologue = Prologue::SignFlip { seed };
        let resp = c.transform(req).unwrap();
        assert_eq!(resp.backend, "native");

        let mut want = x;
        apply_signs(&mut want, &sign_vector(seed, n));
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        assert_eq!(resp.data, want, "served rotation must match the premultiply");
        c.shutdown();
    }

    #[test]
    fn prologue_composes_with_epilogue_through_server() {
        use crate::hadamard::{apply_signs, sign_vector};
        use crate::quant::{fp8_quantize_slice, Fp8Format};
        let c = native_coordinator(2);
        let mut rng = Rng::new(42);
        let seed = 0x5EED_F00Du64;
        let n = 512;
        let x = rng.normal_vec(2 * n);
        let mut req = TransformRequest::new(6, n, x.clone());
        req.prologue = Prologue::SignFlip { seed };
        req.epilogue = Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 };
        let resp = c.transform(req).unwrap();

        let mut want = x;
        apply_signs(&mut want, &sign_vector(seed, n));
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        let scale = fp8_quantize_slice(&mut want, Fp8Format::E4M3);
        assert_eq!(resp.data, want);
        assert_eq!(resp.scales, QuantScales::PerTensor(scale));
        c.shutdown();
    }

    #[test]
    fn nan_scale_sentinel_collision_is_rejected() {
        // regression: scale bits 0x7fc00001 are the batcher's no-scale
        // sentinel; before the non-finite admission check this request
        // would land in the None-scale bucket and its "scale" would be
        // applied to every batchmate
        let c = native_coordinator(1);
        let mut req = TransformRequest::new(1, 256, vec![0.0; 256]);
        req.scale = Some(f32::from_bits(0x7fc0_0001));
        assert!(c.submit(req).is_err());
        assert_eq!(c.metrics().snapshot().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn pjrt_fallback_policy() {
        assert!(pjrt_needs_native_fallback(5, 4, 0.25), "overfull");
        assert!(pjrt_needs_native_fallback(1, 128, 0.25), "underfilled");
        assert!(!pjrt_needs_native_fallback(64, 128, 0.25));
        assert!(!pjrt_needs_native_fallback(128, 128, 0.25));
        assert!(!pjrt_needs_native_fallback(1, 1, 0.5));
        assert!(pjrt_needs_native_fallback(2, 0, 0.25), "degenerate capacity");
    }

    #[test]
    fn overfull_pjrt_batch_executes_natively_untruncated() {
        use crate::coordinator::router::PjrtBucket;
        use crate::coordinator::Route;
        let engine = ExecEngine::single_threaded();
        let metrics = Metrics::default();
        let mut rng = Rng::new(31);
        let (rows, n) = (4usize, 256usize);
        let x = rng.normal_vec(rows * n);
        let req = TransformRequest::new(1, n, x.clone());
        // a pjrt bucket whose fixed shape holds only 2 rows
        let route = Route {
            backend: Backend::Pjrt(PjrtBucket {
                artifact: Arc::from("fwht_shrunk"),
                rows: 2,
            }),
            capacity_rows: 2,
        };
        let key = BucketKey::of(&req, &route);
        let (tx, resp_rx) = mpsc::channel();
        let batch = Batch {
            key,
            route,
            items: vec![Pending {
                req,
                tx: ResponseTx::Oneshot(tx),
                enqueued: Instant::now(),
            }],
            rows,
        };
        let (fwd_tx, fwd_rx) = mpsc::channel::<Batch>();
        let mut scratch = NativeScratch::default();
        let spent =
            dispatch_batch(batch, &metrics, &engine, Some(&fwd_tx), 0.25, &mut scratch);
        assert!(fwd_rx.try_recv().is_err(), "overfull batch must not reach pjrt");
        assert!(
            spent.map(|v| v.is_empty()).unwrap_or(false),
            "locally-executed batch must hand back its emptied items vec"
        );
        let resp = resp_rx.recv().unwrap().unwrap();
        assert_eq!(resp.backend, "native");
        let mut want = x;
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        assert_eq!(resp.data, want, "all 4 rows present, none truncated");
    }

    #[test]
    fn failure_path_records_metrics() {
        let metrics = Metrics::default();
        let (tx, rx) = mpsc::channel();
        let items = vec![Pending {
            req: TransformRequest::new(1, 64, vec![0.0; 64]),
            tx: ResponseTx::Oneshot(tx),
            enqueued: Instant::now(),
        }];
        fail_items(items, "boom", &metrics, Instant::now());
        assert!(rx.recv().unwrap().is_err());
        let snap = metrics.snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(metrics.queue.count(), 1, "queue histogram must record errors");
        assert_eq!(metrics.e2e.count(), 1, "e2e histogram must record errors");
    }

    #[test]
    fn pjrt_execution_failure_fails_requests_and_records_metrics() {
        // the stub backend cannot compile, so a deferred (non-preloaded)
        // artifact fails at execution time — the whole error path in one
        // end-to-end pass: forward, load failure, error responses, metrics
        let dir = std::env::temp_dir()
            .join(format!("hc_pjrt_fail_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "fwht_hadacore_256x8", "op": "fwht",
                 "kernel": "hadacore", "file": "m.hlo.txt",
                 "n": 256, "rows": 8,
                 "inputs": [{"shape": [8, 256], "dtype": "float32"}],
                 "outputs": [{"shape": [8, 256], "dtype": "float32"}]}
               ],
               "weights": [], "model": {}}"#,
        )
        .unwrap();
        let c = Coordinator::start(
            Some(dir.clone()),
            CoordinatorConfig {
                workers: 1,
                batcher: BatcherConfig {
                    max_delay: Duration::from_micros(100),
                    work_conserving: false,
                },
                idle_timeout: Duration::from_millis(10),
                preload_pjrt: false,
                min_pjrt_fill: 0.0,
                ..Default::default()
            },
        )
        .unwrap();
        // rows == the bucket's fixed shape: full flush, forwarded to pjrt
        let result = c.transform(TransformRequest::new(1, 256, vec![1.0; 8 * 256]));
        assert!(result.is_err(), "stub compile must fail the batch");
        let snap = c.metrics().snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed, 1);
        assert!(c.metrics().e2e.count() >= 1);
        c.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_track_latency() {
        let c = native_coordinator(2);
        for id in 0..10 {
            c.transform(TransformRequest::new(id, 64, vec![1.0; 64])).unwrap();
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.completed, 10);
        assert!(snap.e2e_p50_us > 0);
        assert!(snap.e2e_p99_us >= snap.e2e_p50_us);
        c.shutdown();
    }
}
