//! Serving metrics: counters + fixed-bucket latency histograms.
//!
//! Lock-free on the hot path (atomics only); snapshots are consistent
//! enough for reporting (individual counters are exact, cross-counter
//! skew is bounded by in-flight work).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Log-spaced latency histogram: 1us .. ~17min in 48 buckets
/// (geometric, x2 per bucket after the first 16 linear us buckets).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const LINEAR: u64 = 16; // 0..16us in 1us steps
const TOTAL_BUCKETS: usize = 48;

fn bucket_index(us: u64) -> usize {
    if us < LINEAR {
        us as usize
    } else {
        let extra = (64 - (us / LINEAR).leading_zeros()) as usize;
        (LINEAR as usize + extra - 1).min(TOTAL_BUCKETS - 1)
    }
}

/// Upper bound (µs) of a bucket, for percentile reconstruction.
fn bucket_upper(idx: usize) -> u64 {
    if (idx as u64) < LINEAR {
        idx as u64 + 1
    } else {
        LINEAR << (idx - LINEAR as usize + 1)
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: (0..TOTAL_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency observation in microseconds.
    pub fn record(&self, us: u64) {
        self.record_n(us, 1);
    }

    /// Record `n` observations of the same value — the bulk form used by
    /// the exposition parser to reconstruct a histogram from bucket
    /// counts ([`crate::obs::registry::parse_histogram`]).
    pub fn record_n(&self, us: u64, n: u64) {
        self.buckets[bucket_index(us)].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        self.sum_us.fetch_add(us * n, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Add every observation of `other` into `self`, bucket for bucket.
    /// Merging per-shard histograms this way is exactly equivalent to
    /// one histogram fed the union of the samples (same fixed bounds on
    /// both sides — the property the registry's exposition and the
    /// cluster's fleet-wide percentiles rely on, pinned by tests in
    /// [`crate::obs::registry`]).
    pub fn merge_from(&self, other: &Histogram) {
        for (i, b) in other.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// `(bucket upper bound µs, observations in that bucket)` for every
    /// bucket, in ascending bound order — the exposition's raw material.
    pub fn bucket_bounds_counts(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| (bucket_upper(i), b.load(Ordering::Relaxed)))
            .collect()
    }

    /// Sum of all observed values in µs.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Maximum observed latency in µs.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile (0..100) in µs via bucket upper bounds.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_upper(i);
            }
        }
        self.max_us()
    }

    /// One-line text report: count, mean, p50/p90/p99 reconstruction and
    /// max. The serving layer streams this through the `Stats` wire frame
    /// so a remote client sees the same percentiles an in-process caller
    /// would compute.
    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: count {}  mean {:.1}us  p50 {}us  p90 {}us  p99 {}us  max {}us",
            self.count(),
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(90.0),
            self.percentile_us(99.0),
            self.max_us(),
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// All coordinator metrics — a thin view over handles registered in the
/// process-wide [`crate::obs::registry`]: each field is an `Arc` into
/// the registry's `hadacore_*` namespace, so the hot path bumps the same
/// atomics the `/metrics` exposition reads (through `Deref`, pre-registry
/// call sites like `metrics.submitted.fetch_add(1, _)` are unchanged).
/// A process may hold several coordinators (the self-hosted cluster
/// fleet does); each `Metrics` keeps exact per-instance counts while the
/// exposition sums the instances into the process-wide series.
#[derive(Debug)]
pub struct Metrics {
    /// Requests accepted by the router (`hadacore_requests_total`).
    pub submitted: Arc<AtomicU64>,
    /// Requests completed (responses delivered — successes *and* errors;
    /// `completed - failed` counts the successes).
    pub completed: Arc<AtomicU64>,
    /// Requests rejected at admission.
    pub rejected: Arc<AtomicU64>,
    /// Requests that received an error response (batch execution failed
    /// or the executor was unavailable). Error responses still record
    /// queue/e2e latency.
    pub failed: Arc<AtomicU64>,
    /// Batches executed.
    pub batches: Arc<AtomicU64>,
    /// Total data rows executed (excluding padding).
    pub rows: Arc<AtomicU64>,
    /// Padding rows added to fill PJRT bucket shapes.
    pub padded_rows: Arc<AtomicU64>,
    /// Batches executed on the native backend.
    pub native_batches: Arc<AtomicU64>,
    /// Batches executed on the PJRT backend.
    pub pjrt_batches: Arc<AtomicU64>,
    /// Queue-wait latency (`hadacore_queue_us`).
    pub queue: Arc<Histogram>,
    /// Kernel execution latency per batch (`hadacore_exec_us`).
    pub exec: Arc<Histogram>,
    /// End-to-end request latency (`hadacore_e2e_us`).
    pub e2e: Arc<Histogram>,
}

impl Metrics {
    /// Fresh metrics, registered under the `hadacore_*` namespace of the
    /// process-wide registry. Registration happens here — coordinator
    /// construction — never on the request path.
    pub fn new() -> Metrics {
        let r = crate::obs::registry();
        Metrics {
            submitted: r.counter(
                "hadacore_requests_total",
                "requests accepted by the coordinator router",
            ),
            completed: r.counter(
                "hadacore_requests_completed_total",
                "responses delivered (successes and errors)",
            ),
            rejected: r.counter(
                "hadacore_requests_rejected_total",
                "requests rejected at admission",
            ),
            failed: r.counter(
                "hadacore_requests_failed_total",
                "requests answered with an error response",
            ),
            batches: r.counter("hadacore_batches_total", "batches executed"),
            rows: r.counter(
                "hadacore_batch_rows_total",
                "data rows executed (excluding padding)",
            ),
            padded_rows: r.counter(
                "hadacore_padded_rows_total",
                "padding rows added to fill PJRT bucket shapes",
            ),
            native_batches: r.counter(
                "hadacore_batches_native_total",
                "batches executed on the native backend",
            ),
            pjrt_batches: r.counter(
                "hadacore_batches_pjrt_total",
                "batches executed on the PJRT backend",
            ),
            queue: r.histogram_us("hadacore_queue_us", "queue-wait latency"),
            exec: r.histogram_us("hadacore_exec_us", "batch execution latency"),
            e2e: r.histogram_us("hadacore_e2e_us", "end-to-end request latency"),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// Point-in-time copy of the counters for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub rows: u64,
    pub padded_rows: u64,
    pub native_batches: u64,
    pub pjrt_batches: u64,
    pub queue_p50_us: u64,
    pub queue_p90_us: u64,
    pub queue_p99_us: u64,
    pub exec_p50_us: u64,
    pub exec_p90_us: u64,
    pub exec_p99_us: u64,
    pub e2e_p50_us: u64,
    pub e2e_p90_us: u64,
    pub e2e_p95_us: u64,
    pub e2e_p99_us: u64,
    pub e2e_mean_us: f64,
}

impl Metrics {
    /// Take a snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            native_batches: self.native_batches.load(Ordering::Relaxed),
            pjrt_batches: self.pjrt_batches.load(Ordering::Relaxed),
            queue_p50_us: self.queue.percentile_us(50.0),
            queue_p90_us: self.queue.percentile_us(90.0),
            queue_p99_us: self.queue.percentile_us(99.0),
            exec_p50_us: self.exec.percentile_us(50.0),
            exec_p90_us: self.exec.percentile_us(90.0),
            exec_p99_us: self.exec.percentile_us(99.0),
            e2e_p50_us: self.e2e.percentile_us(50.0),
            e2e_p90_us: self.e2e.percentile_us(90.0),
            e2e_p95_us: self.e2e.percentile_us(95.0),
            e2e_p99_us: self.e2e.percentile_us(99.0),
            e2e_mean_us: self.e2e.mean_us(),
        }
    }
}

impl MetricsSnapshot {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted, {} completed, {} rejected, {} failed\n\
             batches:  {} total ({} native, {} pjrt), {} rows + {} pad rows\n\
             queue:    p50 {}us  p90 {}us  p99 {}us\n\
             exec:     p50 {}us  p90 {}us  p99 {}us\n\
             e2e:      p50 {}us  p90 {}us  p95 {}us  p99 {}us  mean {:.1}us",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.batches,
            self.native_batches,
            self.pjrt_batches,
            self.rows,
            self.padded_rows,
            self.queue_p50_us,
            self.queue_p90_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p90_us,
            self.exec_p99_us,
            self.e2e_p50_us,
            self.e2e_p90_us,
            self.e2e_p95_us,
            self.e2e_p99_us,
            self.e2e_mean_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_monotone() {
        let mut last = 0;
        for us in [0u64, 1, 5, 15, 16, 31, 32, 100, 1000, 10_000, 1_000_000] {
            let b = bucket_index(us);
            assert!(b >= last, "us={us}");
            last = b;
            assert!(bucket_upper(b) >= us.min(bucket_upper(TOTAL_BUCKETS - 1)));
        }
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::new();
        for us in 0..100u64 {
            h.record(us);
        }
        assert_eq!(h.count(), 100);
        assert!(h.percentile_us(50.0) >= 40 && h.percentile_us(50.0) <= 64);
        assert!(h.percentile_us(99.0) >= 90);
        assert!(h.mean_us() > 40.0 && h.mean_us() < 60.0);
        assert_eq!(h.max_us(), 99);
        let empty = Histogram::new();
        assert_eq!(empty.percentile_us(50.0), 0);
        assert_eq!(empty.mean_us(), 0.0);
    }

    #[test]
    fn percentile_reconstruction_against_known_bucket_fills() {
        // linear region: one observation in each of the first 16 buckets
        // (us = 0..15, bucket uppers 1..16). The p-th percentile targets
        // observation ceil(p/100 * 16); its bucket upper bound is exact.
        let h = Histogram::new();
        for us in 0..16u64 {
            h.record(us);
        }
        assert_eq!(h.percentile_us(50.0), 8, "obs #8 sits in bucket 7 (upper 8)");
        assert_eq!(h.percentile_us(90.0), 15, "ceil(0.9*16)=15 -> bucket 14");
        assert_eq!(h.percentile_us(99.0), 16, "ceil(0.99*16)=16 -> bucket 15");
        assert_eq!(h.percentile_us(100.0), 16);

        // geometric region: a 90/10 bimodal fill. 90 observations at 10us
        // (bucket 10, upper 11) and 10 at 1_000_000us (10^6/16 = 62500,
        // needs 16 doublings -> bucket 31, upper 16<<16 = 1048576).
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(10);
        }
        for _ in 0..10 {
            h.record(1_000_000);
        }
        assert_eq!(h.percentile_us(50.0), 11);
        assert_eq!(h.percentile_us(90.0), 11, "the 90th obs is still in the fast mode");
        assert_eq!(h.percentile_us(99.0), 1_048_576, "the tail lands in bucket 31");
        assert_eq!(h.max_us(), 1_000_000);
    }

    #[test]
    fn histogram_report_carries_the_percentile_line() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(10);
        }
        for _ in 0..10 {
            h.record(1_000_000);
        }
        let r = h.report("e2e");
        assert!(r.starts_with("e2e: count 100"), "got: {r}");
        assert!(r.contains("p50 11us"), "got: {r}");
        assert!(r.contains("p90 11us"), "got: {r}");
        assert!(r.contains("p99 1048576us"), "got: {r}");
        assert!(r.contains("max 1000000us"), "got: {r}");
    }

    #[test]
    fn snapshot_report_formats() {
        let m = Metrics::default();
        m.submitted.store(10, Ordering::Relaxed);
        m.failed.store(3, Ordering::Relaxed);
        m.e2e.record(120);
        let s = m.snapshot();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.failed, 3);
        assert!(s.report().contains("10 submitted"));
        assert!(s.report().contains("3 failed"));
    }
}
