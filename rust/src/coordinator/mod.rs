//! Layer-3 coordinator: the serving layer around the transform kernels.
//!
//! Modelled on the vLLM-router architecture the task brief points at, at
//! the scale a Hadamard-transform service needs:
//!
//! * [`router`] — admission + dispatch: validates a request, picks the
//!   execution backend (native Rust kernel or a compiled PJRT artifact)
//!   and the size bucket it batches into.
//! * [`batcher`] — bucketed dynamic batching: requests for the same
//!   (kernel, n) accumulate until the bucket's row capacity fills or its
//!   deadline expires, then flush as one kernel invocation. This is the
//!   serving-side realisation of the paper's element-count axis: larger
//!   batches amortise per-launch overhead exactly as the evaluation grids
//!   show.
//! * [`server`] — worker threads draining the batcher, executing batches,
//!   and completing per-request response channels.
//! * [`metrics`] — counters and latency histograms (queue / execute /
//!   end-to-end percentiles).
//!
//! The coordinator owns the event loop and process lifecycle; Python never
//! appears on this path.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig, BucketKey};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use router::{Backend, Route, Router, RouterConfig};
pub use server::{Coordinator, CoordinatorConfig, SubmitError, TaggedResponseTx};

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::hadamard::{KernelKind, Prologue};
use crate::obs::TraceCtx;
use crate::quant::{Epilogue, QuantScales};
use crate::util::error as anyhow;
use crate::util::pool::PooledBuf;

/// A transform request: `rows` rows of size `n`, transformed in place
/// semantically (the response carries the transformed buffer back).
///
/// Every backend computes the same operation per row:
/// `x <- (x @ H_n) * scale` — the right-Hadamard-transform convention of
/// the fast-hadamard-transform library (`H_n` is symmetric, so left and
/// right transforms coincide; see [`crate::hadamard`]).
#[derive(Debug)]
pub struct TransformRequest {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Hadamard size (row length). Must be `B * 2^k` with
    /// `B ∈ {1, 12, 20, 28, 40}` within [`crate::MAX_HADAMARD_SIZE`]
    /// (see [`crate::hadamard::split_base`]); non-power-of-two sizes
    /// always execute on the native backend.
    pub n: usize,
    /// Number of rows in `data` (`data.len() == rows * n`).
    pub rows: usize,
    /// Row-major payload. A [`PooledBuf`] so the serving layer can hand
    /// over a pool-affiliated buffer that is transformed **in place**
    /// and travels on into the response unchanged; plain `Vec<f32>`
    /// callers convert implicitly via `From` (unpooled, drops normally).
    pub data: PooledBuf,
    /// Which kernel implementation to use.
    pub kernel: KernelKind,
    /// Output scaling, matching [`crate::hadamard::FwhtOptions`]:
    /// `None` applies the orthonormal `1/sqrt(n)` (the paper's
    /// convention, making the transform its own inverse);
    /// `Some(s)` applies `s` verbatim (`Some(1.0)` = the raw ±1
    /// transform). Custom-scale requests batch separately and always
    /// execute natively — PJRT artifacts bake the orthonormal scale in.
    /// Non-finite scales are rejected at admission (a NaN scale would
    /// collide with the no-scale bucket sentinel and corrupt batchmates).
    pub scale: Option<f32>,
    /// Fused randomized-rotation prologue ([`Prologue::None`] = plain
    /// transform): a seeded ±1 sign-flip diagonal applied to each row
    /// *before* the transform, in the same pass over the data — the
    /// QuaRot-style rotation `x ← (x·D) @ H_n * scale`. The sign vector
    /// is a pure function of `(seed, n)`, so batching requests that share
    /// a seed is safe; requests with different seeds batch separately
    /// (the seed is part of the [`BucketKey`]) and always execute
    /// natively (PJRT artifacts have no sign-flip stage).
    pub prologue: Prologue,
    /// Fused rotate→quantize epilogue ([`Epilogue::None`] = plain
    /// transform). Executed by the engine in the same pass over the data
    /// as the rotation; the response's [`TransformResponse::scales`]
    /// carries the quantisation scale(s) back. Epilogue requests batch
    /// separately from plain ones and always execute natively (PJRT
    /// artifacts have no quantise stage).
    pub epilogue: Epilogue,
    /// Force the native backend even when an artifact exists.
    pub force_native: bool,
    /// Span-tracing context ([`TraceCtx::NONE`] = unsampled, the
    /// default). Stamped at conn-reader admission (or adopted from the
    /// wire), carried by value through batching into the engine's
    /// `JobSpec`, so one sampled request's lifecycle is reconstructable
    /// from the flight recorder ([`crate::obs::trace`]).
    pub trace: TraceCtx,
}

impl TransformRequest {
    /// A default-shaped request. Accepts a plain `Vec<f32>` (the public
    /// in-process API, wrapped unpooled) or an already-pooled buffer
    /// (the serving layer's zero-copy path).
    pub fn new(id: u64, n: usize, data: impl Into<PooledBuf>) -> Self {
        let data = data.into();
        let rows = data.len() / n.max(1);
        TransformRequest {
            id,
            n,
            rows,
            data,
            kernel: KernelKind::HadaCore,
            scale: None,
            prologue: Prologue::None,
            epilogue: Epilogue::None,
            force_native: false,
            trace: TraceCtx::NONE,
        }
    }
}

/// The response to one [`TransformRequest`].
#[derive(Debug)]
pub struct TransformResponse {
    /// Echoed request id.
    pub id: u64,
    /// Transformed rows (same shape as the request payload):
    /// `data[r*n..][..n] = (request.data[r*n..][..n] @ H_n) * scale`.
    /// On the native path this is the **request's own buffer**,
    /// transformed in place — no scatter copy; dropping the response
    /// returns a pooled buffer to its pool.
    pub data: PooledBuf,
    /// Time spent queued before execution.
    pub queue_us: u64,
    /// Kernel execution time of the batch this request rode in.
    pub exec_us: u64,
    /// Rows in the executed batch (including padding), for observability.
    pub batch_rows: usize,
    /// Which backend executed it ("native" | "pjrt").
    pub backend: &'static str,
    /// Scale(s) produced by the request's epilogue
    /// ([`QuantScales::None`] for plain requests). Per-tensor FP8 scales
    /// are **per request** — the coordinator never couples one request's
    /// amax to a batchmate's — and grouped-INT8 scales cover exactly this
    /// request's `rows * n / group` groups in element order.
    pub scales: QuantScales,
}

/// Where a completed (or failed) request's response is delivered.
///
/// The in-process API ([`Coordinator::submit`]) uses one channel per
/// request; the TCP serving layer ([`crate::serve`]) multiplexes every
/// request of a connection onto one channel and demultiplexes by request
/// id — responses may complete out of order, so the tagged variant
/// carries the id alongside the result (errors would otherwise lose it:
/// [`crate::util::error::Error`] has no id field).
pub enum ResponseTx {
    /// Dedicated per-request channel (the `submit` path).
    Oneshot(mpsc::Sender<anyhow::Result<TransformResponse>>),
    /// Shared per-connection channel; the id travels with the result
    /// (the `submit_with` path used by the serving layer).
    Tagged(mpsc::Sender<(u64, anyhow::Result<TransformResponse>)>),
    /// Shared per-connection [`ReplyRing`] — like `Tagged`, but the
    /// queue storage is pre-reserved and reused, so delivering a
    /// response performs no heap allocation (std's `mpsc` allocates a
    /// node per message, which would break the serve path's zero-alloc
    /// steady state).
    Ring(ReplyTx),
}

impl ResponseTx {
    /// Deliver a response, ignoring a hung-up receiver (the client went
    /// away; the work is already done either way).
    pub fn send(&self, id: u64, result: anyhow::Result<TransformResponse>) {
        match self {
            ResponseTx::Oneshot(tx) => {
                let _ = tx.send(result);
            }
            ResponseTx::Tagged(tx) => {
                let _ = tx.send((id, result));
            }
            ResponseTx::Ring(tx) => tx.send(id, result),
        }
    }
}

/// One queued reply: `(request id, completion result)`.
type Reply = (u64, anyhow::Result<TransformResponse>);

struct RingState {
    queue: VecDeque<Reply>,
    /// Live [`ReplyTx`] handles; `recv` returns `None` once this hits
    /// zero with the queue drained (mpsc disconnect semantics).
    senders: usize,
}

/// A bounded-storage MPSC reply queue for the serving layer: the
/// connection's writer thread `recv`s, the coordinator's workers `send`
/// through per-request [`ReplyTx`] clones. The deque is pre-reserved to
/// the connection's pipeline depth and retained across messages, so
/// steady-state delivery allocates nothing.
pub struct ReplyRing {
    state: Mutex<RingState>,
    cv: Condvar,
}

impl ReplyRing {
    /// A ring pre-reserving room for `depth` in-flight replies, plus its
    /// first sender handle.
    pub fn with_depth(depth: usize) -> (Arc<ReplyRing>, ReplyTx) {
        let ring = Arc::new(ReplyRing {
            state: Mutex::new(RingState {
                queue: VecDeque::with_capacity(depth.max(1)),
                senders: 1,
            }),
            cv: Condvar::new(),
        });
        let tx = ReplyTx { ring: Arc::clone(&ring) };
        (ring, tx)
    }

    /// Block until a reply is available (`Some`) or every sender has
    /// dropped with the queue drained (`None`).
    pub fn recv(&self) -> Option<Reply> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(reply) = st.queue.pop_front() {
                return Some(reply);
            }
            if st.senders == 0 {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Replies currently queued (test/observability hook).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Whether no replies are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Sending handle of a [`ReplyRing`]. Clones track a sender count inside
/// the ring's mutex (no allocation); dropping the last sender wakes the
/// receiver so it can observe disconnection.
pub struct ReplyTx {
    ring: Arc<ReplyRing>,
}

impl ReplyTx {
    /// Queue a reply, ignoring a hung-up receiver (the connection's
    /// writer exits only after every sender is gone, so "hung up" here
    /// means the whole ring is being torn down).
    pub fn send(&self, id: u64, result: anyhow::Result<TransformResponse>) {
        let mut st = self.ring.state.lock().unwrap();
        st.queue.push_back((id, result));
        drop(st);
        self.ring.cv.notify_one();
    }
}

impl Clone for ReplyTx {
    fn clone(&self) -> Self {
        self.ring.state.lock().unwrap().senders += 1;
        ReplyTx { ring: Arc::clone(&self.ring) }
    }
}

impl Drop for ReplyTx {
    fn drop(&mut self) {
        let mut st = self.ring.state.lock().unwrap();
        st.senders -= 1;
        let disconnected = st.senders == 0;
        drop(st);
        if disconnected {
            // wake a receiver blocked in `recv` so it can return None
            self.ring.cv.notify_all();
        }
    }
}

/// Per-request bookkeeping inside the batcher (internal; public only
/// because it crosses the `Batcher` API boundary).
#[doc(hidden)]
pub struct Pending {
    pub req: TransformRequest,
    pub tx: ResponseTx,
    pub enqueued: Instant,
}
