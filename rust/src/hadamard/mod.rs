//! Native Fast Walsh-Hadamard Transform kernels.
//!
//! Three implementations of the same transform, mirroring the paper's
//! comparison set:
//!
//! * [`scalar`] — the textbook in-place butterfly loop (paper §2.2 /
//!   Wikipedia pseudocode). Unvectorised; the correctness oracle.
//! * [`dao`] — the Dao AI Lab `fast-hadamard-transform` algorithm at the
//!   level the CPU can express it: 8-elements-per-"thread" register stage,
//!   then hierarchical contiguous butterfly passes (the warp-shuffle and
//!   shared-memory exchange phases collapse into cache-blocked passes).
//!   The measured baseline.
//! * [`hadacore`] — the paper's contribution: the transform as rounds of
//!   16x16 matrix multiplications against `H_16` (and the §3.3
//!   block-diagonal residual factor), executed by the [`mma`] microkernel —
//!   the CPU stand-in for a Tensor Core / MXU tile op.
//!
//! Plus support: [`matrices`] (Sylvester construction, the Paley-II
//! non-power-of-two bases, & factor matrices), [`mma`] (the 16x16 tile
//! microkernel), and dtype-generic wrappers over f32 / f16 / bf16
//! storage (paper Appendix C).
//!
//! All transforms operate row-wise on a `rows x n` row-major buffer and
//! compute `x <- (x @ H_n) * scale` per row (the right-Hadamard-transform
//! convention of the fast-hadamard-transform library; `H_n` symmetric).
//!
//! Supported sizes are `n = B * 2^k` with base `B ∈ {1, 12, 20, 28, 40}`
//! — the same family the fast-hadamard-transform library ships, covering
//! the Llama-family hidden dims (14336 = 28·512, 28672 = 28·1024,
//! 40960 = 40·1024) that a plain power-of-two kernel excludes. For
//! `B > 1` the transform factors as `H_n = H_B ⊗ H_{2^k}` (base axis
//! slow): a leading block-diagonal base-matrix stage followed by the
//! power-of-two machinery on each contiguous `2^k` block. The full
//! derivation is in `docs/KERNEL_MATH.md`.

pub mod dao;
pub mod hadacore;
pub mod matrices;
pub mod mma;
pub mod scalar;
pub mod simd;

use crate::util::f16::Element;

pub use dao::fwht_dao_f32;
pub use hadacore::fwht_hadacore_f32;
pub use matrices::{
    block_diagonal, factor_16, hadamard_base, hadamard_dense, is_pow2,
    is_supported_size, split_base, H16,
};
pub use scalar::fwht_scalar_f32;

/// Transform options shared by all kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FwhtOptions {
    /// Output scaling applied after the transform.
    pub scale: f32,
}

impl FwhtOptions {
    /// No scaling (raw ±1 transform).
    pub fn raw() -> Self {
        FwhtOptions { scale: 1.0 }
    }

    /// Orthonormal scaling `1/sqrt(n)` — the paper's convention.
    pub fn normalized(n: usize) -> Self {
        FwhtOptions { scale: 1.0 / (n as f32).sqrt() }
    }

    /// Explicit scale.
    pub fn with_scale(scale: f32) -> Self {
        FwhtOptions { scale }
    }
}

/// Which kernel implementation to run (used by the router/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Textbook scalar butterfly (oracle).
    Scalar,
    /// Dao-style optimised butterfly (baseline).
    Dao,
    /// HadaCore 16x16 matrix-unit rounds (the paper's kernel).
    HadaCore,
}

impl KernelKind {
    /// Canonical name used in manifests / CLI.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Dao => "dao",
            KernelKind::HadaCore => "hadacore",
        }
    }

    /// Parse a kernel name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(KernelKind::Scalar),
            "dao" | "baseline" => Some(KernelKind::Dao),
            "hadacore" => Some(KernelKind::HadaCore),
            _ => None,
        }
    }

    /// All kernels, oracle first.
    pub fn all() -> [KernelKind; 3] {
        [KernelKind::Scalar, KernelKind::Dao, KernelKind::HadaCore]
    }
}

/// Deterministic ±1 sign vector: a pure function of `(seed, n)`.
///
/// This is the diagonal `D` of the QuaRot-style randomized rotation
/// `x ← (x·D) @ H_n / √n`: one [`crate::util::rng::Rng`] draw per
/// element, seeded with `seed ^ n·0x9E3779B97F4A7C15` so different sizes
/// draw decorrelated streams from the same user seed, taking the top bit
/// of each draw. Every path that needs the signs (engine prologue, wire
/// requests, tests, the Python golden port) derives them through this
/// one function, so they agree byte-for-byte by construction.
pub fn sign_vector(seed: u64, n: usize) -> Vec<f32> {
    let mut rng =
        crate::util::rng::Rng::new(seed ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..n)
        .map(|_| if rng.next_u64() >> 63 == 0 { 1.0 } else { -1.0 })
        .collect()
}

/// Process-wide cap on distinct `(seed, n)` sign vectors kept alive by
/// [`sign_vector_cached`]. Steady-state serving traffic uses a handful
/// of rotation seeds; the cap exists so adversarial seed churn (or a
/// randomized test) cannot grow the cache without bound.
const SIGN_CACHE_CAP: usize = 64;

/// Memoised [`sign_vector`]: one shared `Arc` per `(seed, n)`, so the
/// per-batch prologue materialisation the exec engine used to perform
/// (`Vec` + `Arc` per batch — the PR 7 allocation caveat) becomes two
/// map lookups after warmup. Misses past [`SIGN_CACHE_CAP`] allocate a
/// fresh uncached vector instead of evicting: the first
/// `SIGN_CACHE_CAP` working-set seeds stay permanently zero-alloc, and
/// overflow traffic degrades to exactly the old per-batch behaviour.
pub fn sign_vector_cached(seed: u64, n: usize) -> std::sync::Arc<Vec<f32>> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    static CACHE: crate::util::lazy::Lazy<Mutex<HashMap<(u64, usize), Arc<Vec<f32>>>>> =
        crate::util::lazy::Lazy::new(|| Mutex::new(HashMap::new()));
    let mut cache = CACHE.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(hit) = cache.get(&(seed, n)) {
        return Arc::clone(hit);
    }
    let fresh = Arc::new(sign_vector(seed, n));
    if cache.len() < SIGN_CACHE_CAP {
        cache.insert((seed, n), Arc::clone(&fresh));
    }
    fresh
}

/// Multiply every `signs.len()`-sized row of `data` elementwise by
/// `signs` (`x ← x·D`). Each multiply is by ±1.0, an **exact** IEEE
/// operation — applying the flip fused inside a chunk traversal, before
/// or after 16-bit widening, or as a separate pass all produce the same
/// bits, which is what makes the fused prologue provably identical to
/// the unfused pre-multiply.
pub fn apply_signs(data: &mut [f32], signs: &[f32]) {
    assert!(!signs.is_empty(), "empty sign vector");
    assert_eq!(data.len() % signs.len(), 0, "data not a multiple of n");
    for row in data.chunks_exact_mut(signs.len()) {
        for (v, s) in row.iter_mut().zip(signs) {
            *v *= *s;
        }
    }
}

/// A randomized-rotation step fused into the transform as a prologue:
/// the [`crate::exec`] engine sign-flips each chunk's rows in the same
/// working-set traversal that transforms them (mirror of the fused
/// [`crate::quant::Epilogue`]), so the rotation `x ← (x·D) @ H_n · s`
/// costs one multiply per element and zero extra passes over the batch.
///
/// The inverse (`unrotate`) is the transform followed by the same sign
/// flip — see [`unapply`](Prologue::unapply). With the orthonormal
/// scale, `unrotate(rotate(x)) = x`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Prologue {
    /// Plain transform, no rotation.
    #[default]
    None,
    /// Seeded ±1 diagonal `D = diag(sign_vector(seed, n))` applied
    /// before the transform.
    SignFlip {
        /// Seed of the sign stream (pure function of `(seed, n)`).
        seed: u64,
    },
}

impl Prologue {
    /// True for the plain (no-rotation) prologue.
    pub fn is_none(self) -> bool {
        matches!(self, Prologue::None)
    }

    /// Admission-time validation against a transform size. Every
    /// supported size admits a sign flip; the hook exists so the router
    /// treats prologues and epilogues uniformly.
    pub fn validate(self, n: usize) -> Result<(), String> {
        match self {
            _ if n == 0 => Err("prologue requires n > 0".to_string()),
            _ => Ok(()),
        }
    }

    /// The materialised sign vector, or `None` for [`Prologue::None`].
    pub fn signs(self, n: usize) -> Option<Vec<f32>> {
        match self {
            Prologue::None => None,
            Prologue::SignFlip { seed } => Some(sign_vector(seed, n)),
        }
    }

    /// Like [`signs`](Prologue::signs), but served from the process-wide
    /// [`sign_vector_cached`] pool — the steady-state path the exec
    /// engine uses so rotated serving traffic stays zero-alloc after
    /// warmup (a sign vector is a pure function of `(seed, n)`, so
    /// sharing the `Arc` across batches is exact).
    pub fn signs_cached(self, n: usize) -> Option<std::sync::Arc<Vec<f32>>> {
        match self {
            Prologue::None => None,
            Prologue::SignFlip { seed } => Some(sign_vector_cached(seed, n)),
        }
    }

    /// Undo this prologue's rotation on already-transformed rows: apply
    /// the transform again (caller does that part), then flip the same
    /// signs. `data` holds rows of length `n`.
    pub fn unapply(self, data: &mut [f32], n: usize) {
        if let Some(signs) = self.signs(n) {
            apply_signs(data, &signs);
        }
    }
}

/// Dispatch a f32 transform by kernel kind. `data.len()` must be a
/// multiple of `n`.
pub fn fwht_f32(kind: KernelKind, data: &mut [f32], n: usize, opts: &FwhtOptions) {
    match kind {
        KernelKind::Scalar => fwht_scalar_f32(data, n, opts),
        KernelKind::Dao => fwht_dao_f32(data, n, opts),
        KernelKind::HadaCore => fwht_hadacore_f32(data, n, opts),
    }
}

/// Dtype-generic transform over 16-bit (or f32) storage.
///
/// Mirrors the paper's 16-bit path: widen to an FP32 working buffer
/// (Tensor-Core/MXU accumulators are FP32 for BF16), transform, then
/// narrow with round-to-nearest-even. For `f32` this still runs through
/// the same code path (widen/narrow are the identity).
///
/// Allocates a fresh working buffer per call; hot paths (the
/// [`crate::exec`] engine's workers) use [`fwht_generic_with_scratch`]
/// with a reused per-thread workspace instead.
pub fn fwht_generic<E: Element>(
    kind: KernelKind,
    data: &mut [E],
    n: usize,
    opts: &FwhtOptions,
) {
    let mut work: Vec<f32> = Vec::new();
    fwht_generic_with_scratch(kind, data, n, opts, &mut work);
}

/// [`fwht_generic`] with a caller-owned f32 workspace.
///
/// `scratch` is cleared and refilled with the widened input; its capacity
/// is retained across calls, so a workspace reused for same-shaped
/// batches performs **no heap allocation in steady state** — the
/// widen-compute-narrow staging Ootomo & Yokota (2022) show can be made
/// cheap when the working set is reused deliberately.
pub fn fwht_generic_with_scratch<E: Element>(
    kind: KernelKind,
    data: &mut [E],
    n: usize,
    opts: &FwhtOptions,
    scratch: &mut Vec<f32>,
) {
    scratch.clear();
    scratch.extend(data.iter().map(|v| v.to_f32()));
    fwht_f32(kind, scratch, n, opts);
    for (dst, src) in data.iter_mut().zip(scratch.iter()) {
        *dst = E::from_f32(*src);
    }
}

/// Out-of-place convenience wrapper (the paper's Appendix B compares
/// in-place vs out-of-place; the native kernels are in-place by default
/// and this allocates the destination copy explicitly).
pub fn fwht_f32_out_of_place(
    kind: KernelKind,
    src: &[f32],
    n: usize,
    opts: &FwhtOptions,
) -> Vec<f32> {
    let mut dst = src.to_vec();
    fwht_f32(kind, &mut dst, n, opts);
    dst
}

/// Validate a (len, n) pair: n in the supported `B * 2^k` family within
/// bounds, len divisible. Returns the row count `len / n`.
pub fn validate_dims(len: usize, n: usize) -> Result<usize, String> {
    if !is_supported_size(n) {
        return Err(format!(
            "Hadamard size must be B * 2^k with B in {{1, 12, 20, 28, 40}}, got {n}"
        ));
    }
    if n > crate::MAX_HADAMARD_SIZE {
        return Err(format!(
            "Hadamard size {n} exceeds supported maximum {}",
            crate::MAX_HADAMARD_SIZE
        ));
    }
    if len % n != 0 {
        return Err(format!("buffer length {len} not a multiple of n={n}"));
    }
    Ok(len / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in KernelKind::all() {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("baseline"), Some(KernelKind::Dao));
        assert_eq!(KernelKind::parse("x"), None);
    }

    #[test]
    fn options_constructors() {
        assert_eq!(FwhtOptions::raw().scale, 1.0);
        assert!((FwhtOptions::normalized(256).scale - 1.0 / 16.0).abs() < 1e-7);
        assert_eq!(FwhtOptions::with_scale(2.0).scale, 2.0);
    }

    #[test]
    fn validate_dims_checks() {
        assert_eq!(validate_dims(1024, 256), Ok(4));
        // 48 = 12 * 4 is in the family; 100 is not a multiple of it
        assert!(validate_dims(100, 48).is_err());
        assert_eq!(validate_dims(96, 48), Ok(2));
        assert!(validate_dims(100, 256).is_err());
        assert!(validate_dims(1 << 21, 1 << 17).is_err());
        // the non-power-of-two family end to end
        assert_eq!(validate_dims(2 * 14336, 14336), Ok(2));
        assert_eq!(validate_dims(40960, 40960), Ok(1));
        assert!(validate_dims(100, 10).is_err());
        assert!(
            validate_dims(100, 10).unwrap_err().contains("12, 20, 28, 40"),
            "rejection must enumerate the size family"
        );
    }

    #[test]
    fn sign_vector_is_deterministic_and_balanced() {
        let a = sign_vector(7, 1024);
        let b = sign_vector(7, 1024);
        assert_eq!(a, b, "pure function of (seed, n)");
        assert!(a.iter().all(|&s| s == 1.0 || s == -1.0));
        let plus = a.iter().filter(|&&s| s == 1.0).count();
        assert!((300..=724).contains(&plus), "degenerate sign stream: {plus}");
        // different seeds and different sizes draw different streams
        assert_ne!(a, sign_vector(8, 1024));
        assert_ne!(a[..512], sign_vector(7, 512)[..]);
    }

    #[test]
    fn apply_signs_is_exact_and_involutive() {
        let mut rng = crate::util::rng::Rng::new(21);
        let n = 256;
        let x = rng.normal_vec(3 * n);
        let signs = sign_vector(3, n);
        let mut y = x.clone();
        apply_signs(&mut y, &signs);
        // ±1 multiply flips at most the sign bit — exact
        for (a, b) in x.iter().zip(y.iter()) {
            assert_eq!(a.abs().to_bits(), b.abs().to_bits());
        }
        apply_signs(&mut y, &signs);
        assert_eq!(x, y, "D·D = I bit-exactly");
    }

    #[test]
    fn prologue_basics() {
        assert!(Prologue::None.is_none());
        assert!(!Prologue::SignFlip { seed: 1 }.is_none());
        assert!(Prologue::None.signs(64).is_none());
        assert_eq!(
            Prologue::SignFlip { seed: 5 }.signs(64).unwrap(),
            sign_vector(5, 64)
        );
        assert!(Prologue::SignFlip { seed: 5 }.validate(256).is_ok());
        assert!(Prologue::SignFlip { seed: 5 }.validate(0).is_err());
        assert_eq!(Prologue::default(), Prologue::None);
    }

    #[test]
    fn rotate_then_unrotate_recovers_input() {
        // orthonormal scale: unrotate(rotate(x)) == x up to f32 rounding
        let mut rng = crate::util::rng::Rng::new(33);
        let n = 512;
        let x = rng.normal_vec(2 * n);
        let p = Prologue::SignFlip { seed: 11 };
        let opts = FwhtOptions::normalized(n);
        let mut y = x.clone();
        apply_signs(&mut y, &p.signs(n).unwrap());
        fwht_f32(KernelKind::HadaCore, &mut y, n, &opts);
        // inverse: transform, then the same signs
        fwht_f32(KernelKind::HadaCore, &mut y, n, &opts);
        p.unapply(&mut y, n);
        crate::util::prop::assert_close(&y, &x, 1e-4, 1e-4);
    }

    #[test]
    fn generic_with_scratch_matches_and_reuses_capacity() {
        use crate::util::f16::F16;
        let mut rng = crate::util::rng::Rng::new(9);
        let (rows, n) = (3usize, 256usize);
        let x = rng.normal_vec(rows * n);
        let base: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let opts = FwhtOptions::normalized(n);

        let mut plain = base.clone();
        fwht_generic(KernelKind::HadaCore, &mut plain, n, &opts);

        let mut scratched = base;
        let mut scratch = Vec::new();
        fwht_generic_with_scratch(
            KernelKind::HadaCore,
            &mut scratched,
            n,
            &opts,
            &mut scratch,
        );
        assert_eq!(plain, scratched, "scratch path must be bit-identical");

        // steady state: a second same-shaped call must not reallocate
        let cap = scratch.capacity();
        fwht_generic_with_scratch(
            KernelKind::HadaCore,
            &mut scratched,
            n,
            &opts,
            &mut scratch,
        );
        assert_eq!(scratch.capacity(), cap);
    }
}
