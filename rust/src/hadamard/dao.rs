//! Dao-style optimised butterfly FWHT — the measured baseline.
//!
//! The Dao AI Lab `fast-hadamard-transform` CUDA kernel (paper §2.4)
//! organises the butterfly recursion as:
//!
//! 1. each thread owns 8 contiguous elements and completes the first three
//!    levels entirely in registers;
//! 2. warp shuffles carry the next five levels;
//! 3. two threadblock-wide shared-memory transposes carry the rest.
//!
//! On a CPU the same hierarchy maps onto the memory system instead of the
//! thread hierarchy: the first three levels run unrolled on 8-element
//! register blocks, and the remaining levels are contiguous-run butterfly
//! passes whose inner loops auto-vectorise (the "warp/block exchange" is
//! free — it's just addressing). This gives the baseline the same
//! algorithmic structure and op count (`2 m n log2 n` flops) the paper
//! attributes to it.

use super::matrices::{hadamard_base, split_base};
use super::mma::left_mul_base_strided;
use super::{validate_dims, FwhtOptions};

/// First three butterfly levels of one 8-element block, fully unrolled
/// (the "8 elements per thread" register stage).
#[inline]
fn fwht8(b: &mut [f32]) {
    // level h=1
    let (a0, a1) = (b[0] + b[1], b[0] - b[1]);
    let (a2, a3) = (b[2] + b[3], b[2] - b[3]);
    let (a4, a5) = (b[4] + b[5], b[4] - b[5]);
    let (a6, a7) = (b[6] + b[7], b[6] - b[7]);
    // level h=2
    let (c0, c2) = (a0 + a2, a0 - a2);
    let (c1, c3) = (a1 + a3, a1 - a3);
    let (c4, c6) = (a4 + a6, a4 - a6);
    let (c5, c7) = (a5 + a7, a5 - a7);
    // level h=4
    b[0] = c0 + c4;
    b[1] = c1 + c5;
    b[2] = c2 + c6;
    b[3] = c3 + c7;
    b[4] = c0 - c4;
    b[5] = c1 - c5;
    b[6] = c2 - c6;
    b[7] = c3 - c7;
}

/// One butterfly level with pair distance `h >= 8`: contiguous runs of
/// length `h` vectorise cleanly.
#[inline]
fn butterfly_level(row: &mut [f32], h: usize) {
    let n = row.len();
    let mut i = 0;
    while i < n {
        let (lo, hi) = row[i..i + 2 * h].split_at_mut(h);
        for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
            let a = *x;
            let b = *y;
            *x = a + b;
            *y = a - b;
        }
        i += 2 * h;
    }
}

/// Power-of-two Dao butterfly over one contiguous `m`-sized block.
#[inline]
fn dao_pow2_block(blk: &mut [f32]) {
    let m = blk.len();
    if m < 8 {
        // sizes 2 and 4: plain levels (no 8-block stage available)
        let mut h = 1;
        while h < m {
            let mut i = 0;
            while i < m {
                for j in i..i + h {
                    let x = blk[j];
                    let y = blk[j + h];
                    blk[j] = x + y;
                    blk[j + h] = x - y;
                }
                i += 2 * h;
            }
            h *= 2;
        }
    } else {
        // register stage: 3 levels per 8-element block
        for b in blk.chunks_exact_mut(8) {
            fwht8(b);
        }
        // exchange stages: levels h = 8 .. m/2
        let mut h = 8;
        while h < m {
            butterfly_level(blk, h);
            h *= 2;
        }
    }
}

/// In-place Dao-style FWHT of every `n`-sized row in `data`.
///
/// Non-power-of-two sizes `n = B * 2^k` run the leading base-matrix
/// stage (the tiled [`left_mul_base_strided`] contraction with `H_B`)
/// and then the butterfly hierarchy on each contiguous `2^k` block —
/// the same stage split as the HadaCore kernel, so the baseline pays a
/// comparable cost structure on the widened size family.
pub fn fwht_dao_f32(data: &mut [f32], n: usize, opts: &FwhtOptions) {
    let rows = validate_dims(data.len(), n).expect("invalid dimensions");
    let (base, m) = split_base(n).expect("validated by validate_dims");
    let hb = (base > 1).then(|| hadamard_base(base));
    for r in 0..rows {
        let row = &mut data[r * n..(r + 1) * n];
        if let Some(hb) = hb {
            left_mul_base_strided(row, base, m, hb);
        }
        if m > 1 {
            for blk in row.chunks_exact_mut(m) {
                dao_pow2_block(blk);
            }
        }
        if opts.scale != 1.0 {
            for v in row.iter_mut() {
                *v *= opts.scale;
            }
        }
    }
}

/// Out-of-place variant: copies then transforms (the library's default
/// mode before the paper's Appendix B in-place patch; benchmarked in the
/// in-place ablation).
pub fn fwht_dao_f32_out_of_place(
    src: &[f32],
    dst: &mut [f32],
    n: usize,
    opts: &FwhtOptions,
) {
    assert_eq!(src.len(), dst.len());
    dst.copy_from_slice(src);
    fwht_dao_f32(dst, n, opts);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::scalar::fwht_scalar_f32;
    use crate::util::prop::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_all_sizes() {
        let mut rng = Rng::new(1);
        for k in 1..=15 {
            let n = 1usize << k;
            let rows = if n > 4096 { 2 } else { 4 };
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x.clone();
            fwht_dao_f32(&mut got, n, &FwhtOptions::normalized(n));
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            assert_close(&got, &want, 1e-4, 1e-4);
        }
    }

    #[test]
    fn matches_scalar_non_pow2_sizes() {
        let mut rng = Rng::new(9);
        for n in [12usize, 24, 40, 48, 80, 112, 768, 5120, 14336] {
            let rows = if n > 4096 { 2 } else { 3 };
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x;
            fwht_dao_f32(&mut got, n, &FwhtOptions::normalized(n));
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            assert_close(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn fwht8_is_h8() {
        // single 8-block equals a size-8 scalar transform
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(8);
        let mut got = x.clone();
        fwht8(&mut got);
        let mut want = x;
        fwht_scalar_f32(&mut want, 8, &FwhtOptions::raw());
        assert_close(&got, &want, 1e-5, 1e-5);
    }

    #[test]
    fn property_matches_scalar_random_shapes() {
        check("dao vs scalar", 30, |rng| {
            let n = 1usize << rng.range(1, 13);
            let rows = rng.range(1, 4);
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x;
            fwht_dao_f32(&mut got, n, &FwhtOptions::raw());
            fwht_scalar_f32(&mut want, n, &FwhtOptions::raw());
            assert_close(&got, &want, 1e-4, 1e-3);
        });
    }

    #[test]
    fn out_of_place_matches_in_place() {
        let mut rng = Rng::new(3);
        let n = 256;
        let src = rng.normal_vec(4 * n);
        let mut oop = vec![0.0f32; src.len()];
        fwht_dao_f32_out_of_place(&src, &mut oop, n, &FwhtOptions::normalized(n));
        let mut ip = src.clone();
        fwht_dao_f32(&mut ip, n, &FwhtOptions::normalized(n));
        assert_eq!(oop, ip);
    }
}
