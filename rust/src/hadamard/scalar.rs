//! Textbook in-place butterfly FWHT — the correctness oracle.
//!
//! Direct transcription of the paper's §2.2 pseudocode (per row): `log2(n)`
//! levels, each pairing elements `h` apart with an add/sub. Deliberately
//! unoptimised — every other kernel is validated against this one, which
//! in turn is validated against the dense Hadamard matmul in tests.
//!
//! Non-power-of-two sizes `n = B * 2^k` (`H_n = H_B ⊗ H_{2^k}`, base
//! axis slow — see `docs/KERNEL_MATH.md`) run a leading naive dense
//! contraction with `H_B` across the `B` strided blocks, then the
//! butterfly on each contiguous `2^k` block. The base stage is its own
//! textbook loop (independent of the optimised [`super::mma`] tile
//! kernels) so this file stays a self-contained oracle.

use super::matrices::{hadamard_base, split_base};
use super::{validate_dims, FwhtOptions};

/// In-place scalar FWHT of every `n`-sized row in `data`.
///
/// Panics on invalid dimensions (see [`validate_dims`]).
pub fn fwht_scalar_f32(data: &mut [f32], n: usize, opts: &FwhtOptions) {
    let rows = validate_dims(data.len(), n).expect("invalid dimensions");
    let (base, m) = split_base(n).expect("validated by validate_dims");
    let hb = (base > 1).then(|| hadamard_base(base));
    let mut tmp = vec![0.0f32; if base > 1 { base } else { 0 }];
    for r in 0..rows {
        let row = &mut data[r * n..(r + 1) * n];
        // leading base stage: y_b = sum_c H_B[b][c] * x_c across the B
        // blocks of m contiguous elements, one output column at a time
        if let Some(hb) = hb {
            for t in 0..m {
                for (b, slot) in tmp.iter_mut().enumerate() {
                    *slot = (0..base).map(|c| hb[b * base + c] * row[c * m + t]).sum();
                }
                for (b, v) in tmp.iter().enumerate() {
                    row[b * m + t] = *v;
                }
            }
        }
        // power-of-two butterfly on each contiguous m-block
        for blk in row.chunks_exact_mut(m) {
            let mut h = 1;
            while h < m {
                let mut i = 0;
                while i < m {
                    for j in i..i + h {
                        let x = blk[j];
                        let y = blk[j + h];
                        blk[j] = x + y;
                        blk[j + h] = x - y;
                    }
                    i += h * 2;
                }
                h *= 2;
            }
        }
        if opts.scale != 1.0 {
            for v in row.iter_mut() {
                *v *= opts.scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::matrices::{hadamard_dense, matvec_right};
    use crate::util::prop::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn size_2_by_hand() {
        let mut d = vec![3.0f32, 1.0];
        fwht_scalar_f32(&mut d, 2, &FwhtOptions::raw());
        assert_eq!(d, vec![4.0, 2.0]);
    }

    #[test]
    fn size_4_by_hand() {
        // H4 @ [1,0,0,0] = first row of H4 = [1,1,1,1]
        let mut d = vec![1.0f32, 0.0, 0.0, 0.0];
        fwht_scalar_f32(&mut d, 4, &FwhtOptions::raw());
        assert_eq!(d, vec![1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn matches_dense_matmul_all_small_sizes() {
        let mut rng = Rng::new(42);
        for n in [2usize, 4, 8, 16, 32, 64, 128, 256, 512] {
            let h = hadamard_dense(n);
            let x = rng.normal_vec(n);
            let mut got = x.clone();
            fwht_scalar_f32(&mut got, n, &FwhtOptions::raw());
            let mut want = vec![0.0f32; n];
            matvec_right(&x, &h, n, &mut want);
            assert_close(&got, &want, 1e-4, 1e-3);
        }
    }

    #[test]
    fn matches_dense_matmul_non_pow2_sizes() {
        use crate::hadamard::matrices::matvec_hadamard_n;
        let mut rng = Rng::new(43);
        for n in [12usize, 20, 24, 28, 40, 48, 96, 160, 224, 768] {
            let x = rng.normal_vec(n);
            let mut got = x.clone();
            fwht_scalar_f32(&mut got, n, &FwhtOptions::raw());
            let mut want = vec![0.0f32; n];
            matvec_hadamard_n(&x, n, &mut want);
            assert_close(&got, &want, 1e-4, 1e-3);
        }
    }

    #[test]
    fn multi_row_independent() {
        let mut rng = Rng::new(7);
        let n = 64;
        let rows = 5;
        let data = rng.normal_vec(rows * n);
        // transform all rows at once
        let mut all = data.clone();
        fwht_scalar_f32(&mut all, n, &FwhtOptions::raw());
        // transform each row separately
        for r in 0..rows {
            let mut one = data[r * n..(r + 1) * n].to_vec();
            fwht_scalar_f32(&mut one, n, &FwhtOptions::raw());
            assert_eq!(&all[r * n..(r + 1) * n], &one[..]);
        }
    }

    #[test]
    fn normalized_is_involution() {
        check("scalar involution", 20, |rng| {
            let k = rng.range(1, 10);
            let n = 1usize << k;
            let x = rng.normal_vec(2 * n);
            let mut y = x.clone();
            let opts = FwhtOptions::normalized(n);
            fwht_scalar_f32(&mut y, n, &opts);
            fwht_scalar_f32(&mut y, n, &opts);
            assert_close(&y, &x, 1e-4, 1e-4);
        });
    }

    #[test]
    fn preserves_norm_when_normalized() {
        check("scalar norm", 20, |rng| {
            let n = 1usize << rng.range(1, 12);
            let x = rng.normal_vec(n);
            let mut y = x.clone();
            fwht_scalar_f32(&mut y, n, &FwhtOptions::normalized(n));
            let nx: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            let ny: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
            assert!(
                ((nx - ny).abs() / nx.max(1e-12)) < 1e-4,
                "norm drift: {nx} vs {ny}"
            );
        });
    }

    #[test]
    #[should_panic(expected = "invalid dimensions")]
    fn rejects_bad_len() {
        let mut d = vec![0.0f32; 100];
        fwht_scalar_f32(&mut d, 64, &FwhtOptions::raw());
    }
}
