//! 16x16 tile matrix-multiply microkernel — the CPU stand-in for one
//! Tensor Core `mma` pair / one MXU tile op.
//!
//! The paper performs a 16x16 x 16x16 product with two
//! `mma.m16n8k16` instructions. On CPU the analogous primitive is a fully
//! unrolled 16x16 kernel that LLVM auto-vectorises to AVX/NEON lanes: the
//! inner dimension (16 f32 = 64 B = one cache line) maps onto SIMD
//! registers, and the `k` loop accumulates fused multiply-adds.
//!
//! Three shapes cover every use in the HadaCore rounds:
//!
//! * [`right_mul_h`]  — `X (R x 16, row-major) <- X @ M` for tall-skinny X
//!   (the fast-axis round; R is `rows * n / 16`).
//! * [`left_mul_h_strided`] — `B (16 x inner) <- M @ B` where B's rows are
//!   `inner` apart in memory (the strided rounds; vectorises over `inner`).
//! * [`mm16`] — plain 16x16 x 16x16 product used by tests and the padded
//!   cross-chunk round.

use super::simd;

/// C = A @ B for 16x16 row-major tiles (f32, FP32 accumulate).
#[inline]
pub fn mm16(a: &[f32; 256], b: &[f32; 256], c: &mut [f32; 256]) {
    for i in 0..16 {
        let mut acc = [0.0f32; 16];
        for k in 0..16 {
            let aik = a[i * 16 + k];
            let brow = &b[k * 16..k * 16 + 16];
            for j in 0..16 {
                acc[j] += aik * brow[j];
            }
        }
        c[i * 16..i * 16 + 16].copy_from_slice(&acc);
    }
}

/// In-place `X <- X @ M` where `x` is `(rows, 16)` row-major contiguous
/// and `m` is a 16x16 row-major factor (H16 or a block-diagonal tile).
///
/// This is the fast-axis HadaCore round: every contiguous group of 16
/// elements is one row of X. Rows are processed in blocks of 4 to give
/// the compiler independent accumulator chains.
pub fn right_mul_h(x: &mut [f32], m: &[f32; 256]) {
    debug_assert!(x.len() % 16 == 0);
    let rows = x.len() / 16;
    let mut i = 0;
    // unrolled pairs of rows: two independent accumulator sets
    while i + 2 <= rows {
        let (r0, rest) = x[i * 16..].split_at_mut(16);
        let r1 = &mut rest[..16];
        let mut acc0 = [0.0f32; 16];
        let mut acc1 = [0.0f32; 16];
        for k in 0..16 {
            let a0 = r0[k];
            let a1 = r1[k];
            let mrow = &m[k * 16..k * 16 + 16];
            for j in 0..16 {
                acc0[j] += a0 * mrow[j];
                acc1[j] += a1 * mrow[j];
            }
        }
        r0.copy_from_slice(&acc0);
        r1.copy_from_slice(&acc1);
        i += 2;
    }
    if i < rows {
        let r = &mut x[i * 16..i * 16 + 16];
        let mut acc = [0.0f32; 16];
        for k in 0..16 {
            let a = r[k];
            let mrow = &m[k * 16..k * 16 + 16];
            for j in 0..16 {
                acc[j] += a * mrow[j];
            }
        }
        r.copy_from_slice(&acc);
    }
}

/// In-place `B <- M @ B` where `b` views a `(16, inner)` block whose rows
/// are `inner` elements apart starting at `b[0]` (so `b.len() == 16*inner`),
/// and `m` is a 16x16 row-major factor.
///
/// This is the strided HadaCore round: the contraction runs over the 16
/// strided rows while the arithmetic vectorises over the contiguous
/// `inner` axis. Since `M` entries are ±1 (or 0 on the block-diagonal
/// tile) the products still compile to mul+add chains over full SIMD
/// width; specialising to add/sub is the job of the perf pass if the
/// profile asks for it.
///
/// Works column-tile by column-tile (64 columns = 4 cache lines) to stay
/// in registers/L1 for very large `inner`.
pub fn left_mul_h_strided(b: &mut [f32], inner: usize, m: &[f32; 256]) {
    debug_assert_eq!(b.len(), 16 * inner);
    const TILE: usize = 64;
    let mut col = 0;
    let mut tmp = [0.0f32; 16 * TILE];
    while col < inner {
        let w = TILE.min(inner - col);
        // gather-compute-scatter on a (16, w) column tile
        for i in 0..16 {
            let out = &mut tmp[i * w..(i + 1) * w];
            out.iter_mut().for_each(|v| *v = 0.0);
            for k in 0..16 {
                let mik = m[i * 16 + k];
                if mik == 0.0 {
                    continue; // block-diagonal tiles are mostly zero
                }
                let src = &b[k * inner + col..k * inner + col + w];
                for (o, s) in out.iter_mut().zip(src.iter()) {
                    *o += mik * s;
                }
            }
        }
        for i in 0..16 {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

/// In-place `B <- M @ B` for a `(size, inner)` block with `size < 16`
/// (the small cross-chunk factor for n/256 < 16, and the n<16 base case).
/// `m` is `size x size` row-major.
pub fn left_mul_small_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    debug_assert_eq!(b.len(), size * inner);
    debug_assert_eq!(m.len(), size * size);
    const TILE: usize = 64;
    let mut tmp = vec![0.0f32; size * TILE];
    let mut col = 0;
    while col < inner {
        let w = TILE.min(inner - col);
        for i in 0..size {
            let out = &mut tmp[i * w..(i + 1) * w];
            out.iter_mut().for_each(|v| *v = 0.0);
            for k in 0..size {
                let mik = m[i * size + k];
                let src = &b[k * inner + col..k * inner + col + w];
                for (o, s) in out.iter_mut().zip(src.iter()) {
                    *o += mik * s;
                }
            }
        }
        for i in 0..size {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

/// Largest base order [`left_mul_base_strided`] supports (sizes its
/// stack tile).
pub const MAX_BASE: usize = 40;

/// In-place `B <- H_base @ B` for a `(size, inner)` block whose rows are
/// `inner` elements apart (`b.len() == size * inner`), with `m` an
/// arbitrary dense `size x size` factor and `size <= MAX_BASE`.
///
/// This is the leading base-matrix stage of the non-power-of-two
/// transform (`n = B * 2^k`, `inner = 2^k`): the contraction runs over
/// the `B` strided blocks while the arithmetic vectorises over the
/// contiguous `inner` axis — the same gather-compute-scatter tiling as
/// [`left_mul_small_strided`], but with a stack tile sized for the
/// largest base so the per-row hot path performs no heap allocation.
pub fn left_mul_base_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    debug_assert_eq!(b.len(), size * inner);
    debug_assert_eq!(m.len(), size * size);
    assert!(size <= MAX_BASE, "base order {size} exceeds {MAX_BASE}");
    (simd::ops().left_mul_base_strided)(b, size, inner, m)
}

// ---------------------------------------------------------------------
// Fast constant-factor paths (§Perf), now runtime-dispatched.
//
// The generic tile kernels above multiply by an arbitrary 16x16 matrix —
// the faithful stand-in for a Tensor Core/MXU `mma`, and what the tests
// verify against. For the *constant* Hadamard factors the product has a
// closed butterfly form (H16 = 4 radix-2 stages; the §3.3 block-diagonal
// tile = m stages), which removes the mul-by-±1 generality the
// auto-vectoriser cannot see through. Profiling showed the generic path
// ran ~10-30x below the butterfly baseline because the `m[i*16+k]`
// branch-and-multiply pattern defeats SLP vectorisation; these
// specialisations are the optimisation the perf pass landed
// (EXPERIMENTS.md §Perf has the before/after).
//
// Since ISSUE 8 the butterfly bodies live in [`super::simd`]: the
// wrappers below validate shapes and dispatch through the process-wide
// backend table (AVX2 / AVX-512 / NEON / scalar, `HADACORE_SIMD`
// override). Every backend is bit-identical — see `simd` and
// `docs/KERNEL_MATH.md` §8 — so callers never observe which one ran
// except through `simd::dispatch_count`.

/// Fast `X <- X @ H16` over a `(rows, 16)` contiguous buffer:
/// the 16x16 constant product realised as 4 radix-2 stages per row.
pub fn right_mul_h16_fast(x: &mut [f32]) {
    debug_assert!(x.len() % 16 == 0);
    (simd::ops().right_mul_h16)(x)
}

/// Fast `X <- X @ (I kron H_{2^m})` over a `(rows, 16)` contiguous buffer
/// (the paper's §3.3 block-diagonal residual round): m stages per group.
pub fn right_mul_bd_fast(x: &mut [f32], m: u32) {
    debug_assert!(m < 4);
    debug_assert!(x.len() % 16 == 0);
    if m == 0 {
        return; // identity — not a dispatch
    }
    (simd::ops().right_mul_bd)(x, m)
}

/// Fused round 0 for the block-diagonal path (§Perf iteration 2): the BD
/// residual round (m stages on the fastest 2^m axis) followed by the
/// first 16-round (4 stages at stride 2^m) equals one contiguous
/// butterfly of size `16 * 2^m` — `H_{2^m}` fast kron `H16` next is
/// `H_{16*2^m}` on the fastest contiguous chunk. One memory pass instead
/// of two, and no short-stride stage.
pub fn right_mul_fused_chunk_fast(x: &mut [f32], chunk: usize) {
    debug_assert!(chunk.is_power_of_two() && (16..=128).contains(&chunk));
    debug_assert!(x.len() % chunk == 0);
    (simd::ops().right_mul_fused_chunk)(x, chunk)
}

/// Fast `B <- H16 @ B` for a `(16, inner)` block with row stride `inner`:
/// 4 butterfly stages over the row index; each stage is a pair of
/// contiguous `inner`-length vector add/subs, which vectorises at full
/// width.
///
/// §Perf note: a register-tiled single-pass variant (load a 16x16 tile,
/// run all 4 stages in registers, store — the CUDA kernel's fragment
/// pattern) was tried and measured *slower* on this CPU (0.45-0.9x vs
/// 0.6-1.1x against the baseline): the strided 16-float tile loads defeat
/// the hardware prefetcher, while the stage-pass form streams whole rows.
/// Run-to-run noise on this machine is ~±30-40% at large working sets;
/// medians over 12 samples were compared. See EXPERIMENTS.md §Perf.
pub fn left_mul_h16_strided_fast(b: &mut [f32], inner: usize) {
    debug_assert_eq!(b.len(), 16 * inner);
    (simd::ops().left_mul_h16_strided)(b, inner)
}

/// Fast `B <- H_size @ B` for a small `(size, inner)` block (size in
/// {2,4,8}): log2(size) row-stages.
pub fn left_mul_small_strided_fast(b: &mut [f32], size: usize, inner: usize) {
    debug_assert_eq!(b.len(), size * inner);
    debug_assert!(size.is_power_of_two() && size <= 16);
    (simd::ops().left_mul_small_strided)(b, size, inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::matrices::{block_diagonal, hadamard_dense, H16};
    use crate::util::rng::Rng;

    fn naive_mm(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                for j in 0..n {
                    c[i * n + j] += a[i * n + k] * b[k * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn mm16_matches_naive() {
        let mut rng = Rng::new(1);
        let mut a = [0.0f32; 256];
        let mut b = [0.0f32; 256];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let mut c = [0.0f32; 256];
        mm16(&a, &b, &mut c);
        let want = naive_mm(&a, &b, 16);
        for (g, w) in c.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn mm16_h16_squared_is_16_identity() {
        // H16 @ H16 = 16 * I
        let mut c = [0.0f32; 256];
        mm16(&H16, &H16, &mut c);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 16.0 } else { 0.0 };
                assert_eq!(c[i * 16 + j], want);
            }
        }
    }

    #[test]
    fn right_mul_matches_naive_rows() {
        let mut rng = Rng::new(2);
        for rows in [1usize, 2, 3, 7, 16] {
            let mut x = rng.normal_vec(rows * 16);
            let orig = x.clone();
            right_mul_h(&mut x, &H16);
            for r in 0..rows {
                for j in 0..16 {
                    let want: f32 =
                        (0..16).map(|k| orig[r * 16 + k] * H16[k * 16 + j]).sum();
                    assert!((x[r * 16 + j] - want).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn left_mul_strided_matches_naive() {
        let mut rng = Rng::new(3);
        for inner in [1usize, 5, 16, 64, 100, 256] {
            let mut b = rng.normal_vec(16 * inner);
            let orig = b.clone();
            left_mul_h_strided(&mut b, inner, &H16);
            for i in 0..16 {
                for c in 0..inner {
                    let want: f32 = (0..16)
                        .map(|k| H16[i * 16 + k] * orig[k * inner + c])
                        .sum();
                    assert!(
                        (b[i * inner + c] - want).abs() < 1e-3,
                        "inner={inner} i={i} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    fn left_mul_strided_block_diagonal_skips_zeros() {
        let bd = block_diagonal(2); // H4 tiled: 75% zeros
        let mut rng = Rng::new(4);
        let inner = 32;
        let mut b = rng.normal_vec(16 * inner);
        let orig = b.clone();
        left_mul_h_strided(&mut b, inner, &bd);
        for i in 0..16 {
            for c in 0..inner {
                let want: f32 =
                    (0..16).map(|k| bd[i * 16 + k] * orig[k * inner + c]).sum();
                assert!((b[i * inner + c] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fast_right_mul_matches_generic() {
        let mut rng = Rng::new(21);
        for rows in [1usize, 3, 8] {
            let x = rng.normal_vec(rows * 16);
            let mut fast = x.clone();
            let mut generic = x;
            right_mul_h16_fast(&mut fast);
            right_mul_h(&mut generic, &H16);
            for (a, b) in fast.iter().zip(generic.iter()) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fast_right_mul_bd_matches_generic() {
        let mut rng = Rng::new(22);
        for m in 0..4u32 {
            let bd = block_diagonal(m);
            let x = rng.normal_vec(4 * 16);
            let mut fast = x.clone();
            let mut generic = x;
            right_mul_bd_fast(&mut fast, m);
            right_mul_h(&mut generic, &bd);
            for (a, b) in fast.iter().zip(generic.iter()) {
                assert!((a - b).abs() < 1e-4, "m={m}");
            }
        }
    }

    #[test]
    fn fast_left_mul_matches_generic() {
        let mut rng = Rng::new(23);
        for inner in [1usize, 2, 8, 37, 256] {
            let x = rng.normal_vec(16 * inner);
            let mut fast = x.clone();
            let mut generic = x;
            left_mul_h16_strided_fast(&mut fast, inner);
            left_mul_h_strided(&mut generic, inner, &H16);
            for (a, b) in fast.iter().zip(generic.iter()) {
                assert!((a - b).abs() < 1e-3, "inner={inner}");
            }
        }
    }

    #[test]
    fn fast_left_small_matches_generic() {
        let mut rng = Rng::new(24);
        for size in [2usize, 4, 8] {
            let h = hadamard_dense(size);
            for inner in [1usize, 5, 64] {
                let x = rng.normal_vec(size * inner);
                let mut fast = x.clone();
                let mut generic = x;
                left_mul_small_strided_fast(&mut fast, size, inner);
                left_mul_small_strided(&mut generic, size, inner, &h);
                for (a, b) in fast.iter().zip(generic.iter()) {
                    assert!((a - b).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn left_mul_base_strided_matches_naive() {
        use crate::hadamard::matrices::hadamard_base;
        let mut rng = Rng::new(25);
        // the Paley-II bases plus a random dense factor (generality)
        for size in [12usize, 20, 28, 40] {
            let h = hadamard_base(size);
            for inner in [1usize, 5, 64, 100] {
                let mut b = rng.normal_vec(size * inner);
                let orig = b.clone();
                left_mul_base_strided(&mut b, size, inner, h);
                for i in 0..size {
                    for c in 0..inner {
                        let want: f32 = (0..size)
                            .map(|k| h[i * size + k] * orig[k * inner + c])
                            .sum();
                        assert!(
                            (b[i * inner + c] - want).abs() < 1e-3,
                            "size={size} inner={inner} i={i} c={c}"
                        );
                    }
                }
            }
        }
        let size = 12;
        let h: Vec<f32> = rng.normal_vec(size * size);
        let inner = 37;
        let mut b = rng.normal_vec(size * inner);
        let orig = b.clone();
        left_mul_base_strided(&mut b, size, inner, &h);
        for i in 0..size {
            for c in 0..inner {
                let want: f32 = (0..size)
                    .map(|k| h[i * size + k] * orig[k * inner + c])
                    .sum();
                assert!((b[i * inner + c] - want).abs() < 1e-3);
            }
        }
    }

    /// Every reachable SIMD backend must produce the **bit-identical**
    /// output of the scalar backend on every dispatched entry point —
    /// the unit-level face of the `tests/simd_parity.rs` matrix.
    /// Serialised against other backend-forcing tests via the global
    /// counter semantics: bit-identity makes cross-test interleaving
    /// benign, and the previous backend is always restored.
    #[test]
    fn all_reachable_backends_are_bit_identical_to_scalar() {
        use crate::hadamard::matrices::hadamard_base;
        use crate::hadamard::simd::{self, Backend};
        let mut rng = Rng::new(26);
        let prev = simd::force(Backend::Scalar).unwrap();
        for backend in Backend::all() {
            if !simd::reachable(backend) {
                continue;
            }
            let sc = simd::ops_for(Backend::Scalar);
            let ops = simd::ops_for(backend);

            for rows in [1usize, 3, 8] {
                let x = rng.normal_vec(rows * 16);
                let (mut a, mut b) = (x.clone(), x);
                (sc.right_mul_h16)(&mut a);
                (ops.right_mul_h16)(&mut b);
                assert_eq!(bits(&a), bits(&b), "{backend:?} right_mul_h16");
                for m in 1..4u32 {
                    let x = rng.normal_vec(rows * 16);
                    let (mut a, mut b) = (x.clone(), x);
                    (sc.right_mul_bd)(&mut a, m);
                    (ops.right_mul_bd)(&mut b, m);
                    assert_eq!(bits(&a), bits(&b), "{backend:?} right_mul_bd m={m}");
                }
            }
            for chunk in [16usize, 32, 64, 128] {
                let x = rng.normal_vec(3 * chunk);
                let (mut a, mut b) = (x.clone(), x);
                (sc.right_mul_fused_chunk)(&mut a, chunk);
                (ops.right_mul_fused_chunk)(&mut b, chunk);
                assert_eq!(bits(&a), bits(&b), "{backend:?} fused chunk={chunk}");
            }
            for inner in [1usize, 2, 7, 37, 256] {
                let x = rng.normal_vec(16 * inner);
                let (mut a, mut b) = (x.clone(), x);
                (sc.left_mul_h16_strided)(&mut a, inner);
                (ops.left_mul_h16_strided)(&mut b, inner);
                assert_eq!(bits(&a), bits(&b), "{backend:?} h16 inner={inner}");
                for size in [2usize, 4, 8] {
                    let x = rng.normal_vec(size * inner);
                    let (mut a, mut b) = (x.clone(), x);
                    (sc.left_mul_small_strided)(&mut a, size, inner);
                    (ops.left_mul_small_strided)(&mut b, size, inner);
                    assert_eq!(
                        bits(&a),
                        bits(&b),
                        "{backend:?} small size={size} inner={inner}"
                    );
                }
            }
            for base in [12usize, 20, 28, 40] {
                let h = hadamard_base(base);
                for inner in [1usize, 5, 64, 100] {
                    let x = rng.normal_vec(base * inner);
                    let (mut a, mut b) = (x.clone(), x);
                    (sc.left_mul_base_strided)(&mut a, base, inner, h);
                    (ops.left_mul_base_strided)(&mut b, base, inner, h);
                    assert_eq!(
                        bits(&a),
                        bits(&b),
                        "{backend:?} base={base} inner={inner}"
                    );
                }
            }
        }
        simd::force(prev).unwrap();
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn left_mul_small_matches_naive() {
        let mut rng = Rng::new(5);
        for size in [2usize, 4, 8] {
            let h = hadamard_dense(size);
            for inner in [1usize, 17, 64, 80] {
                let mut b = rng.normal_vec(size * inner);
                let orig = b.clone();
                left_mul_small_strided(&mut b, size, inner, &h);
                for i in 0..size {
                    for c in 0..inner {
                        let want: f32 = (0..size)
                            .map(|k| h[i * size + k] * orig[k * inner + c])
                            .sum();
                        assert!((b[i * inner + c] - want).abs() < 1e-3);
                    }
                }
            }
        }
    }
}
