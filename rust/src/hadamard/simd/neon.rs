//! NEON backend (aarch64): 16-element butterfly tiles on four 128-bit
//! registers.
//!
//! Lane mapping (`docs/KERNEL_MATH.md` §8): one contiguous 16-group is
//! `(q0, q1, q2, q3)` = lanes 0–3 / 4–7 / 8–11 / 12–15. Stage `h = 1`
//! is `vrev64q_f32` (swap adjacent lanes), stage `h = 2` is
//! `vextq_f32::<2>` (rotate halves), each followed by one add and one
//! sub with `vbslq_f32` selecting the sub into the `j + h` lanes;
//! stages `h = 4, 8` are cross-register `(a + b, a - b)` pairs. Every
//! output lane is the scalar butterfly's single add or sub in the same
//! operand order — bit-identical.
//!
//! **No FMA**: the base-stage contraction must use `vmulq_f32` +
//! `vaddq_f32` (two roundings). `vmlaq_f32` is *banned* here — on
//! aarch64 it lowers to a fused FMLA instruction whose single rounding
//! would diverge from the scalar `*o += mik * s`.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

use super::SimdOps;
use crate::hadamard::mma::MAX_BASE;

/// Lane masks selecting the `j + h` (minus) lanes of a stage.
const MINUS_H1: [u32; 4] = [0, u32::MAX, 0, u32::MAX];
const MINUS_H2: [u32; 4] = [0, 0, u32::MAX, u32::MAX];

/// Stage `h = 1` on one 4-lane register: `s[j] = v[j ^ 1]`.
#[inline(always)]
unsafe fn bf1(v: float32x4_t) -> float32x4_t {
    let s = vrev64q_f32(v);
    let plus = vaddq_f32(v, s);
    let minus = vsubq_f32(s, v);
    vbslq_f32(vld1q_u32(MINUS_H1.as_ptr()), minus, plus)
}

/// Stage `h = 2` on one 4-lane register: `s[j] = v[j ^ 2]`.
#[inline(always)]
unsafe fn bf2(v: float32x4_t) -> float32x4_t {
    let s = vextq_f32::<2>(v, v);
    let plus = vaddq_f32(v, s);
    let minus = vsubq_f32(s, v);
    vbslq_f32(vld1q_u32(MINUS_H2.as_ptr()), minus, plus)
}

/// The first `stages` butterfly stages (h = 1, 2, 4, 8) of one
/// 16-group held as `(q0, q1, q2, q3)`.
#[inline(always)]
unsafe fn stages16(
    mut q0: float32x4_t,
    mut q1: float32x4_t,
    mut q2: float32x4_t,
    mut q3: float32x4_t,
    stages: u32,
) -> (float32x4_t, float32x4_t, float32x4_t, float32x4_t) {
    if stages >= 1 {
        q0 = bf1(q0);
        q1 = bf1(q1);
        q2 = bf1(q2);
        q3 = bf1(q3);
    }
    if stages >= 2 {
        q0 = bf2(q0);
        q1 = bf2(q1);
        q2 = bf2(q2);
        q3 = bf2(q3);
    }
    if stages >= 3 {
        // h=4: register pairs (q0,q1) and (q2,q3)
        let (p0, m0) = (vaddq_f32(q0, q1), vsubq_f32(q0, q1));
        let (p1, m1) = (vaddq_f32(q2, q3), vsubq_f32(q2, q3));
        q0 = p0;
        q1 = m0;
        q2 = p1;
        q3 = m1;
    }
    if stages >= 4 {
        // h=8: register pairs (q0,q2) and (q1,q3)
        let (p0, m0) = (vaddq_f32(q0, q2), vsubq_f32(q0, q2));
        let (p1, m1) = (vaddq_f32(q1, q3), vsubq_f32(q1, q3));
        q0 = p0;
        q1 = p1;
        q2 = m0;
        q3 = m1;
    }
    (q0, q1, q2, q3)
}

/// Run `stages` butterfly stages over every contiguous 16-group.
unsafe fn stages_over_groups(x: &mut [f32], stages: u32) {
    for g in x.chunks_exact_mut(16) {
        let p = g.as_mut_ptr();
        let (q0, q1, q2, q3) = stages16(
            vld1q_f32(p),
            vld1q_f32(p.add(4)),
            vld1q_f32(p.add(8)),
            vld1q_f32(p.add(12)),
            stages,
        );
        vst1q_f32(p, q0);
        vst1q_f32(p.add(4), q1);
        vst1q_f32(p.add(8), q2);
        vst1q_f32(p.add(12), q3);
    }
}

/// Elementwise `(a, b) <- (a + b, a - b)` over two equal-length rows.
#[inline(always)]
unsafe fn add_sub_rows(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let pa = a.as_mut_ptr();
    let pb = b.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let va = vld1q_f32(pa.add(i));
        let vb = vld1q_f32(pb.add(i));
        vst1q_f32(pa.add(i), vaddq_f32(va, vb));
        vst1q_f32(pb.add(i), vsubq_f32(va, vb));
        i += 4;
    }
    while i < n {
        let xa = *pa.add(i);
        let xb = *pb.add(i);
        *pa.add(i) = xa + xb;
        *pb.add(i) = xa - xb;
        i += 1;
    }
}

unsafe fn right_mul_h16(x: &mut [f32]) {
    stages_over_groups(x, 4);
}

unsafe fn right_mul_bd(x: &mut [f32], m: u32) {
    stages_over_groups(x, m);
}

unsafe fn right_mul_fused_chunk(x: &mut [f32], chunk: usize) {
    stages_over_groups(x, 4);
    for c in x.chunks_exact_mut(chunk) {
        let mut h = 16usize;
        while h < chunk {
            let mut i = 0;
            while i < chunk {
                let (lo, hi) = c[i..i + 2 * h].split_at_mut(h);
                add_sub_rows(lo, hi);
                i += 2 * h;
            }
            h *= 2;
        }
    }
}

unsafe fn left_mul_h16_strided(b: &mut [f32], inner: usize) {
    let mut h = 1usize;
    for _ in 0..4 {
        let mut i = 0;
        while i < 16 {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

unsafe fn left_mul_small_strided(b: &mut [f32], size: usize, inner: usize) {
    let mut h = 1usize;
    while h < size {
        let mut i = 0;
        while i < size {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

unsafe fn left_mul_base_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    const TILE: usize = 64;
    let mut tmp = [0.0f32; MAX_BASE * TILE];
    let mut col = 0;
    while col < inner {
        let w = TILE.min(inner - col);
        for i in 0..size {
            let po = tmp[i * w..(i + 1) * w].as_mut_ptr();
            let mut j = 0;
            while j + 4 <= w {
                vst1q_f32(po.add(j), vdupq_n_f32(0.0));
                j += 4;
            }
            while j < w {
                *po.add(j) = 0.0;
                j += 1;
            }
            for k in 0..size {
                let mik = m[i * size + k];
                let vm = vdupq_n_f32(mik);
                let ps = b.as_ptr().add(k * inner + col);
                let mut j = 0;
                while j + 4 <= w {
                    let acc = vld1q_f32(po.add(j));
                    let s = vld1q_f32(ps.add(j));
                    // vmulq + vaddq, never vmlaq (FMLA fuses the rounding)
                    let prod = vmulq_f32(vm, s);
                    vst1q_f32(po.add(j), vaddq_f32(acc, prod));
                    j += 4;
                }
                while j < w {
                    *po.add(j) += mik * *ps.add(j);
                    j += 1;
                }
            }
        }
        for i in 0..size {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

// Safe wrappers — SAFETY throughout: NEON is a baseline feature of
// every aarch64 target this crate compiles for (the module itself is
// `cfg(target_arch = "aarch64")`-gated), and the kernels use no other
// unchecked preconditions.

fn right_mul_h16_s(x: &mut [f32]) {
    unsafe { right_mul_h16(x) }
}
fn right_mul_bd_s(x: &mut [f32], m: u32) {
    unsafe { right_mul_bd(x, m) }
}
fn right_mul_fused_chunk_s(x: &mut [f32], chunk: usize) {
    unsafe { right_mul_fused_chunk(x, chunk) }
}
fn left_mul_h16_strided_s(b: &mut [f32], inner: usize) {
    unsafe { left_mul_h16_strided(b, inner) }
}
fn left_mul_small_strided_s(b: &mut [f32], size: usize, inner: usize) {
    unsafe { left_mul_small_strided(b, size, inner) }
}
fn left_mul_base_strided_s(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    unsafe { left_mul_base_strided(b, size, inner, m) }
}

/// The NEON dispatch table.
pub static OPS: SimdOps = SimdOps {
    right_mul_h16: right_mul_h16_s,
    right_mul_bd: right_mul_bd_s,
    right_mul_fused_chunk: right_mul_fused_chunk_s,
    left_mul_h16_strided: left_mul_h16_strided_s,
    left_mul_small_strided: left_mul_small_strided_s,
    left_mul_base_strided: left_mul_base_strided_s,
};
