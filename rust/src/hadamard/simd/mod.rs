//! Runtime-dispatched SIMD backends for the hot butterfly kernels.
//!
//! The paper maps 16×16 butterfly tiles onto Tensor Core `mma`
//! instructions; the CPU analogue is mapping the same tiles onto the
//! widest vector unit the host exposes. This module provides AVX2,
//! AVX-512 and NEON implementations of the six hot entry points in
//! [`crate::hadamard::mma`] (the 16-group butterfly rounds, the fused
//! chunk round, the strided row rounds, and the dense base-matrix
//! stage), selected **once per process** behind a dispatch table:
//!
//! * detection: [`is_x86_feature_detected!`] on x86-64 (AVX-512F >
//!   AVX2 > scalar), compile-time NEON on aarch64, scalar everywhere
//!   else;
//! * override: `HADACORE_SIMD=off|scalar|avx2|avx512|neon|auto`, read
//!   **once** and frozen at first dispatch (same contract as
//!   `HADACORE_TUNE` in [`crate::exec::tune`]). Forcing a backend the
//!   host cannot run falls back to scalar with a warning rather than
//!   crashing;
//! * tests: [`force`] switches the active backend programmatically
//!   (the forced-dispatch parity matrix in `tests/simd_parity.rs`),
//!   and per-backend [`dispatch_count`] counters prove non-vacuously
//!   which backend actually executed.
//!
//! ## Bit-identity contract
//!
//! Every backend must be **bit-identical** to [`Backend::Scalar`] (and
//! therefore to `fwht_scalar` and the golden digests): each butterfly
//! output is a single IEEE add or sub of two inputs, and the base-stage
//! contraction is a fixed-order chain of mul-then-add pairs — both
//! reorder freely across *lanes* without touching the per-element
//! operation sequence. The derivation lives in `docs/KERNEL_MATH.md`
//! §8; the one sharp edge is that **no backend may use fused
//! multiply-add** (scalar Rust never contracts `a*b + c`, so an FMA's
//! single rounding would diverge). See the per-ISA modules.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::lazy::Lazy;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "x86_64")]
pub mod avx512;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod scalar;

/// One SIMD implementation of the hot kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Backend {
    /// Portable scalar loops — the reference the others are pinned to.
    Scalar = 0,
    /// 256-bit AVX2 (x86-64).
    Avx2 = 1,
    /// 512-bit AVX-512F (x86-64).
    Avx512 = 2,
    /// 128-bit NEON (aarch64 baseline).
    Neon = 3,
}

impl Backend {
    /// All backends, scalar first (index order matches the enum
    /// discriminants and the dispatch-counter array).
    pub fn all() -> [Backend; 4] {
        [Backend::Scalar, Backend::Avx2, Backend::Avx512, Backend::Neon]
    }

    /// Stable lowercase name (env values, bench records, stats).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Avx512 => "avx512",
            Backend::Neon => "neon",
        }
    }

    /// Parse an explicit backend name (`off` is an alias for `scalar`;
    /// `auto` is *not* a backend and is handled by the env reader).
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "scalar" => Some(Backend::Scalar),
            "avx2" => Some(Backend::Avx2),
            "avx512" => Some(Backend::Avx512),
            "neon" => Some(Backend::Neon),
            _ => None,
        }
    }

    /// f32 lanes per vector register — the throughput width the
    /// roofline model feeds into
    /// [`crate::gpu_model::roofline::recommend_fusion_depth_for_lanes`].
    pub fn lanes(self) -> usize {
        match self {
            Backend::Scalar => 1,
            Backend::Avx2 => 8,
            Backend::Avx512 => 16,
            Backend::Neon => 4,
        }
    }

    fn from_index(i: usize) -> Backend {
        match i {
            1 => Backend::Avx2,
            2 => Backend::Avx512,
            3 => Backend::Neon,
            _ => Backend::Scalar,
        }
    }
}

/// The six hot entry points every backend implements. All function
/// pointers are *safe* wrappers; the per-ISA modules guarantee their
/// internal `unsafe` (target-feature intrinsics) is sound because a
/// backend's table is only ever installed after [`reachable`] confirmed
/// the feature on this host.
pub struct SimdOps {
    /// `X <- X @ H16` over a `(rows, 16)` contiguous buffer.
    pub right_mul_h16: fn(&mut [f32]),
    /// `X <- X @ (I kron H_{2^m})`, `m` in `1..=3` stages per 16-group.
    pub right_mul_bd: fn(&mut [f32], u32),
    /// Fused round 0: 4 stages per 16-group, then levels `h=16..chunk/2`.
    pub right_mul_fused_chunk: fn(&mut [f32], usize),
    /// `B <- H16 @ B` for a `(16, inner)` row-strided block.
    pub left_mul_h16_strided: fn(&mut [f32], usize),
    /// `B <- H_size @ B` for a small pow2 `(size, inner)` block.
    pub left_mul_small_strided: fn(&mut [f32], usize, usize),
    /// `B <- M @ B` for a dense `(size, size)` base factor.
    pub left_mul_base_strided: fn(&mut [f32], usize, usize, &[f32]),
}

/// True if this host can execute `backend`.
pub fn reachable(backend: Backend) -> bool {
    match backend {
        Backend::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 => is_x86_feature_detected!("avx512f"),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => true,
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 | Backend::Avx512 => false,
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => false,
    }
}

/// The best backend this host can run (widest first).
pub fn detect() -> Backend {
    for b in [Backend::Avx512, Backend::Avx2, Backend::Neon] {
        if reachable(b) {
            return b;
        }
    }
    Backend::Scalar
}

/// `HADACORE_SIMD`, read once per process (first dispatch) and frozen —
/// later `set_var` calls are deliberately ignored, mirroring
/// `HADACORE_TUNE`.
static ENV_CHOICE: Lazy<Backend> = Lazy::new(env_choice);

fn env_choice() -> Backend {
    match std::env::var("HADACORE_SIMD") {
        Ok(v) if v.eq_ignore_ascii_case("auto") || v.is_empty() => detect(),
        Ok(v) => match Backend::parse(&v) {
            Some(b) if reachable(b) => b,
            Some(b) => {
                eprintln!(
                    "HADACORE_SIMD={}: backend not reachable on this host, \
                     falling back to scalar",
                    b.name()
                );
                Backend::Scalar
            }
            None => {
                eprintln!("HADACORE_SIMD={v}: unknown backend, using auto-detection");
                detect()
            }
        },
        Err(_) => detect(),
    }
}

/// Discriminant of the active backend; `usize::MAX` = not yet frozen.
static ACTIVE: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Per-backend dispatch counters (indexed by discriminant). Relaxed:
/// they are non-vacuity evidence, not synchronisation.
static DISPATCHES: [AtomicU64; 4] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// The active backend, freezing the `HADACORE_SIMD` choice on first
/// call.
pub fn active() -> Backend {
    let cur = ACTIVE.load(Ordering::Acquire);
    if cur != usize::MAX {
        return Backend::from_index(cur);
    }
    let choice = *ENV_CHOICE.force();
    // racing first calls agree: env_choice is memoised by the Lazy
    ACTIVE.store(choice as usize, Ordering::Release);
    choice
}

/// Switch the active backend (tests / benches). Returns the previously
/// active backend so callers can restore it; errs if `backend` is not
/// reachable on this host. This is the *programmatic* override — the
/// env var stays frozen and is simply superseded.
pub fn force(backend: Backend) -> Result<Backend, String> {
    if !reachable(backend) {
        return Err(format!("backend {} not reachable on this host", backend.name()));
    }
    let prev = active(); // freeze the env choice first
    ACTIVE.store(backend as usize, Ordering::Release);
    Ok(prev)
}

/// How many kernel dispatches `backend` has served so far in this
/// process (monotone; never reset).
pub fn dispatch_count(backend: Backend) -> u64 {
    DISPATCHES[backend as usize].load(Ordering::Relaxed)
}

/// Total dispatches across all backends.
pub fn dispatch_total() -> u64 {
    Backend::all().iter().map(|&b| dispatch_count(b)).sum()
}

/// The ops table of `backend`. Unreachable backends fall back to
/// scalar (callers guard with [`reachable`]; this keeps the function
/// total).
pub fn ops_for(backend: Backend) -> &'static SimdOps {
    match backend {
        Backend::Scalar => &scalar::OPS,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if reachable(Backend::Avx2) => &avx2::OPS,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 if reachable(Backend::Avx512) => &avx512::OPS,
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => &neon::OPS,
        _ => &scalar::OPS,
    }
}

/// The active ops table, counting this dispatch. Called by the
/// [`crate::hadamard::mma`] wrappers on every kernel entry.
pub(crate) fn ops() -> &'static SimdOps {
    let b = active();
    DISPATCHES[b as usize].fetch_add(1, Ordering::Relaxed);
    ops_for(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_parse_round_trip() {
        for b in Backend::all() {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("off"), Some(Backend::Scalar));
        assert_eq!(Backend::parse("AVX2"), Some(Backend::Avx2));
        assert_eq!(Backend::parse("auto"), None);
        assert_eq!(Backend::parse("sse9"), None);
    }

    #[test]
    fn lanes_are_the_register_widths() {
        assert_eq!(Backend::Scalar.lanes(), 1);
        assert_eq!(Backend::Neon.lanes(), 4);
        assert_eq!(Backend::Avx2.lanes(), 8);
        assert_eq!(Backend::Avx512.lanes(), 16);
    }

    #[test]
    fn scalar_is_always_reachable_and_detect_is_reachable() {
        assert!(reachable(Backend::Scalar));
        assert!(reachable(detect()));
    }

    #[test]
    fn force_rejects_unreachable_and_restores() {
        if let Some(&bad) = Backend::all().iter().find(|&&b| !reachable(b)) {
            assert!(force(bad).is_err());
        }
        let prev = force(Backend::Scalar).expect("scalar always forceable");
        let before = dispatch_count(Backend::Scalar);
        let mut x = [1.0f32; 16];
        crate::hadamard::mma::right_mul_h16_fast(&mut x);
        assert!(dispatch_count(Backend::Scalar) > before, "forced backend must run");
        force(prev).unwrap();
        assert_eq!(active(), prev);
    }
}
