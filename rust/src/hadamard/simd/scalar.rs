//! Scalar reference backend — the exact loop nests the crate shipped
//! before runtime dispatch existed (moved verbatim from
//! `hadamard/mma.rs`). Every other backend is pinned bit-for-bit to
//! these bodies; the golden digests in `tests/golden/` were produced by
//! them.
//!
//! The loops are written so LLVM's auto-vectoriser can still do its
//! thing (this is the `HADACORE_SIMD=off` fallback, not a deliberately
//! slow path) — the explicit-intrinsic backends exist to remove the
//! dependence on what the auto-vectoriser happens to find.

use super::SimdOps;
use crate::hadamard::mma::MAX_BASE;

/// Butterfly stages `h = 1,2,..,2^(stages-1)` on one contiguous
/// 16-group.
#[inline(always)]
pub(crate) fn fwht16_stages(c: &mut [f32], stages: u32) {
    let mut h = 1usize;
    for _ in 0..stages {
        let mut i = 0;
        while i < 16 {
            for j in i..i + h {
                let a = c[j];
                let b = c[j + h];
                c[j] = a + b;
                c[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// `X <- X @ H16`: 4 radix-2 stages per contiguous 16-row.
pub fn right_mul_h16(x: &mut [f32]) {
    debug_assert!(x.len() % 16 == 0);
    for chunk in x.chunks_exact_mut(16) {
        fwht16_stages(chunk, 4);
    }
}

/// `X <- X @ (I kron H_{2^m})`: m stages per 16-group (`1 <= m < 4`;
/// the `m == 0` identity returns in the dispatch wrapper).
pub fn right_mul_bd(x: &mut [f32], m: u32) {
    debug_assert!(m >= 1 && m < 4);
    for chunk in x.chunks_exact_mut(16) {
        fwht16_stages(chunk, m);
    }
}

/// Fused round 0: 4 stages per 16-group, then contiguous levels
/// `h = 16, 32, 64` inside each `chunk`-sized run.
pub fn right_mul_fused_chunk(x: &mut [f32], chunk: usize) {
    debug_assert!(chunk.is_power_of_two() && (16..=128).contains(&chunk));
    debug_assert!(x.len() % chunk == 0);
    for g in x.chunks_exact_mut(16) {
        fwht16_stages(g, 4);
    }
    for c in x.chunks_exact_mut(chunk) {
        let mut h = 16usize;
        while h < chunk {
            let mut i = 0;
            while i < chunk {
                let (lo, hi) = c[i..i + 2 * h].split_at_mut(h);
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let xa = *a;
                    let xb = *b;
                    *a = xa + xb;
                    *b = xa - xb;
                }
                i += 2 * h;
            }
            h *= 2;
        }
    }
}

/// `B <- H16 @ B` for a `(16, inner)` row-strided block: 4 stages over
/// the row index, each an elementwise add/sub of two `inner`-rows.
pub fn left_mul_h16_strided(b: &mut [f32], inner: usize) {
    debug_assert_eq!(b.len(), 16 * inner);
    let mut h = 1usize;
    for _ in 0..4 {
        let mut i = 0;
        while i < 16 {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                let row_a = &mut head[j * inner..j * inner + inner];
                let row_b = &mut tail[..inner];
                for (a, v) in row_a.iter_mut().zip(row_b.iter_mut()) {
                    let x = *a;
                    let y = *v;
                    *a = x + y;
                    *v = x - y;
                }
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// `B <- H_size @ B` for a small pow2 `(size, inner)` block:
/// log2(size) row-stages.
pub fn left_mul_small_strided(b: &mut [f32], size: usize, inner: usize) {
    debug_assert_eq!(b.len(), size * inner);
    debug_assert!(size.is_power_of_two() && size <= 16);
    let mut h = 1usize;
    while h < size {
        let mut i = 0;
        while i < size {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                let row_a = &mut head[j * inner..j * inner + inner];
                let row_b = &mut tail[..inner];
                for (a, v) in row_a.iter_mut().zip(row_b.iter_mut()) {
                    let x = *a;
                    let y = *v;
                    *a = x + y;
                    *v = x - y;
                }
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// `B <- M @ B` for a dense `size x size` base factor, gather-compute-
/// scatter over 64-column tiles. The k-loop is a strict mul-then-add
/// chain per element — the operation order every vector backend must
/// reproduce exactly (no FMA, no zero-skipping: `o + 0.0*s` can flip
/// the sign of a negative zero, so even the ±0 products are performed).
pub fn left_mul_base_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    debug_assert_eq!(b.len(), size * inner);
    debug_assert_eq!(m.len(), size * size);
    debug_assert!(size <= MAX_BASE);
    const TILE: usize = 64;
    let mut tmp = [0.0f32; MAX_BASE * TILE];
    let mut col = 0;
    while col < inner {
        let w = TILE.min(inner - col);
        for i in 0..size {
            let out = &mut tmp[i * w..(i + 1) * w];
            out.iter_mut().for_each(|v| *v = 0.0);
            for k in 0..size {
                let mik = m[i * size + k];
                let src = &b[k * inner + col..k * inner + col + w];
                for (o, s) in out.iter_mut().zip(src.iter()) {
                    *o += mik * s;
                }
            }
        }
        for i in 0..size {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

/// The scalar dispatch table.
pub static OPS: SimdOps = SimdOps {
    right_mul_h16,
    right_mul_bd,
    right_mul_fused_chunk,
    left_mul_h16_strided,
    left_mul_small_strided,
    left_mul_base_strided,
};
