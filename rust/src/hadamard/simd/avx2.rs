//! AVX2 backend: 16-element butterfly tiles on two 256-bit registers.
//!
//! Lane mapping (`docs/KERNEL_MATH.md` §8): one contiguous 16-group is
//! `(v0, v1)` = lanes 0–7 / 8–15. Stages `h = 1, 2, 4` are in-register
//! shuffles (`s[j] = v[j ^ h]`) followed by one add and one sub with a
//! blend selecting the sub into the `j + h` lanes; stage `h = 8` is the
//! cross-register pair `(v0 + v1, v0 - v1)`. Each output lane is the
//! same single `a + b` / `a - b` the scalar butterfly performs, in the
//! same operand order, so the results are bit-identical.
//!
//! **No FMA**: the base-stage contraction uses an explicit
//! `_mm256_mul_ps` + `_mm256_add_ps` pair (two roundings), never
//! `_mm256_fmadd_ps` (one rounding) — scalar Rust does not contract
//! `acc + m*s`, and bit-identity to the scalar kernel is the contract.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

use super::SimdOps;
use crate::hadamard::mma::MAX_BASE;

/// One in-register butterfly stage: `s = v` shuffled by `SHUF`
/// (`s[j] = v[j ^ h]`), then `plus = v + s`, `minus = s - v`, with
/// `BLEND` selecting `minus` into the upper (`j + h`) lanes — where
/// `s[j+h] = v[j]`, so `minus[j+h] = v[j] - v[j+h]`, the scalar
/// `a - b`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn bf_lane<const SHUF: i32, const BLEND: i32>(v: __m256) -> __m256 {
    let s = _mm256_permute_ps::<SHUF>(v);
    let plus = _mm256_add_ps(v, s);
    let minus = _mm256_sub_ps(s, v);
    _mm256_blend_ps::<BLEND>(plus, minus)
}

/// Stage `h = 4`: swap the 128-bit halves of the register.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn bf_cross128(v: __m256) -> __m256 {
    let s = _mm256_permute2f128_ps::<0x01>(v, v);
    let plus = _mm256_add_ps(v, s);
    let minus = _mm256_sub_ps(s, v);
    _mm256_blend_ps::<0xF0>(plus, minus)
}

/// The first `stages` butterfly stages (h = 1, 2, 4, 8) of one
/// 16-group held as `(v0, v1)`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn stages16(mut v0: __m256, mut v1: __m256, stages: u32) -> (__m256, __m256) {
    if stages >= 1 {
        v0 = bf_lane::<0xB1, 0xAA>(v0); // h=1: swap adjacent lanes
        v1 = bf_lane::<0xB1, 0xAA>(v1);
    }
    if stages >= 2 {
        v0 = bf_lane::<0x4E, 0xCC>(v0); // h=2: swap lane pairs
        v1 = bf_lane::<0x4E, 0xCC>(v1);
    }
    if stages >= 3 {
        v0 = bf_cross128(v0); // h=4: swap 128-bit halves
        v1 = bf_cross128(v1);
    }
    if stages >= 4 {
        // h=8: cross-register — minus lands wholly in v1
        let plus = _mm256_add_ps(v0, v1);
        let minus = _mm256_sub_ps(v0, v1);
        v0 = plus;
        v1 = minus;
    }
    (v0, v1)
}

/// Run `stages` butterfly stages over every contiguous 16-group.
#[target_feature(enable = "avx2")]
unsafe fn stages_over_groups(x: &mut [f32], stages: u32) {
    for g in x.chunks_exact_mut(16) {
        let p = g.as_mut_ptr();
        let (v0, v1) =
            stages16(_mm256_loadu_ps(p), _mm256_loadu_ps(p.add(8)), stages);
        _mm256_storeu_ps(p, v0);
        _mm256_storeu_ps(p.add(8), v1);
    }
}

/// Elementwise `(a, b) <- (a + b, a - b)` over two equal-length rows —
/// the strided butterfly body. Vector main loop + scalar tail, both in
/// ascending index order (each element is independent, so any split is
/// bit-identical).
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn add_sub_rows(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let pa = a.as_mut_ptr();
    let pb = b.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm256_loadu_ps(pa.add(i));
        let vb = _mm256_loadu_ps(pb.add(i));
        _mm256_storeu_ps(pa.add(i), _mm256_add_ps(va, vb));
        _mm256_storeu_ps(pb.add(i), _mm256_sub_ps(va, vb));
        i += 8;
    }
    while i < n {
        let xa = *pa.add(i);
        let xb = *pb.add(i);
        *pa.add(i) = xa + xb;
        *pb.add(i) = xa - xb;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn right_mul_h16(x: &mut [f32]) {
    stages_over_groups(x, 4);
}

#[target_feature(enable = "avx2")]
unsafe fn right_mul_bd(x: &mut [f32], m: u32) {
    stages_over_groups(x, m);
}

#[target_feature(enable = "avx2")]
unsafe fn right_mul_fused_chunk(x: &mut [f32], chunk: usize) {
    stages_over_groups(x, 4);
    for c in x.chunks_exact_mut(chunk) {
        let mut h = 16usize;
        while h < chunk {
            let mut i = 0;
            while i < chunk {
                let (lo, hi) = c[i..i + 2 * h].split_at_mut(h);
                add_sub_rows(lo, hi);
                i += 2 * h;
            }
            h *= 2;
        }
    }
}

#[target_feature(enable = "avx2")]
unsafe fn left_mul_h16_strided(b: &mut [f32], inner: usize) {
    let mut h = 1usize;
    for _ in 0..4 {
        let mut i = 0;
        while i < 16 {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn left_mul_small_strided(b: &mut [f32], size: usize, inner: usize) {
    let mut h = 1usize;
    while h < size {
        let mut i = 0;
        while i < size {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn left_mul_base_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    const TILE: usize = 64;
    let mut tmp = [0.0f32; MAX_BASE * TILE];
    let mut col = 0;
    while col < inner {
        let w = TILE.min(inner - col);
        for i in 0..size {
            let po = tmp[i * w..(i + 1) * w].as_mut_ptr();
            let mut j = 0;
            while j + 8 <= w {
                _mm256_storeu_ps(po.add(j), _mm256_setzero_ps());
                j += 8;
            }
            while j < w {
                *po.add(j) = 0.0;
                j += 1;
            }
            for k in 0..size {
                let mik = m[i * size + k];
                let vm = _mm256_set1_ps(mik);
                let ps = b.as_ptr().add(k * inner + col);
                let mut j = 0;
                while j + 8 <= w {
                    let acc = _mm256_loadu_ps(po.add(j));
                    let s = _mm256_loadu_ps(ps.add(j));
                    // mul then add, never fmadd: the scalar `*o += mik*s`
                    // rounds twice, and bit-identity demands the same
                    let prod = _mm256_mul_ps(vm, s);
                    _mm256_storeu_ps(po.add(j), _mm256_add_ps(acc, prod));
                    j += 8;
                }
                while j < w {
                    *po.add(j) += mik * *ps.add(j);
                    j += 1;
                }
            }
        }
        for i in 0..size {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

// Safe wrappers — SAFETY throughout: this table is only installed by
// `simd::ops_for` after `is_x86_feature_detected!("avx2")` confirmed
// the feature on this host, and the kernels use no other unchecked
// preconditions (pointers derive from the argument slices and every
// debug-checked shape invariant is re-asserted by the `mma` wrappers).

fn right_mul_h16_s(x: &mut [f32]) {
    unsafe { right_mul_h16(x) }
}
fn right_mul_bd_s(x: &mut [f32], m: u32) {
    unsafe { right_mul_bd(x, m) }
}
fn right_mul_fused_chunk_s(x: &mut [f32], chunk: usize) {
    unsafe { right_mul_fused_chunk(x, chunk) }
}
fn left_mul_h16_strided_s(b: &mut [f32], inner: usize) {
    unsafe { left_mul_h16_strided(b, inner) }
}
fn left_mul_small_strided_s(b: &mut [f32], size: usize, inner: usize) {
    unsafe { left_mul_small_strided(b, size, inner) }
}
fn left_mul_base_strided_s(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    unsafe { left_mul_base_strided(b, size, inner, m) }
}

/// The AVX2 dispatch table.
pub static OPS: SimdOps = SimdOps {
    right_mul_h16: right_mul_h16_s,
    right_mul_bd: right_mul_bd_s,
    right_mul_fused_chunk: right_mul_fused_chunk_s,
    left_mul_h16_strided: left_mul_h16_strided_s,
    left_mul_small_strided: left_mul_small_strided_s,
    left_mul_base_strided: left_mul_base_strided_s,
};
