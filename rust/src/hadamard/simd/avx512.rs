//! AVX-512F backend: one 16-element butterfly tile per 512-bit
//! register.
//!
//! Lane mapping (`docs/KERNEL_MATH.md` §8): a contiguous 16-group is
//! exactly one zmm register, so all four stages are in-register.
//! Stages `h = 1, 2` shuffle within 128-bit lanes
//! (`_mm512_permute_ps`), stages `h = 4, 8` shuffle whole 128-bit
//! lanes (`_mm512_shuffle_f32x4`); each stage computes `plus = v + s`,
//! `minus = s - v` and mask-blends `minus` into the `j + h` lanes —
//! where `s[j+h] = v[j]`, so `minus[j+h] = v[j] - v[j+h]`, the scalar
//! `a - b` in the scalar operand order.
//!
//! **No FMA** (same contract as the AVX2 backend): the base-stage
//! contraction is `_mm512_mul_ps` + `_mm512_add_ps`, never
//! `_mm512_fmadd_ps`.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

use super::SimdOps;
use crate::hadamard::mma::MAX_BASE;

/// In-128-bit-lane butterfly stage (`h = 1` or `2`): `SHUF` is the
/// within-lane shuffle (`s[j] = v[j ^ h]`), `MINUS` the lane mask that
/// receives `s - v`.
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn bf_lane<const SHUF: i32>(v: __m512, minus_mask: __mmask16) -> __m512 {
    let s = _mm512_permute_ps::<SHUF>(v);
    let plus = _mm512_add_ps(v, s);
    let minus = _mm512_sub_ps(s, v);
    _mm512_mask_blend_ps(minus_mask, plus, minus)
}

/// Cross-128-bit-lane butterfly stage (`h = 4` or `8`): `SHUF` permutes
/// whole 128-bit lanes.
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn bf_cross<const SHUF: i32>(v: __m512, minus_mask: __mmask16) -> __m512 {
    let s = _mm512_shuffle_f32x4::<SHUF>(v, v);
    let plus = _mm512_add_ps(v, s);
    let minus = _mm512_sub_ps(s, v);
    _mm512_mask_blend_ps(minus_mask, plus, minus)
}

/// The first `stages` butterfly stages (h = 1, 2, 4, 8) of one
/// 16-group held in a single zmm.
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn stages16(mut v: __m512, stages: u32) -> __m512 {
    if stages >= 1 {
        v = bf_lane::<0xB1>(v, 0xAAAA); // h=1: swap adjacent lanes
    }
    if stages >= 2 {
        v = bf_lane::<0x4E>(v, 0xCCCC); // h=2: swap lane pairs
    }
    if stages >= 3 {
        v = bf_cross::<0xB1>(v, 0xF0F0); // h=4: swap adjacent 128-bit lanes
    }
    if stages >= 4 {
        v = bf_cross::<0x4E>(v, 0xFF00); // h=8: swap 256-bit halves
    }
    v
}

/// Run `stages` butterfly stages over every contiguous 16-group.
#[target_feature(enable = "avx512f")]
unsafe fn stages_over_groups(x: &mut [f32], stages: u32) {
    for g in x.chunks_exact_mut(16) {
        let p = g.as_mut_ptr();
        _mm512_storeu_ps(p, stages16(_mm512_loadu_ps(p), stages));
    }
}

/// Elementwise `(a, b) <- (a + b, a - b)` over two equal-length rows.
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn add_sub_rows(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let pa = a.as_mut_ptr();
    let pb = b.as_mut_ptr();
    let mut i = 0;
    while i + 16 <= n {
        let va = _mm512_loadu_ps(pa.add(i));
        let vb = _mm512_loadu_ps(pb.add(i));
        _mm512_storeu_ps(pa.add(i), _mm512_add_ps(va, vb));
        _mm512_storeu_ps(pb.add(i), _mm512_sub_ps(va, vb));
        i += 16;
    }
    while i < n {
        let xa = *pa.add(i);
        let xb = *pb.add(i);
        *pa.add(i) = xa + xb;
        *pb.add(i) = xa - xb;
        i += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn right_mul_h16(x: &mut [f32]) {
    stages_over_groups(x, 4);
}

#[target_feature(enable = "avx512f")]
unsafe fn right_mul_bd(x: &mut [f32], m: u32) {
    stages_over_groups(x, m);
}

#[target_feature(enable = "avx512f")]
unsafe fn right_mul_fused_chunk(x: &mut [f32], chunk: usize) {
    stages_over_groups(x, 4);
    for c in x.chunks_exact_mut(chunk) {
        let mut h = 16usize;
        while h < chunk {
            let mut i = 0;
            while i < chunk {
                let (lo, hi) = c[i..i + 2 * h].split_at_mut(h);
                add_sub_rows(lo, hi);
                i += 2 * h;
            }
            h *= 2;
        }
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn left_mul_h16_strided(b: &mut [f32], inner: usize) {
    let mut h = 1usize;
    for _ in 0..4 {
        let mut i = 0;
        while i < 16 {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn left_mul_small_strided(b: &mut [f32], size: usize, inner: usize) {
    let mut h = 1usize;
    while h < size {
        let mut i = 0;
        while i < size {
            for j in i..i + h {
                let (head, tail) = b.split_at_mut((j + h) * inner);
                add_sub_rows(
                    &mut head[j * inner..j * inner + inner],
                    &mut tail[..inner],
                );
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn left_mul_base_strided(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    const TILE: usize = 64;
    let mut tmp = [0.0f32; MAX_BASE * TILE];
    let mut col = 0;
    while col < inner {
        let w = TILE.min(inner - col);
        for i in 0..size {
            let po = tmp[i * w..(i + 1) * w].as_mut_ptr();
            let mut j = 0;
            while j + 16 <= w {
                _mm512_storeu_ps(po.add(j), _mm512_setzero_ps());
                j += 16;
            }
            while j < w {
                *po.add(j) = 0.0;
                j += 1;
            }
            for k in 0..size {
                let mik = m[i * size + k];
                let vm = _mm512_set1_ps(mik);
                let ps = b.as_ptr().add(k * inner + col);
                let mut j = 0;
                while j + 16 <= w {
                    let acc = _mm512_loadu_ps(po.add(j));
                    let s = _mm512_loadu_ps(ps.add(j));
                    // mul then add, never fmadd (two roundings, like scalar)
                    let prod = _mm512_mul_ps(vm, s);
                    _mm512_storeu_ps(po.add(j), _mm512_add_ps(acc, prod));
                    j += 16;
                }
                while j < w {
                    *po.add(j) += mik * *ps.add(j);
                    j += 1;
                }
            }
        }
        for i in 0..size {
            b[i * inner + col..i * inner + col + w]
                .copy_from_slice(&tmp[i * w..(i + 1) * w]);
        }
        col += w;
    }
}

// Safe wrappers — SAFETY throughout: only installed by `simd::ops_for`
// after `is_x86_feature_detected!("avx512f")` confirmed the feature.

fn right_mul_h16_s(x: &mut [f32]) {
    unsafe { right_mul_h16(x) }
}
fn right_mul_bd_s(x: &mut [f32], m: u32) {
    unsafe { right_mul_bd(x, m) }
}
fn right_mul_fused_chunk_s(x: &mut [f32], chunk: usize) {
    unsafe { right_mul_fused_chunk(x, chunk) }
}
fn left_mul_h16_strided_s(b: &mut [f32], inner: usize) {
    unsafe { left_mul_h16_strided(b, inner) }
}
fn left_mul_small_strided_s(b: &mut [f32], size: usize, inner: usize) {
    unsafe { left_mul_small_strided(b, size, inner) }
}
fn left_mul_base_strided_s(b: &mut [f32], size: usize, inner: usize, m: &[f32]) {
    unsafe { left_mul_base_strided(b, size, inner, m) }
}

/// The AVX-512F dispatch table.
pub static OPS: SimdOps = SimdOps {
    right_mul_h16: right_mul_h16_s,
    right_mul_bd: right_mul_bd_s,
    right_mul_fused_chunk: right_mul_fused_chunk_s,
    left_mul_h16_strided: left_mul_h16_strided_s,
    left_mul_small_strided: left_mul_small_strided_s,
    left_mul_base_strided: left_mul_base_strided_s,
};
