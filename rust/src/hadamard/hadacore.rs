//! HadaCore: the paper's FWHT as rounds of 16x16 matrix multiplications.
//!
//! Every round multiplies one 16-sized axis of the reshaped input by the
//! constant `H_16` (or, on the final round for non-power-of-16 sizes, by
//! the §3.3 block-diagonal tiling of `H_{2^m}`), using the [`super::mma`]
//! microkernel as the matrix-unit stand-in. For `n = 2^m * 16^r` the
//! transform completes in `ceil(log16 n)` rounds instead of `log2 n`
//! butterfly levels — the paper's core trade: `16 m n ceil(log16 n)` flops
//! on matrix hardware vs `2 m n log2 n` flops on scalar hardware.
//!
//! Non-power-of-two sizes `n = B * 2^k` (`B ∈ {12, 20, 28, 40}`, the
//! fast-hadamard-transform base family) factor as `H_n = H_B ⊗ H_{2^k}`
//! and add one **leading base-matrix stage** — a block-diagonal
//! contraction of each row's `(B, 2^k)` view with the dense Paley-II
//! base — ahead of the rounds above, which then treat the buffer as
//! `rows * B` independent length-`2^k` rows. Derivation and supported-
//! size table: `docs/KERNEL_MATH.md`.
//!
//! Memory layout of the rounds (per row of length `n`, fastest axis
//! first): `[2^m | 16 | 16 | ... | 16]`. Round 0 transforms the fastest
//! 16 contiguous elements (one `right_mul_h` over the whole buffer — the
//! analogue of the CUDA kernel transforming each 16x16 register fragment);
//! round `i` transforms the 16-axis with inner stride `2^m * 16^(i-1)`
//! via strided left-multiplies (the analogue of the transpose-through-
//! shared-memory step: on CPU the "transpose" is pure addressing).
//!
//! Two residual-factor strategies are implemented (and benchmarked as an
//! ablation — DESIGN.md E8):
//!
//! * [`ResidualMode::BlockDiagonal`] (default, paper-faithful): the `2^m`
//!   factor is one extra full 16x16 round with `I kron H_{2^m}`. This
//!   reproduces the paper's cost structure — e.g. size 512 pays the same
//!   number of rounds as 4096, the effect visible in its results tables.
//! * [`ResidualMode::SmallFactor`]: contract the `2^m` axis directly with
//!   the small `H_{2^m}` matrix (cheaper; what a CPU would actually do).

use super::matrices::{block_diagonal, factor_16, hadamard_base, split_base};
use super::mma::{
    left_mul_base_strided, left_mul_h16_strided_fast, left_mul_small_strided_fast,
    right_mul_fused_chunk_fast, right_mul_h16_fast,
};
use super::{validate_dims, FwhtOptions};

/// Strategy for the non-power-of-16 residual factor (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualMode {
    /// Full 16x16 round with the block-diagonal tiling (paper-faithful).
    BlockDiagonal,
    /// Direct contraction with the small `H_{2^m}` factor.
    SmallFactor,
}

/// HadaCore kernel configuration.
#[derive(Clone, Copy, Debug)]
pub struct HadaCoreConfig {
    /// Residual-factor strategy.
    pub residual: ResidualMode,
}

impl Default for HadaCoreConfig {
    fn default() -> Self {
        HadaCoreConfig { residual: ResidualMode::BlockDiagonal }
    }
}

/// In-place HadaCore FWHT of every `n`-sized row (default configuration).
pub fn fwht_hadacore_f32(data: &mut [f32], n: usize, opts: &FwhtOptions) {
    fwht_hadacore_f32_cfg(data, n, opts, &HadaCoreConfig::default());
}

/// In-place HadaCore FWHT with an explicit configuration.
///
/// Non-power-of-two sizes `n = B * 2^k` (`B ∈ {12, 20, 28}` after the
/// canonical [`split_base`] factorisation) run a **leading base-matrix
/// stage** — the §3.3 block-diagonal idea applied to the Kronecker
/// factor `H_B`: one tiled contraction of the `(B, 2^k)` view of each
/// row with the dense Paley-II base — and then the 16x16 rounds on each
/// contiguous `2^k` block. Because the `B` blocks are contiguous, the
/// power-of-two rounds see the buffer as `rows * B` independent rows of
/// length `2^k`; no round machinery changes.
pub fn fwht_hadacore_f32_cfg(
    data: &mut [f32],
    n: usize,
    opts: &FwhtOptions,
    cfg: &HadaCoreConfig,
) {
    let rows = validate_dims(data.len(), n).expect("invalid dimensions");
    let (base, pow2) = split_base(n).expect("validated by validate_dims");
    if base > 1 {
        let hb = hadamard_base(base);
        for row in data.chunks_exact_mut(n) {
            left_mul_base_strided(row, base, pow2, hb);
        }
    }
    pow2_rounds(data, rows * base, pow2, cfg.residual);
    apply_scale(data, opts.scale);
}

/// The power-of-two round schedule over a `(rows, m)` view (`m = 2^k`):
/// the original HadaCore kernel body, shared by the direct and planned
/// paths' derivations. `m == 1` is the identity.
fn pow2_rounds(data: &mut [f32], rows: usize, m: usize, residual: ResidualMode) {
    if m == 1 {
        return;
    }
    if m < 16 {
        // base case: m in {2,4,8} — one small round per row
        for row in data.chunks_exact_mut(m) {
            left_mul_small_strided_fast(row, m, 1);
        }
        return;
    }
    let (m2, r) = factor_16(m);
    match residual {
        ResidualMode::BlockDiagonal => {
            // Round 0: fastest 16 elements x (BD residual fused when m2>0,
            // plain H16 when m2==0 — in that case round 0 IS the first
            // 16-round).
            if m2 > 0 {
                // fused: BD round + first 16-round = one contiguous
                // butterfly over chunks of 16 * 2^m2 (see mma.rs §Perf)
                let chunk = (1usize << m2) * 16;
                right_mul_fused_chunk_fast(data, chunk.min(m));
                // remaining 16-rounds at inner = 2^m2 * 16^i for i in 1..r
                for i in 1..r {
                    let inner = (1usize << m2) * 16usize.pow(i);
                    strided_round(data, rows, m, inner);
                }
            } else {
                right_mul_h16_fast(data);
                for i in 1..r {
                    let inner = 16usize.pow(i);
                    strided_round(data, rows, m, inner);
                }
            }
        }
        ResidualMode::SmallFactor => {
            // 16-rounds at inner = 16^i, then the small residual factor
            // on the slowest axis.
            right_mul_h16_fast(data);
            for i in 1..r {
                let inner = 16usize.pow(i);
                strided_round(data, rows, m, inner);
            }
            if m2 > 0 {
                let inner = 16usize.pow(r);
                for row in data.chunks_exact_mut(m) {
                    left_mul_small_strided_fast(row, 1 << m2, inner);
                }
            }
        }
    }
}

/// One pass of the power-of-two round schedule, in execution order.
///
/// Every pass touches a *contiguous aligned block* of the buffer
/// (`block_len` elements) and is independent across blocks, and the
/// block of each pass divides the block of every later pass. Those two
/// facts are what make **round fusion** (`fwht_hadacore_f32_planned_depth`)
/// a pure traversal reordering: a group of consecutive passes can run
/// tile-by-tile over blocks of the *last* pass in the group — one read
/// and one write of the tile instead of one per pass — while every
/// element still undergoes the identical sequence of f32 operations, so
/// the fused output is bit-for-bit the unfused output (see
/// `docs/KERNEL_MATH.md` §Fused rounds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pow2Round {
    /// Round 0: a contiguous butterfly over `chunk`-sized groups —
    /// `H_{2^m} ⊗ H16` on the fastest axis when `chunk > 16` (the BD
    /// residual fused with the first 16-round), plain `H16` when
    /// `chunk == 16`.
    Contiguous { chunk: usize },
    /// A strided 16-round: `H16` on the axis with inner stride `inner`,
    /// block length `16 * inner`.
    Strided { inner: usize },
    /// The explicit residual/small factor: `H_size` on the axis with
    /// inner stride `inner`, block length `size * inner` (always a full
    /// pow2 row). Used by [`ResidualMode::SmallFactor`] and by sizes
    /// with `2^k < 16`.
    Small { size: usize, inner: usize },
}

impl Pow2Round {
    /// Contiguous aligned block length (in elements) this pass operates
    /// on. Divides the block length of every later pass in a schedule.
    pub fn block_len(self) -> usize {
        match self {
            Pow2Round::Contiguous { chunk } => chunk,
            Pow2Round::Strided { inner } => 16 * inner,
            Pow2Round::Small { size, inner } => size * inner,
        }
    }
}

/// Precomputed round structure for one `(n, residual)` pair.
///
/// Everything `fwht_hadacore_f32_cfg` rederives on every call — the
/// canonical `n = B * 2^k` base split, the `2^k = 2^m * 16^r`
/// factorisation, the fused round-0 chunk, the inner stride of each
/// 16-round, and the §3.3 block-diagonal residual table — computed once.
/// [`crate::exec::plan`] memoizes one plan per transform size
/// process-wide so the batch engine's dispatch allocates nothing and
/// recomputes nothing per call.
///
/// # Examples
///
/// ```
/// use hadacore::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
///
/// // 14336 = 28 * 512: a Llama-3 8B FFN dim only the B·2^k family admits
/// let plan = HadaCorePlan::new(14336, &HadaCoreConfig::default());
/// assert_eq!(plan.n(), 14336);
/// assert_eq!(plan.base(), 28);
/// // base stage + fused round 0 (512 = 2·16²) + one strided 16-round
/// assert_eq!(plan.passes(), 3);
///
/// // powers of two have no base stage, as before
/// assert_eq!(HadaCorePlan::new(256, &HadaCoreConfig::default()).base(), 1);
/// ```
#[derive(Clone, Debug)]
pub struct HadaCorePlan {
    n: usize,
    /// Canonical base order (1, 12, 20, or 28; 40·2^k sizes split as
    /// 20·2^(k+1) — see [`split_base`]).
    base: usize,
    /// The power-of-two factor `2^k = n / base`.
    pow2: usize,
    residual: ResidualMode,
    /// The pow2 round schedule in execution order (empty when
    /// `2^k == 1`). Block lengths are non-decreasing and each divides
    /// the next — the invariant round fusion relies on.
    rounds: Vec<Pow2Round>,
    /// The §3.3 residual factor `I kron H_{2^m}` as a 16x16 table
    /// (identity when `m == 0`) — the matrix the tile-microkernel path
    /// and the tests consume.
    bd: [f32; 256],
}

impl HadaCorePlan {
    /// Build the plan for transform size `n` (must be in the supported
    /// `B * 2^k` family within [`crate::MAX_HADAMARD_SIZE`]).
    pub fn new(n: usize, cfg: &HadaCoreConfig) -> HadaCorePlan {
        let (base, pow2) = split_base(n).unwrap_or_else(|| {
            panic!("Hadamard size must be B * 2^k with B in {{1, 12, 20, 28, 40}}, got {n}")
        });
        let (m, r) = if pow2 > 1 { factor_16(pow2) } else { (0, 0) };
        let mut rounds = Vec::new();
        if pow2 > 1 && pow2 < 16 {
            rounds.push(Pow2Round::Small { size: pow2, inner: 1 });
        } else if pow2 >= 16 {
            match cfg.residual {
                ResidualMode::BlockDiagonal => {
                    if m > 0 {
                        rounds.push(Pow2Round::Contiguous {
                            chunk: ((1usize << m) * 16).min(pow2),
                        });
                        for i in 1..r {
                            rounds.push(Pow2Round::Strided {
                                inner: (1usize << m) * 16usize.pow(i),
                            });
                        }
                    } else {
                        rounds.push(Pow2Round::Contiguous { chunk: 16 });
                        for i in 1..r {
                            rounds.push(Pow2Round::Strided { inner: 16usize.pow(i) });
                        }
                    }
                }
                ResidualMode::SmallFactor => {
                    rounds.push(Pow2Round::Contiguous { chunk: 16 });
                    for i in 1..r {
                        rounds.push(Pow2Round::Strided { inner: 16usize.pow(i) });
                    }
                    if m > 0 {
                        rounds.push(Pow2Round::Small {
                            size: 1 << m,
                            inner: 16usize.pow(r),
                        });
                    }
                }
            }
        }
        debug_assert!(
            rounds.windows(2).all(|w| w[1].block_len() % w[0].block_len() == 0),
            "round blocks must nest for fusion to be exact"
        );
        HadaCorePlan {
            n,
            base,
            pow2,
            residual: cfg.residual,
            rounds,
            bd: block_diagonal(m),
        }
    }

    /// Transform size this plan was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Canonical base order of the plan's size (1 for powers of two).
    pub fn base(&self) -> usize {
        self.base
    }

    /// Residual strategy this plan was built for.
    pub fn residual(&self) -> ResidualMode {
        self.residual
    }

    /// Number of memory passes over the buffer the planned execution
    /// makes at fusion depth 1. One less than the paper's `ceil(log16 n)`
    /// logical round count when the §Perf fused round-0 applies (the BD
    /// residual and the first 16-round share one pass); non-power-of-two
    /// sizes add one leading base-matrix pass.
    pub fn passes(&self) -> usize {
        self.passes_at(1)
    }

    /// Memory passes over the buffer at fusion depth `depth`: the base
    /// pass (if any) plus `ceil(rounds / depth)` fused traversals. The
    /// quantity the [`crate::exec::tune`] cost model minimises.
    pub fn passes_at(&self, depth: usize) -> usize {
        let depth = depth.max(1);
        let base_pass = usize::from(self.base > 1);
        if self.rounds.is_empty() {
            return base_pass.max(1);
        }
        base_pass + (self.rounds.len() + depth - 1) / depth
    }

    /// The pow2 round schedule, in execution order.
    pub fn rounds(&self) -> &[Pow2Round] {
        &self.rounds
    }

    /// Largest fusion depth that changes anything for this size: the
    /// pow2 round count (at least 1). Depths above this are clamped by
    /// the executor.
    pub fn max_fusion_depth(&self) -> usize {
        self.rounds.len().max(1)
    }

    /// Fused-tile working set at `depth` in elements: the block length
    /// of the last round in the largest *fused* (≥ 2 rounds) group —
    /// the contiguous span a fused traversal must keep cache-hot for
    /// the saved passes to be real. `0` when no group fuses (depth 1,
    /// or fewer than 2 rounds).
    pub fn fused_tile_elems(&self, depth: usize) -> usize {
        self.rounds
            .chunks(depth.max(1))
            .filter(|g| g.len() > 1)
            .map(|g| g[g.len() - 1].block_len())
            .max()
            .unwrap_or(0)
    }

    /// The cached §3.3 residual factor table (`I kron H_{2^m}`).
    pub fn residual_table(&self) -> &[f32; 256] {
        &self.bd
    }
}

/// In-place HadaCore FWHT driven by a precomputed [`HadaCorePlan`].
///
/// Bit-identical to [`fwht_hadacore_f32_cfg`] with the configuration the
/// plan was built from; equivalent to
/// [`fwht_hadacore_f32_planned_depth`] at depth 1.
///
/// Panics if `data.len()` is not a multiple of the plan's `n`.
pub fn fwht_hadacore_f32_planned(
    data: &mut [f32],
    plan: &HadaCorePlan,
    opts: &FwhtOptions,
) {
    fwht_hadacore_f32_planned_depth(data, plan, opts, 1);
}

/// [`fwht_hadacore_f32_planned`] with **round fusion**: consecutive
/// groups of `depth` pow2 rounds execute per cache-blocked tile (one
/// read + one write of the tile for the whole group) instead of one
/// full traversal of the buffer per round — the in-register chaining of
/// the paper's CUDA kernel mapped onto the CPU cache hierarchy. The
/// batch engine's hot path; `depth` is picked by [`crate::exec::tune`].
///
/// **Bit-for-bit identical to every other depth** (and to
/// [`fwht_hadacore_f32_cfg`]): each pass operates independently on
/// contiguous aligned blocks and each block divides the next pass's
/// block ([`Pow2Round`]), so fusion only reorders work across disjoint
/// tiles — the per-element f32 operation sequence never changes.
/// Depths are clamped to `[1, plan.max_fusion_depth()]`.
///
/// Panics if `data.len()` is not a multiple of the plan's `n`.
pub fn fwht_hadacore_f32_planned_depth(
    data: &mut [f32],
    plan: &HadaCorePlan,
    opts: &FwhtOptions,
    depth: usize,
) {
    let n = plan.n;
    validate_dims(data.len(), n).expect("invalid dimensions");
    if plan.base > 1 {
        let hb = hadamard_base(plan.base);
        for row in data.chunks_exact_mut(n) {
            left_mul_base_strided(row, plan.base, plan.pow2, hb);
        }
    }
    let depth = depth.clamp(1, plan.rounds.len().max(1));
    for group in plan.rounds.chunks(depth) {
        // the whole buffer is a multiple of every round's block length
        // (blocks nest and divide the pow2 row), so tiling by the last
        // round's block is exact
        let tile = group[group.len() - 1].block_len();
        for tile_buf in data.chunks_exact_mut(tile) {
            for round in group {
                apply_pow2_round(tile_buf, *round);
            }
        }
    }
    apply_scale(data, opts.scale);
}

/// Execute one [`Pow2Round`] over a buffer that is a whole multiple of
/// the round's block length (a fused tile or the full batch).
#[inline]
fn apply_pow2_round(buf: &mut [f32], round: Pow2Round) {
    match round {
        Pow2Round::Contiguous { chunk } => {
            if chunk == 16 {
                right_mul_h16_fast(buf);
            } else {
                right_mul_fused_chunk_fast(buf, chunk);
            }
        }
        Pow2Round::Strided { inner } => {
            for block in buf.chunks_exact_mut(16 * inner) {
                left_mul_h16_strided_fast(block, inner);
            }
        }
        Pow2Round::Small { size, inner } => {
            for block in buf.chunks_exact_mut(size * inner) {
                left_mul_small_strided_fast(block, size, inner);
            }
        }
    }
}

/// One 16-round on the axis with inner stride `inner` (> 1): for every row
/// and every outer block, left-multiply the `(16, inner)` view by `H16`.
#[inline]
fn strided_round(data: &mut [f32], rows: usize, n: usize, inner: usize) {
    debug_assert!(inner >= 1);
    if inner == 1 {
        right_mul_h16_fast(data);
        return;
    }
    let block = 16 * inner;
    let blocks_per_row = n / block;
    for row_i in 0..rows {
        let row = &mut data[row_i * n..(row_i + 1) * n];
        for b in 0..blocks_per_row {
            left_mul_h16_strided_fast(&mut row[b * block..(b + 1) * block], inner);
        }
    }
}

#[inline]
fn apply_scale(data: &mut [f32], scale: f32) {
    if scale != 1.0 {
        for v in data.iter_mut() {
            *v *= scale;
        }
    }
}

/// FLOP count of the HadaCore algorithm for an `(rows, n)` transform —
/// `16 * rows * n * ceil(log16 2^k)` for the matrix-unit rounds (paper
/// §3.4), plus `2 * rows * n * B` for the leading base-matrix stage when
/// `n = B * 2^k` with `B > 1` (B MACs per element). Used by the GPU
/// model and the roofline report.
pub fn hadacore_flops(rows: usize, n: usize) -> u64 {
    let (base, pow2) = split_base(n).expect("unsupported Hadamard size");
    let (m, r) = if pow2 > 1 { factor_16(pow2) } else { (0, 0) };
    let rounds = (r + u32::from(m > 0)) as u64;
    // each round: (rows*n/16) 16x16x16-vector products = rows*n*16 MACs
    let mma = 16 * rows as u64 * n as u64 * rounds;
    let base_stage = if base > 1 {
        2 * rows as u64 * n as u64 * base as u64
    } else {
        0
    };
    mma + base_stage
}

/// FLOP count of the butterfly algorithm — `2 * rows * n * log2 2^k`,
/// plus the same `2 * rows * n * B` base-stage term as
/// [`hadacore_flops`] for non-power-of-two sizes (the butterfly needs
/// the dense base contraction too).
pub fn butterfly_flops(rows: usize, n: usize) -> u64 {
    let (base, pow2) = split_base(n).expect("unsupported Hadamard size");
    let levels = pow2.trailing_zeros() as u64;
    let butterfly = 2 * rows as u64 * n as u64 * levels;
    let base_stage = if base > 1 {
        2 * rows as u64 * n as u64 * base as u64
    } else {
        0
    };
    butterfly + base_stage
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::scalar::fwht_scalar_f32;
    use crate::util::prop::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_all_sizes() {
        let mut rng = Rng::new(1);
        for k in 1..=15 {
            let n = 1usize << k;
            let rows = if n > 4096 { 2 } else { 5 };
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x.clone();
            fwht_hadacore_f32(&mut got, n, &FwhtOptions::normalized(n));
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            assert_close(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn matches_scalar_non_pow2_sizes() {
        let mut rng = Rng::new(7);
        // every base x several 2^k, including the Llama-3 FFN dim
        for n in [12usize, 20, 28, 40, 24, 48, 96, 160, 224, 320, 768, 5120, 14336] {
            let rows = if n > 4096 { 2 } else { 3 };
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x.clone();
            fwht_hadacore_f32(&mut got, n, &FwhtOptions::normalized(n));
            fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
            assert_close(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn planned_path_is_bit_identical_at_non_pow2_sizes() {
        let mut rng = Rng::new(8);
        for cfg in [
            HadaCoreConfig { residual: ResidualMode::BlockDiagonal },
            HadaCoreConfig { residual: ResidualMode::SmallFactor },
        ] {
            for n in [12usize, 24, 40, 48, 160, 768, 5120, 14336, 40960] {
                let rows = if n > 4096 { 2 } else { 3 };
                let x = rng.normal_vec(rows * n);
                let mut direct = x.clone();
                let mut planned = x;
                let opts = FwhtOptions::normalized(n);
                fwht_hadacore_f32_cfg(&mut direct, n, &opts, &cfg);
                let plan = HadaCorePlan::new(n, &cfg);
                fwht_hadacore_f32_planned(&mut planned, &plan, &opts);
                assert_eq!(direct, planned, "n={n} cfg={cfg:?}");
            }
        }
    }

    #[test]
    fn residual_modes_agree() {
        let mut rng = Rng::new(2);
        for n in [32usize, 128, 512, 2048, 8192] {
            let x = rng.normal_vec(3 * n);
            let mut a = x.clone();
            let mut b = x;
            fwht_hadacore_f32_cfg(
                &mut a,
                n,
                &FwhtOptions::raw(),
                &HadaCoreConfig { residual: ResidualMode::BlockDiagonal },
            );
            fwht_hadacore_f32_cfg(
                &mut b,
                n,
                &FwhtOptions::raw(),
                &HadaCoreConfig { residual: ResidualMode::SmallFactor },
            );
            assert_close(&a, &b, 1e-4, 1e-3);
        }
    }

    #[test]
    fn paper_grid_sizes_match_dao() {
        let mut rng = Rng::new(3);
        for n in [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768] {
            let x = rng.normal_vec(2 * n);
            let mut got = x.clone();
            let mut want = x;
            fwht_hadacore_f32(&mut got, n, &FwhtOptions::normalized(n));
            crate::hadamard::dao::fwht_dao_f32(
                &mut want,
                n,
                &FwhtOptions::normalized(n),
            );
            assert_close(&got, &want, 1e-3, 1e-3);
        }
    }

    #[test]
    fn property_matches_scalar() {
        check("hadacore vs scalar", 30, |rng| {
            let n = 1usize << rng.range(1, 13);
            let rows = rng.range(1, 4);
            let x = rng.normal_vec(rows * n);
            let mut got = x.clone();
            let mut want = x;
            fwht_hadacore_f32(&mut got, n, &FwhtOptions::raw());
            fwht_scalar_f32(&mut want, n, &FwhtOptions::raw());
            assert_close(&got, &want, 1e-3, 1e-2);
        });
    }

    #[test]
    fn property_involution_and_linearity() {
        check("hadacore involution", 15, |rng| {
            let n = 1usize << rng.range(4, 12);
            let x = rng.normal_vec(n);
            let mut y = x.clone();
            let opts = FwhtOptions::normalized(n);
            fwht_hadacore_f32(&mut y, n, &opts);
            fwht_hadacore_f32(&mut y, n, &opts);
            assert_close(&y, &x, 1e-4, 1e-4);
        });
        check("hadacore linearity", 15, |rng| {
            let n = 1usize << rng.range(4, 10);
            let alpha = (rng.f32() - 0.5) * 4.0;
            let x = rng.normal_vec(n);
            let z: Vec<f32> = x.iter().map(|v| v * alpha).collect();
            let mut tx = x.clone();
            let mut tz = z;
            let opts = FwhtOptions::raw();
            fwht_hadacore_f32(&mut tx, n, &opts);
            fwht_hadacore_f32(&mut tz, n, &opts);
            let scaled: Vec<f32> = tx.iter().map(|v| v * alpha).collect();
            assert_close(&tz, &scaled, 1e-3, 1e-2);
        });
    }

    #[test]
    fn flop_counts_match_paper_formulas() {
        // paper §3.4: hadacore >= 2x butterfly flops at power-of-16 sizes
        assert_eq!(butterfly_flops(1, 256), 2 * 256 * 8);
        assert_eq!(hadacore_flops(1, 256), 16 * 256 * 2);
        assert_eq!(hadacore_flops(1, 4096), 16 * 4096 * 3);
        // ceil(log16): 512 pays 3 rounds like 4096
        assert_eq!(hadacore_flops(1, 512), 16 * 512 * 3);
        // 8K pays 4 rounds, same as 32K (paper results note)
        assert_eq!(hadacore_flops(1, 8192), 16 * 8192 * 4);
        assert_eq!(hadacore_flops(1, 32768), 16 * 32768 * 4);
    }

    #[test]
    fn planned_path_is_bit_identical_to_cfg_path() {
        let mut rng = Rng::new(6);
        for cfg in [
            HadaCoreConfig { residual: ResidualMode::BlockDiagonal },
            HadaCoreConfig { residual: ResidualMode::SmallFactor },
        ] {
            for k in 1..=15 {
                let n = 1usize << k;
                let rows = if n > 4096 { 2 } else { 3 };
                let x = rng.normal_vec(rows * n);
                let mut direct = x.clone();
                let mut planned = x;
                let opts = FwhtOptions::normalized(n);
                fwht_hadacore_f32_cfg(&mut direct, n, &opts, &cfg);
                let plan = HadaCorePlan::new(n, &cfg);
                fwht_hadacore_f32_planned(&mut planned, &plan, &opts);
                // same pass structure => bit-identical, not merely close
                assert_eq!(direct, planned, "n={n} cfg={cfg:?}");
            }
        }
    }

    #[test]
    fn plan_structure_matches_factorisation() {
        let cfg = HadaCoreConfig::default();
        // 256 = 16^2: two plain 16-rounds, identity residual
        let p256 = HadaCorePlan::new(256, &cfg);
        assert_eq!(p256.n(), 256);
        assert_eq!(p256.passes(), 2);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert_eq!(p256.residual_table()[i * 16 + j], want);
            }
        }
        // 512 = 2 * 16^2: fused BD round-0 + one strided round
        let p512 = HadaCorePlan::new(512, &cfg);
        assert_eq!(p512.passes(), 2);
        assert_eq!(
            p512.residual_table()[..32].iter().filter(|&&v| v != 0.0).count(),
            4, // H_2 tile occupies a 2x2 block per 16-row band
        );
        // small-factor mode pays an explicit residual pass instead
        let ps = HadaCorePlan::new(
            512,
            &HadaCoreConfig { residual: ResidualMode::SmallFactor },
        );
        assert_eq!(ps.passes(), 3);

        // 14336 = 28 * 512: leading base pass + the 512 schedule
        let p = HadaCorePlan::new(14336, &cfg);
        assert_eq!(p.base(), 28);
        assert_eq!(p.passes(), 3);
        // 40960 = 40 * 1024 canonicalises to 20 * 2048
        let p = HadaCorePlan::new(40960, &cfg);
        assert_eq!(p.base(), 20);
        // 2048 = 8 * 16^2: base pass + fused round 0 + one strided round
        assert_eq!(p.passes(), 3);
        // base-only size: one pass
        assert_eq!(HadaCorePlan::new(12, &cfg).passes(), 1);
        // base + small pow2 (24 = 12 * 2): base pass + small round
        assert_eq!(HadaCorePlan::new(24, &cfg).passes(), 2);
    }

    #[test]
    fn fused_depths_are_bit_identical_to_depth_1() {
        // the tentpole invariant: round fusion is a traversal reordering,
        // never an arithmetic reassociation — every depth must reproduce
        // the unfused output bit for bit, at every size family member
        let mut rng = Rng::new(0xF0);
        for cfg in [
            HadaCoreConfig { residual: ResidualMode::BlockDiagonal },
            HadaCoreConfig { residual: ResidualMode::SmallFactor },
        ] {
            for n in [
                16usize, 32, 256, 512, 2048, 4096, 8192, 32768, 24, 768, 5120,
                14336, 40960,
            ] {
                let rows = if n > 4096 { 2 } else { 3 };
                let x = rng.normal_vec(rows * n);
                let opts = FwhtOptions::normalized(n);
                let plan = HadaCorePlan::new(n, &cfg);
                let mut reference = x.clone();
                fwht_hadacore_f32_cfg(&mut reference, n, &opts, &cfg);
                for depth in 1..=plan.max_fusion_depth() + 1 {
                    let mut fused = x.clone();
                    fwht_hadacore_f32_planned_depth(&mut fused, &plan, &opts, depth);
                    assert_eq!(
                        reference, fused,
                        "n={n} depth={depth} cfg={cfg:?}: fusion drifted"
                    );
                }
            }
        }
    }

    #[test]
    fn round_schedule_blocks_nest_and_tile_model_is_sane() {
        let cfg = HadaCoreConfig::default();
        for n in [256usize, 512, 4096, 8192, 32768, 768, 14336, 40960] {
            let plan = HadaCorePlan::new(n, &cfg);
            let rounds = plan.rounds();
            assert!(!rounds.is_empty());
            for w in rounds.windows(2) {
                assert_eq!(
                    w[1].block_len() % w[0].block_len(),
                    0,
                    "n={n}: blocks must nest"
                );
            }
            // depth 1 fuses nothing; max depth fuses everything into one
            // traversal whose tile is the last round's block
            assert_eq!(plan.fused_tile_elems(1), 0, "n={n}");
            if rounds.len() > 1 {
                assert_eq!(
                    plan.fused_tile_elems(plan.max_fusion_depth()),
                    rounds[rounds.len() - 1].block_len(),
                    "n={n}"
                );
            }
            // pass count shrinks with depth exactly as ceil(rounds/depth)
            let base_pass = usize::from(plan.base() > 1);
            for d in 1..=rounds.len() {
                assert_eq!(
                    plan.passes_at(d),
                    base_pass + (rounds.len() + d - 1) / d,
                    "n={n} d={d}"
                );
            }
        }
        // 8192 = 2^13 = 2 * 16^3 (BD): fused round 0 + two strided rounds
        let p = HadaCorePlan::new(8192, &cfg);
        assert_eq!(p.rounds().len(), 3);
        assert_eq!(p.passes_at(3), 1);
        assert_eq!(p.max_fusion_depth(), 3);
        // depth 2 groups [round0, strided(inner=32)] + [strided(inner=512)]:
        // the only fused group's tile is the inner=32 round's block, 16*32
        assert_eq!(p.fused_tile_elems(2), 512);
        // depth 3 fuses all three rounds; the tile is the whole pow2 row
        assert_eq!(p.fused_tile_elems(3), 8192);
    }

    #[test]
    fn flop_formulas_cover_the_base_stage() {
        // 768 = 12 * 64: two mma rounds on the 64-part + the base stage
        assert_eq!(hadacore_flops(1, 768), 16 * 768 * 2 + 2 * 768 * 12);
        assert_eq!(butterfly_flops(1, 768), 2 * 768 * 6 + 2 * 768 * 12);
    }

    #[test]
    fn impulse_gives_hadamard_row() {
        // transform of e_k is the k-th row of H_n
        let n = 64;
        for k in [0usize, 1, 37] {
            let mut x = vec![0.0f32; n];
            x[k] = 1.0;
            fwht_hadacore_f32(&mut x, n, &FwhtOptions::raw());
            for j in 0..n {
                assert_eq!(
                    x[j],
                    crate::hadamard::matrices::hadamard_entry(k, j),
                    "row {k} col {j}"
                );
            }
        }
    }
}
