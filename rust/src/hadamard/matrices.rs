//! Walsh-Hadamard matrix construction and factorisation helpers.
//!
//! Two constructions cover the full supported size family `n = B * 2^k`
//! (the math derivation lives in `docs/KERNEL_MATH.md`):
//!
//! * **Sylvester** (powers of two), in natural (Hadamard) ordering:
//!   `H[i][j] = (-1)^popcount(i & j)` — the closed form of the recursive
//!   construction `H_{2n} = [[H_n, H_n], [H_n, -H_n]]`. `H16` is the
//!   constant factor every HadaCore round multiplies by (the CUDA kernel
//!   keeps it in registers; here it is a compile-time table).
//! * **Paley II** (the non-power-of-two bases `H12`/`H20`/`H28`, the
//!   same base orders the `fast-hadamard-transform` library ships): a
//!   symmetric conference matrix over `GF(q)`, `q ∈ {5, 9, 13}`,
//!   expanded by 2x2 blocks into a **symmetric** Hadamard matrix of
//!   order `2(q+1)`; `H40` is the Sylvester doubling `H20 ⊗ H2`.
//!   Symmetry matters: the crate-wide convention `x <- x @ H_n` relies
//!   on left and right transforms coinciding, and the normalized
//!   transform being an involution (`H·H = n·I`) needs `H = Hᵀ`. Every
//!   base table is orthogonality- and symmetry-verified when it is
//!   built.
//!
//! The full transform matrix for `n = B * 2^k` is the Kronecker product
//! `H_n = H_B ⊗ H_{2^k}` with the base axis slow (index `i = b*2^k + t`),
//! so a row factors into `B` contiguous `2^k`-blocks.

/// True iff `n` is a positive power of two.
pub fn is_pow2(n: usize) -> bool {
    n > 0 && (n & (n - 1)) == 0
}

/// Base orders accepted by the `B * 2^k` size family, including the
/// redundant `40` (see [`split_base`] for why it canonicalises away).
pub const SUPPORTED_BASES: [usize; 5] = [1, 12, 20, 28, 40];

/// True iff `n` is in the supported transform-size family `B * 2^k`,
/// `B ∈ {1, 12, 20, 28, 40}` (equivalently: [`split_base`] succeeds).
pub fn is_supported_size(n: usize) -> bool {
    split_base(n).is_some()
}

/// Canonical factorisation `n = B * 2^k`: returns `(B, 2^k)` with
/// `B ∈ {1, 12, 20, 28}`, or `None` when `n` is outside the family.
///
/// The base is determined by the odd part of `n` (3 → 12, 5 → 20,
/// 7 → 28), which must come with at least two factors of two — Hadamard
/// matrices only exist at orders 1, 2, and multiples of 4. Base-40 sizes
/// are in the family but canonicalise to base 20: `40 * 2^k = 20 *
/// 2^(k+1)`, and the base-20 split costs fewer base-stage flops
/// (`B^2 * (n/B)` = `20n` vs `40n` MACs per row).
///
/// # Examples
///
/// ```
/// use hadacore::hadamard::matrices::split_base;
///
/// assert_eq!(split_base(1024), Some((1, 1024)));   // plain power of two
/// assert_eq!(split_base(768), Some((12, 64)));     // 12 * 2^6
/// assert_eq!(split_base(14336), Some((28, 512)));  // Llama-3 8B FFN dim
/// assert_eq!(split_base(40960), Some((20, 2048))); // 40*2^10 = 20*2^11
/// assert_eq!(split_base(10), None);                // no Hadamard order 10
/// assert_eq!(split_base(48), Some((12, 4)));
/// ```
pub fn split_base(n: usize) -> Option<(usize, usize)> {
    if n == 0 {
        return None;
    }
    let tz = n.trailing_zeros();
    match (n >> tz, tz) {
        (1, _) => Some((1, n)),
        (3, 2..) => Some((12, n / 12)),
        (5, 2..) => Some((20, n / 20)),
        (7, 2..) => Some((28, n / 28)),
        _ => None,
    }
}

/// Factor `n = 2^m * 16^r` with `0 <= m < 4` (paper §3.3).
///
/// Panics if `n` is not a power of two.
pub fn factor_16(n: usize) -> (u32, u32) {
    assert!(is_pow2(n), "Hadamard size must be a power of 2, got {n}");
    let k = n.trailing_zeros();
    (k % 4, k / 4)
}

/// Entry of the Walsh-Hadamard matrix in natural order: ±1.
#[inline]
pub fn hadamard_entry(i: usize, j: usize) -> f32 {
    if ((i & j).count_ones() & 1) == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Dense unnormalised `n x n` Walsh-Hadamard matrix (row-major).
pub fn hadamard_dense(n: usize) -> Vec<f32> {
    assert!(is_pow2(n), "Hadamard size must be a power of 2, got {n}");
    let mut h = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            h[i * n + j] = hadamard_entry(i, j);
        }
    }
    h
}

/// The 16x16 Hadamard factor as a flat row-major table.
///
/// Built at first use; entries are exactly ±1.0 so no numerical concerns.
pub static H16: crate::util::lazy::Lazy<[f32; 256]> = crate::util::lazy::Lazy::new(|| {
    let mut h = [0.0f32; 256];
    for i in 0..16 {
        for j in 0..16 {
            h[i * 16 + j] = hadamard_entry(i, j);
        }
    }
    h
});

/// Paper §3.3 block-diagonal residual factor: `I_{16/2^m} (kron) H_{2^m}`
/// as a 16x16 row-major table. `m == 0` gives the identity.
pub fn block_diagonal(m: u32) -> [f32; 256] {
    assert!(m < 4, "block-diagonal exponent must be < 4, got {m}");
    let sub = 1usize << m;
    let mut bd = [0.0f32; 256];
    for i in 0..16 {
        for j in 0..16 {
            if i / sub == j / sub {
                bd[i * 16 + j] = hadamard_entry(i % sub, j % sub);
            }
        }
    }
    bd
}

// ---------------------------------------------------------------------
// Non-power-of-two bases: Paley construction II.
//
// For q ≡ 1 (mod 4) a prime power, the Jacobsthal matrix Q over GF(q)
// (Q[i][j] = χ(e_i − e_j), χ the quadratic character) is symmetric with
// zero diagonal, zero row sums, and QQᵀ = qI − J. Bordering it with a
// row/column of ones gives a symmetric conference matrix C of order
// q + 1 (CCᵀ = qI, zero diagonal), and substituting 2x2 blocks
// (H = C ⊗ [[1,1],[1,−1]] + I ⊗ [[1,−1],[−1,−1]]) yields a *symmetric*
// Hadamard matrix of order 2(q+1): the cross terms cancel because C is
// symmetric, leaving HHᵀ = qI⊗2I + I⊗2I = 2(q+1)·I.
//
// q = 5, 9, 13 produce H12, H20, H28. GF(9) is realised as
// GF(3)[t]/(t² + 1) (t² + 1 has no roots mod 3, hence irreducible); its
// elements are encoded as the index a + 3b for a + b·t. Order 40 would
// need q = 19 ≡ 3 (mod 4) — outside Paley II's reach (its Jacobsthal
// matrix is skew there, breaking symmetry) — so H40 is the Sylvester
// doubling H20 ⊗ H2 instead, which stays symmetric and makes the
// base-40 canonicalisation exact: H40 ⊗ H_{2^k} = H20 ⊗ H_{2^(k+1)}.

/// Subtraction in GF(q) for q ∈ {5, 9, 13} under the index encoding
/// above (prime q: the index is the value itself).
fn gf_sub(q: usize, a: usize, b: usize) -> usize {
    if q == 9 {
        let (a0, a1) = (a % 3, a / 3);
        let (b0, b1) = (b % 3, b / 3);
        (a0 + 3 - b0) % 3 + 3 * ((a1 + 3 - b1) % 3)
    } else {
        (a + q - b) % q
    }
}

/// Multiplication in GF(q) for q ∈ {5, 9, 13}.
fn gf_mul(q: usize, a: usize, b: usize) -> usize {
    if q == 9 {
        let (a0, a1) = (a % 3, a / 3);
        let (b0, b1) = (b % 3, b / 3);
        // (a0 + a1 t)(b0 + b1 t) with t² = −1 ≡ 2 (mod 3)
        (a0 * b0 + 2 * a1 * b1) % 3 + 3 * ((a0 * b1 + a1 * b0) % 3)
    } else {
        (a * b) % q
    }
}

/// Build-time verification shared by every base-table constructor:
/// entries ±1, symmetry, and row orthogonality (`H·Hᵀ = n·I`). The
/// checks are exact — every dot product is a small integer sum.
fn verify_symmetric_hadamard(h: &[f32], n: usize) {
    assert_eq!(h.len(), n * n);
    for i in 0..n {
        for j in 0..n {
            let v = h[i * n + j];
            assert!(v == 1.0 || v == -1.0, "H_{n}[{i}][{j}] = {v} not ±1");
            assert_eq!(v, h[j * n + i], "H_{n} must be symmetric at ({i},{j})");
            let dot: f32 = (0..n).map(|k| h[i * n + k] * h[j * n + k]).sum();
            let want = if i == j { n as f32 } else { 0.0 };
            assert_eq!(dot, want, "H_{n} rows {i},{j} not orthogonal");
        }
    }
}

/// Symmetric Hadamard matrix of order `2(q+1)` via Paley construction
/// II, with [`verify_symmetric_hadamard`] run before the table is
/// released.
fn paley2_hadamard(q: usize) -> Vec<f32> {
    // the construction is only symmetric for q ≡ 1 (mod 4): that is
    // what makes χ(−1) = +1 and the Jacobsthal matrix symmetric
    assert_eq!(q % 4, 1, "Paley II needs q ≡ 1 (mod 4), got {q}");
    // quadratic character: χ(0) = 0, χ(square) = +1, else −1
    let squares: std::collections::HashSet<usize> =
        (1..q).map(|x| gf_mul(q, x, x)).collect();
    let chi = |z: usize| -> i32 {
        if z == 0 {
            0
        } else if squares.contains(&z) {
            1
        } else {
            -1
        }
    };

    // symmetric conference matrix C of order q+1: ones border + Jacobsthal
    let n0 = q + 1;
    let mut c = vec![0i32; n0 * n0];
    for j in 1..n0 {
        c[j] = 1;
        c[j * n0] = 1;
    }
    for i in 0..q {
        for j in 0..q {
            c[(i + 1) * n0 + (j + 1)] = chi(gf_sub(q, i, j));
        }
    }

    // 2x2-block substitution: H = C ⊗ M + I ⊗ N
    const M: [i32; 4] = [1, 1, 1, -1];
    const N: [i32; 4] = [1, -1, -1, -1];
    let n = 2 * n0;
    let mut h = vec![0.0f32; n * n];
    for bi in 0..n0 {
        for bj in 0..n0 {
            let cij = c[bi * n0 + bj];
            for u in 0..2 {
                for v in 0..2 {
                    let diag = if bi == bj { N[u * 2 + v] } else { 0 };
                    h[(2 * bi + u) * n + (2 * bj + v)] =
                        (cij * M[u * 2 + v] + diag) as f32;
                }
            }
        }
    }

    verify_symmetric_hadamard(&h, n);
    h
}

/// The order-12 symmetric Hadamard base (Paley II over GF(5)).
pub static H12: crate::util::lazy::Lazy<Vec<f32>> =
    crate::util::lazy::Lazy::new(|| paley2_hadamard(5));

/// The order-20 symmetric Hadamard base (Paley II over GF(9)).
pub static H20: crate::util::lazy::Lazy<Vec<f32>> =
    crate::util::lazy::Lazy::new(|| paley2_hadamard(9));

/// The order-28 symmetric Hadamard base (Paley II over GF(13)).
pub static H28: crate::util::lazy::Lazy<Vec<f32>> =
    crate::util::lazy::Lazy::new(|| paley2_hadamard(13));

/// The order-40 symmetric Hadamard base: the Sylvester doubling
/// `H20 ⊗ H2` (Paley II cannot reach order 40 — it would need
/// `q = 19 ≡ 3 mod 4`), re-verified for orthogonality/symmetry on
/// build.
///
/// Provided as a construction, but the transform path never multiplies
/// by it: under this definition `H40 ⊗ H_{2^k} = H20 ⊗ H_{2^(k+1)}`
/// *exactly*, so `40 * 2^k` sizes canonicalise to the cheaper
/// `20 * 2^(k+1)` split — see [`split_base`].
pub static H40: crate::util::lazy::Lazy<Vec<f32>> = crate::util::lazy::Lazy::new(|| {
    // H40[2i+u][2j+v] = H20[i][j] * H2[u][v] (pow2 axis fast)
    let h20 = H20.force();
    let n = 40;
    let mut h = vec![0.0f32; n * n];
    for i in 0..20 {
        for j in 0..20 {
            let v = h20[i * 20 + j];
            h[(2 * i) * n + 2 * j] = v;
            h[(2 * i) * n + 2 * j + 1] = v;
            h[(2 * i + 1) * n + 2 * j] = v;
            h[(2 * i + 1) * n + 2 * j + 1] = -v;
        }
    }
    verify_symmetric_hadamard(&h, n);
    h
});

/// Dense `b x b` row-major table for base order `b ∈ {12, 20, 28, 40}`.
///
/// Panics on any other order (base 1 has no table — the pow2 factor is
/// handled by the Sylvester machinery).
///
/// # Examples
///
/// ```
/// use hadacore::hadamard::matrices::hadamard_base;
///
/// let h12 = hadamard_base(12);
/// // symmetric, ±1, orthogonal rows: H12 · H12ᵀ = 12·I
/// let dot: f32 = (0..12).map(|k| h12[k] * h12[12 + k]).sum();
/// assert_eq!(dot, 0.0);
/// let norm: f32 = (0..12).map(|k| h12[k] * h12[k]).sum();
/// assert_eq!(norm, 12.0);
/// ```
pub fn hadamard_base(b: usize) -> &'static [f32] {
    match b {
        12 => H12.force().as_slice(),
        20 => H20.force().as_slice(),
        28 => H28.force().as_slice(),
        40 => H40.force().as_slice(),
        _ => panic!("no Hadamard base matrix of order {b} (supported: 12, 20, 28, 40)"),
    }
}

/// Entry `H_n[i][j]` for any supported size `n = B * 2^k`: the Kronecker
/// factorisation `H_B[i/2^k][j/2^k] * H_{2^k}[i%2^k][j%2^k]` with the
/// base axis slow. Reduces to [`hadamard_entry`] for powers of two.
///
/// Panics when `n` is outside the family.
pub fn hadamard_entry_n(n: usize, i: usize, j: usize) -> f32 {
    let (base, m) = split_base(n)
        .unwrap_or_else(|| panic!("unsupported Hadamard size {n}"));
    if base == 1 {
        return hadamard_entry(i, j);
    }
    hadamard_base(base)[(i / m) * base + (j / m)] * hadamard_entry(i % m, j % m)
}

/// Dense reference `y = x @ H_n` for any supported size, computing
/// entries on the fly (no `n x n` materialisation — at `n = 14336` the
/// dense matrix would be 822 MB) and accumulating in f64 with one final
/// rounding. Test helper — O(n^2) per row.
pub fn matvec_hadamard_n(x: &[f32], n: usize, y: &mut [f32]) {
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), n);
    let (base, m) = split_base(n)
        .unwrap_or_else(|| panic!("unsupported Hadamard size {n}"));
    let hb = (base > 1).then(|| hadamard_base(base));
    for (j, out) in y.iter_mut().enumerate() {
        let (bj, tj) = (j / m, j % m);
        let mut acc = 0.0f64;
        // iterate block-wise so the O(n^2) hot loop carries no divisions
        for bk in 0..base {
            let w = match hb {
                Some(hb) => hb[bk * base + bj],
                None => 1.0,
            };
            for (tk, &xv) in x[bk * m..(bk + 1) * m].iter().enumerate() {
                // w and the entry are ±1: the product is an exact sign
                // flip, so f64 accumulation rounds exactly once
                acc += (w * xv * hadamard_entry(tk, tj)) as f64;
            }
        }
        *out = acc as f32;
    }
}

/// Multiply a dense row-vector by a dense matrix: `y = x @ M` (n x n).
/// Test helper — O(n^2), used only to validate kernels at small sizes.
pub fn matvec_right(x: &[f32], m: &[f32], n: usize, y: &mut [f32]) {
    assert_eq!(x.len(), n);
    assert_eq!(m.len(), n * n);
    assert_eq!(y.len(), n);
    for j in 0..n {
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += x[k] as f64 * m[k * n + j] as f64;
        }
        y[j] = acc as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_16_cases() {
        assert_eq!(factor_16(16), (0, 1));
        assert_eq!(factor_16(256), (0, 2));
        assert_eq!(factor_16(128), (3, 1));
        assert_eq!(factor_16(512), (1, 2));
        assert_eq!(factor_16(2048), (3, 2));
        assert_eq!(factor_16(4096), (0, 3));
        assert_eq!(factor_16(32768), (3, 3));
        assert_eq!(factor_16(2), (1, 0));
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn factor_16_rejects_non_pow2() {
        factor_16(48);
    }

    #[test]
    fn h16_matches_sylvester_recursion() {
        // H_16 from the closed form must satisfy the 2x2 block recursion.
        let h16 = &*H16;
        let h8 = hadamard_dense(8);
        for i in 0..8 {
            for j in 0..8 {
                let v = h8[i * 8 + j];
                assert_eq!(h16[i * 16 + j], v);
                assert_eq!(h16[i * 16 + (j + 8)], v);
                assert_eq!(h16[(i + 8) * 16 + j], v);
                assert_eq!(h16[(i + 8) * 16 + (j + 8)], -v);
            }
        }
    }

    #[test]
    fn dense_orthogonality() {
        for n in [2usize, 4, 16, 64] {
            let h = hadamard_dense(n);
            for i in 0..n {
                for j in 0..n {
                    let dot: f32 =
                        (0..n).map(|k| h[i * n + k] * h[j * n + k]).sum();
                    let want = if i == j { n as f32 } else { 0.0 };
                    assert_eq!(dot, want, "rows {i},{j} of H_{n}");
                }
            }
        }
    }

    #[test]
    fn dense_symmetric() {
        for n in [4usize, 32, 128] {
            let h = hadamard_dense(n);
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(h[i * n + j], h[j * n + i]);
                }
            }
        }
    }

    #[test]
    fn block_diagonal_structure() {
        for m in 0..4u32 {
            let bd = block_diagonal(m);
            let sub = 1usize << m;
            for i in 0..16 {
                for j in 0..16 {
                    let v = bd[i * 16 + j];
                    if i / sub == j / sub {
                        assert_eq!(v, hadamard_entry(i % sub, j % sub));
                    } else {
                        assert_eq!(v, 0.0);
                    }
                }
            }
        }
        // m=0 is the identity
        let id = block_diagonal(0);
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(id[i * 16 + j], if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn split_base_canonical_factorisations() {
        assert_eq!(split_base(1), Some((1, 1)));
        assert_eq!(split_base(2), Some((1, 2)));
        assert_eq!(split_base(256), Some((1, 256)));
        assert_eq!(split_base(12), Some((12, 1)));
        assert_eq!(split_base(20), Some((20, 1)));
        assert_eq!(split_base(28), Some((28, 1)));
        assert_eq!(split_base(40), Some((20, 2)), "40 = 20 * 2 canonically");
        assert_eq!(split_base(768), Some((12, 64)));
        assert_eq!(split_base(5120), Some((20, 256)));
        assert_eq!(split_base(14336), Some((28, 512)));
        assert_eq!(split_base(28672), Some((28, 1024)));
        assert_eq!(split_base(40960), Some((20, 2048)));
        // outside the family: odd parts other than {1,3,5,7}, or fewer
        // than two factors of two alongside an odd part
        for n in [0usize, 3, 5, 6, 7, 10, 14, 18, 36, 44, 63, 100] {
            assert_eq!(split_base(n), None, "n={n}");
        }
        assert!(is_supported_size(14336));
        assert!(!is_supported_size(11008)); // odd part 43: not a base
    }

    #[test]
    fn base_tables_build_and_self_verify() {
        // forcing each table runs verify_symmetric_hadamard inside the
        // Lazy initializer (the full ±1/symmetry/orthogonality loop
        // lives there and in the property suite — not duplicated here)
        for b in [12usize, 20, 28, 40] {
            assert_eq!(hadamard_base(b).len(), b * b);
        }
    }

    #[test]
    #[should_panic(expected = "no Hadamard base matrix")]
    fn hadamard_base_rejects_unknown_orders() {
        hadamard_base(16);
    }

    #[test]
    fn entry_n_matches_kronecker_structure() {
        // H_24 = H_12 ⊗ H_2, base axis slow
        let n = 24;
        let h12 = hadamard_base(12);
        for i in 0..n {
            for j in 0..n {
                let want = h12[(i / 2) * 12 + (j / 2)] * hadamard_entry(i % 2, j % 2);
                assert_eq!(hadamard_entry_n(n, i, j), want);
            }
        }
        // pow2 reduces to the Sylvester closed form
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(hadamard_entry_n(16, i, j), hadamard_entry(i, j));
            }
        }
    }

    #[test]
    fn matvec_hadamard_n_matches_dense_pow2() {
        let n = 32;
        let h = hadamard_dense(n);
        let mut rng = crate::util::rng::Rng::new(5);
        let x = rng.normal_vec(n);
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        matvec_hadamard_n(&x, n, &mut a);
        matvec_right(&x, &h, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_right_identity() {
        let n = 8;
        let mut id = vec![0.0f32; n * n];
        for i in 0..n {
            id[i * n + i] = 1.0;
        }
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut y = vec![0.0f32; n];
        matvec_right(&x, &id, n, &mut y);
        assert_eq!(x, y);
    }
}
