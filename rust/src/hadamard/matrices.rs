//! Walsh-Hadamard matrix construction and factorisation helpers.
//!
//! Sylvester/Walsh-Hadamard matrices in natural (Hadamard) ordering:
//! `H[i][j] = (-1)^popcount(i & j)` — the closed form of the recursive
//! construction `H_{2n} = [[H_n, H_n], [H_n, -H_n]]`. `H16` is the constant
//! factor every HadaCore round multiplies by (the CUDA kernel keeps it in
//! registers; here it is a compile-time table).

/// True iff `n` is a positive power of two.
pub fn is_pow2(n: usize) -> bool {
    n > 0 && (n & (n - 1)) == 0
}

/// Factor `n = 2^m * 16^r` with `0 <= m < 4` (paper §3.3).
///
/// Panics if `n` is not a power of two.
pub fn factor_16(n: usize) -> (u32, u32) {
    assert!(is_pow2(n), "Hadamard size must be a power of 2, got {n}");
    let k = n.trailing_zeros();
    (k % 4, k / 4)
}

/// Entry of the Walsh-Hadamard matrix in natural order: ±1.
#[inline]
pub fn hadamard_entry(i: usize, j: usize) -> f32 {
    if ((i & j).count_ones() & 1) == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Dense unnormalised `n x n` Walsh-Hadamard matrix (row-major).
pub fn hadamard_dense(n: usize) -> Vec<f32> {
    assert!(is_pow2(n), "Hadamard size must be a power of 2, got {n}");
    let mut h = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            h[i * n + j] = hadamard_entry(i, j);
        }
    }
    h
}

/// The 16x16 Hadamard factor as a flat row-major table.
///
/// Built at first use; entries are exactly ±1.0 so no numerical concerns.
pub static H16: crate::util::lazy::Lazy<[f32; 256]> = crate::util::lazy::Lazy::new(|| {
    let mut h = [0.0f32; 256];
    for i in 0..16 {
        for j in 0..16 {
            h[i * 16 + j] = hadamard_entry(i, j);
        }
    }
    h
});

/// Paper §3.3 block-diagonal residual factor: `I_{16/2^m} (kron) H_{2^m}`
/// as a 16x16 row-major table. `m == 0` gives the identity.
pub fn block_diagonal(m: u32) -> [f32; 256] {
    assert!(m < 4, "block-diagonal exponent must be < 4, got {m}");
    let sub = 1usize << m;
    let mut bd = [0.0f32; 256];
    for i in 0..16 {
        for j in 0..16 {
            if i / sub == j / sub {
                bd[i * 16 + j] = hadamard_entry(i % sub, j % sub);
            }
        }
    }
    bd
}

/// Multiply a dense row-vector by a dense matrix: `y = x @ M` (n x n).
/// Test helper — O(n^2), used only to validate kernels at small sizes.
pub fn matvec_right(x: &[f32], m: &[f32], n: usize, y: &mut [f32]) {
    assert_eq!(x.len(), n);
    assert_eq!(m.len(), n * n);
    assert_eq!(y.len(), n);
    for j in 0..n {
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += x[k] as f64 * m[k * n + j] as f64;
        }
        y[j] = acc as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_16_cases() {
        assert_eq!(factor_16(16), (0, 1));
        assert_eq!(factor_16(256), (0, 2));
        assert_eq!(factor_16(128), (3, 1));
        assert_eq!(factor_16(512), (1, 2));
        assert_eq!(factor_16(2048), (3, 2));
        assert_eq!(factor_16(4096), (0, 3));
        assert_eq!(factor_16(32768), (3, 3));
        assert_eq!(factor_16(2), (1, 0));
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn factor_16_rejects_non_pow2() {
        factor_16(48);
    }

    #[test]
    fn h16_matches_sylvester_recursion() {
        // H_16 from the closed form must satisfy the 2x2 block recursion.
        let h16 = &*H16;
        let h8 = hadamard_dense(8);
        for i in 0..8 {
            for j in 0..8 {
                let v = h8[i * 8 + j];
                assert_eq!(h16[i * 16 + j], v);
                assert_eq!(h16[i * 16 + (j + 8)], v);
                assert_eq!(h16[(i + 8) * 16 + j], v);
                assert_eq!(h16[(i + 8) * 16 + (j + 8)], -v);
            }
        }
    }

    #[test]
    fn dense_orthogonality() {
        for n in [2usize, 4, 16, 64] {
            let h = hadamard_dense(n);
            for i in 0..n {
                for j in 0..n {
                    let dot: f32 =
                        (0..n).map(|k| h[i * n + k] * h[j * n + k]).sum();
                    let want = if i == j { n as f32 } else { 0.0 };
                    assert_eq!(dot, want, "rows {i},{j} of H_{n}");
                }
            }
        }
    }

    #[test]
    fn dense_symmetric() {
        for n in [4usize, 32, 128] {
            let h = hadamard_dense(n);
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(h[i * n + j], h[j * n + i]);
                }
            }
        }
    }

    #[test]
    fn block_diagonal_structure() {
        for m in 0..4u32 {
            let bd = block_diagonal(m);
            let sub = 1usize << m;
            for i in 0..16 {
                for j in 0..16 {
                    let v = bd[i * 16 + j];
                    if i / sub == j / sub {
                        assert_eq!(v, hadamard_entry(i % sub, j % sub));
                    } else {
                        assert_eq!(v, 0.0);
                    }
                }
            }
        }
        // m=0 is the identity
        let id = block_diagonal(0);
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(id[i * 16 + j], if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn matvec_right_identity() {
        let n = 8;
        let mut id = vec![0.0f32; n * n];
        for i in 0..n {
            id[i * n + i] = 1.0;
        }
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut y = vec![0.0f32; n];
        matvec_right(&x, &id, n, &mut y);
        assert_eq!(x, y);
    }
}
