//! Roofline-guided autotuner for the execution engine.
//!
//! Picks, per batch shape:
//!
//! * **fusion depth** — how many consecutive pow2 rounds
//!   [`crate::hadamard::hadacore::fwht_hadacore_f32_planned_depth`]
//!   executes per cache-blocked tile (1 = the classic one-traversal-per-
//!   round schedule);
//! * **chunk rows** — the pool's sharding granularity for that shape.
//!
//! Each knob resolves independently, in order:
//!
//! 1. **Env pin** (reproducibility): `HADACORE_FUSION_DEPTH` pins the
//!    depth, `HADACORE_CHUNK_ROWS` pins the chunk — each pins *only its
//!    own knob*; the other keeps resolving normally. `HADACORE_TUNE=off`
//!    restores the pre-tuner behaviour (depth 1, static policy chunks)
//!    and `HADACORE_TUNE=model` skips the measurement (pure cost model —
//!    deterministic across runs on any host); unrecognised values are
//!    ignored. All env knobs are read once per process.
//! 2. **Config policy**: [`TunePolicy`] on [`super::ExecConfig`] —
//!    what the parity-grid tests use to force every depth.
//! 3. **Model seed**:
//!    [`crate::gpu_model::roofline::recommend_fusion_depth_for_lanes`]
//!    proposes a depth within the cache budget
//!    ([`FUSION_CACHE_BUDGET`]), weighted by the active SIMD backend's
//!    lane count — wide vector backends are memory-bound and fuse to
//!    the cache cap; the scalar fallback hits its compute floor first
//!    and seeds shallow.
//! 4. **One-shot micro-measurement** (default policy): the seed is
//!    checked against its neighbours and the no-fusion baseline on a
//!    small synthetic buffer — well under a millisecond, once per
//!    `(kernel, n)` per process (the sweep runs on the f32 compute
//!    image; 16-bit storage only rescales the cost estimate) — because
//!    the Markidis line of work says such tradeoffs must be measured,
//!    not assumed. The result is memoized next to the plan cache
//!    ([`super::plan::measurement_for`]); every later batch pays a hash
//!    lookup.
//!
//! Chunk rows start from the engine's balance policy for the *actual*
//! batch rows ([`policy_chunk_rows`], the same function
//! `ExecEngine::chunk_rows_for` delegates to) and are refined with the
//! measured per-element cost: chunks shrink toward finer load balance
//! as long as each chunk still carries ≳
//! [`CHUNK_OVERHEAD_AMORTISATION`] × the pool's per-claim overhead, and
//! never below the configured `min_chunk_elems` floor. The tuner
//! therefore only ever *adds* chunks relative to the static policy —
//! inline-dispatch decisions and sharding thresholds are unchanged, and
//! `Off` reproduces the pre-tuner sharding exactly. The measurement is
//! engine-independent physics; the chunk derivation re-runs per engine
//! config, so two engines with different lane counts never poison each
//! other's decisions.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::gpu_model::roofline::recommend_fusion_depth_for_lanes;
use crate::hadamard::hadacore::HadaCorePlan;
use crate::hadamard::{FwhtOptions, KernelKind};
use crate::util::f16::DType;
use crate::util::rng::Rng;

use super::plan::{measurement_for, plan_for, ExecPlan};
use super::ExecConfig;

/// How the engine picks fusion depth + chunk size (see the module doc
/// for the full pipeline; env vars override every variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunePolicy {
    /// Cost-model seed refined by a one-shot micro-measurement per key
    /// (the default).
    Measure,
    /// Cost-model seed only — deterministic on any host, no timing.
    Model,
    /// Fixed fusion depth (clamped to the plan's round count), chunk
    /// rows from the static balance policy. What the parity-grid tests
    /// use to force every depth.
    FixedDepth(usize),
    /// Fusion off (depth 1) and static policy chunks — the engine
    /// behaves exactly as before the tuner existed.
    Off,
}

/// Cache budget (bytes) a fused tile may occupy: a conservative
/// per-core L2 share on current x86/ARM serving hosts (the tile plus
/// its in-flight read/write halves must not thrash the cache the lanes
/// share).
pub const FUSION_CACHE_BUDGET: usize = 1 << 20;

/// Minimum work per chunk, expressed as multiples of the pool's
/// per-claim overhead ([`CLAIM_OVERHEAD_NS`]), that chunk refinement
/// must preserve: 50× keeps claim cost < 2% of chunk runtime.
pub const CHUNK_OVERHEAD_AMORTISATION: f64 = 50.0;

/// Estimated cost of one chunk claim (queue lock + condvar wake +
/// latch decrement), nanoseconds. Deliberately pessimistic; it only
/// bounds how *fine* the refined sharding may get.
pub const CLAIM_OVERHEAD_NS: f64 = 2_000.0;

/// Elements the micro-measurement buffer holds (256 KiB of f32): big
/// enough to stream through L2 like a real chunk, small enough that a
/// full candidate sweep stays under ~1 ms per key.
const MEASURE_BUDGET_ELEMS: usize = 1 << 16;

/// Timed repetitions per candidate depth; the minimum is kept (the
/// usual microbench rule: minimum-of-k rejects scheduler noise).
const MEASURE_REPS: usize = 3;

/// One memoized micro-measurement: the fastest depth for a
/// `(kernel, n)` and the f32 per-element cost at that depth (feeds the
/// chunk refinement; 16-bit storage rescales it at resolve time).
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Fastest fusion depth observed (1 for the butterfly kernels).
    pub fusion_depth: usize,
    /// Nanoseconds per f32 element at that depth, on this host.
    pub ns_per_elem: f64,
}

/// A resolved tuning decision for one batch shape.
#[derive(Clone, Copy, Debug)]
pub struct Tuning {
    /// Fusion depth handed to the planned HadaCore path (1 for the
    /// butterfly kernels — they have no round schedule to fuse).
    pub fusion_depth: usize,
    /// Rows per pool chunk for this batch shape.
    pub chunk_rows: usize,
    /// True when `chunk_rows` was pinned by `HADACORE_CHUNK_ROWS`: the
    /// engine must then use it verbatim instead of re-clamping against
    /// its static policy.
    pub chunk_pinned: bool,
    /// Where the decision came from (observability / tests).
    pub source: TuneSource,
}

/// Provenance of a [`Tuning`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneSource {
    /// `HADACORE_FUSION_DEPTH` / `HADACORE_CHUNK_ROWS` / `HADACORE_TUNE`.
    Env,
    /// [`TunePolicy::FixedDepth`] or [`TunePolicy::Off`].
    Config,
    /// Cost-model seed, no measurement.
    Model,
    /// Model seed refined by the one-shot micro-measurement.
    Measured,
}

impl TuneSource {
    /// Every variant, in discriminant order (indexes [`decision_count`]).
    pub const ALL: [TuneSource; 4] =
        [TuneSource::Env, TuneSource::Config, TuneSource::Model, TuneSource::Measured];

    /// Stable lowercase label (the `source` label of
    /// `hadacore_tune_decisions_total`).
    pub fn name(self) -> &'static str {
        match self {
            TuneSource::Env => "env",
            TuneSource::Config => "config",
            TuneSource::Model => "model",
            TuneSource::Measured => "measured",
        }
    }
}

/// Per-provenance decision counts (indexed by `TuneSource`
/// discriminant). Process-wide and monotone; sampled at render time by
/// the registry's computed `hadacore_tune_decisions_total` series.
static DECISIONS: [AtomicU64; 4] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// How many resolved tuning decisions carried this provenance so far in
/// this process.
pub fn decision_count(source: TuneSource) -> u64 {
    DECISIONS[source as usize].load(Ordering::Relaxed)
}

/// Resolve the tuning decision for one batch shape under an engine's
/// config. Convenience wrapper over [`tuning_for_plan`] that fetches
/// the cached plan; the engine's dispatch path passes the plan it
/// already holds instead.
pub fn tuning_for(
    cfg: &ExecConfig,
    kind: KernelKind,
    n: usize,
    rows: usize,
    dtype: DType,
) -> Tuning {
    tuning_for_plan(cfg, &plan_for(kind, n), rows, dtype)
}

/// [`tuning_for`] over an already-fetched [`ExecPlan`] — no plan-cache
/// lock on the per-batch path. Cheap after first use: the only
/// expensive input (the micro-measurement) is memoized per
/// `(kernel, n)` in [`super::plan`].
pub fn tuning_for_plan(
    cfg: &ExecConfig,
    plan: &ExecPlan,
    rows: usize,
    dtype: DType,
) -> Tuning {
    let (kind, n) = (plan.kind, plan.n);
    let max_depth = plan
        .hadacore
        .as_ref()
        .map(HadaCorePlan::max_fusion_depth)
        .unwrap_or(1);
    let policy_chunk = policy_chunk_rows(cfg, rows, n);

    // env knobs (each pins only its own knob; read once per process —
    // this fn sits on the per-batch dispatch path, and a reproducible
    // run wants the pinned values frozen at startup anyway)
    let env = env_overrides();
    let policy = match env.mode.as_deref() {
        Some("off") => TunePolicy::Off,
        Some("model") => TunePolicy::Model,
        // unrecognised values (and no value) leave the config policy
        Some(_) | None => cfg.tune,
    };

    // model seed (from the cached plan — no construction per batch).
    // Lane-aware: the SIMD backend moved the compute roofline, so the
    // model only recommends fusing while memory time still exceeds the
    // backend's compute floor (scalar fallback → depth 1 seed).
    let lanes = crate::hadamard::simd::active().lanes();
    let seed_depth = plan
        .hadacore
        .as_ref()
        .map(|hp| recommend_fusion_depth_for_lanes(hp, FUSION_CACHE_BUDGET, lanes))
        .unwrap_or(1)
        .min(max_depth);

    // the measurement, taken lazily: only when some unpinned knob needs
    // it (memoized per (kernel, n), f32 basis)
    let need_measurement = policy == TunePolicy::Measure
        && (env.depth.is_none() || env.chunk.is_none());
    let measured = need_measurement.then(|| measurement_for(kind, n, seed_depth));

    // fusion depth: env pin > policy
    let (fusion_depth, depth_source) = match (env.depth, policy) {
        (Some(d), _) => (d.clamp(1, max_depth), TuneSource::Env),
        (None, TunePolicy::Off) => (1, TuneSource::Config),
        (None, TunePolicy::FixedDepth(d)) => {
            (d.clamp(1, max_depth), TuneSource::Config)
        }
        (None, TunePolicy::Model) => (seed_depth, TuneSource::Model),
        (None, TunePolicy::Measure) => (
            measured.map(|m| m.fusion_depth).unwrap_or(seed_depth),
            TuneSource::Measured,
        ),
    };

    // chunk rows: env pin > policy-dependent refinement of the static
    // balance policy (computed on the actual batch rows, so `Off`
    // reproduces the pre-tuner sharding exactly)
    let dtype_factor = match dtype {
        // 16-bit storage adds the widen/narrow staging on top of the
        // measured f32 compute
        DType::F32 => 1.0,
        DType::F16 | DType::BF16 => 1.5,
    };
    let (chunk_rows, chunk_pinned) = match env.chunk {
        Some(c) => (c.max(1), true),
        None => (
            match (policy, measured) {
                (TunePolicy::Off | TunePolicy::FixedDepth(_), _) => policy_chunk,
                (TunePolicy::Model, _) => {
                    // no measurement: a memory-bound streaming guess
                    // (~0.5 ns per element per traversal)
                    let passes = plan
                        .hadacore
                        .as_ref()
                        .map(|hp| hp.passes_at(fusion_depth))
                        .unwrap_or(1);
                    refine_chunk_rows(cfg, rows, n, 0.5 * passes as f64 * dtype_factor)
                }
                (TunePolicy::Measure, Some(m)) => {
                    refine_chunk_rows(cfg, rows, n, m.ns_per_elem * dtype_factor)
                }
                // unreachable in practice (Measure computes `measured`
                // unless both knobs are pinned) — fall back to policy
                (TunePolicy::Measure, None) => policy_chunk,
            },
            false,
        ),
    };

    let source = if env.chunk.is_some() { TuneSource::Env } else { depth_source };
    DECISIONS[source as usize].fetch_add(1, Ordering::Relaxed);
    Tuning { fusion_depth, chunk_rows, chunk_pinned, source }
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|s| s.trim().parse().ok())
}

/// The env knobs, frozen at first use (see the call site).
struct EnvOverrides {
    depth: Option<usize>,
    chunk: Option<usize>,
    mode: Option<String>,
}

fn env_overrides() -> &'static EnvOverrides {
    static ENV: crate::util::lazy::Lazy<EnvOverrides> =
        crate::util::lazy::Lazy::new(|| EnvOverrides {
            depth: env_usize("HADACORE_FUSION_DEPTH"),
            chunk: env_usize("HADACORE_CHUNK_ROWS"),
            mode: std::env::var("HADACORE_TUNE").ok(),
        });
    ENV.force()
}

/// The engine's static balance policy — the single source of truth,
/// also used by `ExecEngine::chunk_rows_for`: enough chunks to balance
/// the lanes, never below the `min_chunk_elems` floor.
pub(crate) fn policy_chunk_rows(cfg: &ExecConfig, rows: usize, n: usize) -> usize {
    let target_chunks = (cfg.threads * cfg.chunks_per_thread.max(1)).max(1);
    let by_balance = (rows + target_chunks - 1) / target_chunks;
    let min_rows = (cfg.min_chunk_elems + n - 1) / n;
    by_balance.max(min_rows).max(1)
}

/// Refine the chunk height with a measured (or modelled) per-element
/// cost: shrink chunks toward finer balance while each chunk still
/// amortises its claim overhead, clamped to
/// `[min_chunk_elems floor, static policy]` so the tuner never shards
/// *coarser* than the configured policy nor finer than the floor.
fn refine_chunk_rows(
    cfg: &ExecConfig,
    rows: usize,
    n: usize,
    ns_per_elem: f64,
) -> usize {
    let policy = policy_chunk_rows(cfg, rows, n);
    let floor = ((cfg.min_chunk_elems + n - 1) / n).max(1);
    let amortised_elems =
        CHUNK_OVERHEAD_AMORTISATION * CLAIM_OVERHEAD_NS / ns_per_elem.max(1e-3);
    let amortised_rows = (amortised_elems / n as f64).ceil().max(1.0) as usize;
    amortised_rows.clamp(floor, policy)
}

/// Run the micro-measurement for one `(kernel, n)`: time the planned
/// kernel at the candidate depths (model seed ±1 plus the no-fusion
/// baseline) on a deterministic synthetic f32 buffer and keep the
/// fastest. Called by [`super::plan::measurement_for`] on a memo miss —
/// at most once per key per process (modulo a benign compute-twice race
/// on concurrent first use).
pub(crate) fn measure_profile(
    kind: KernelKind,
    n: usize,
    plan: &ExecPlan,
    seed_depth: usize,
) -> Measurement {
    let max_depth = plan
        .hadacore
        .as_ref()
        .map(HadaCorePlan::max_fusion_depth)
        .unwrap_or(1);
    let rows = (MEASURE_BUDGET_ELEMS / n).max(1);
    let elems = rows * n;
    let mut rng = Rng::new(0x7E57_0000 ^ n as u64);
    let base = rng.normal_vec(elems);
    let opts = FwhtOptions::normalized(n);
    let mut buf = vec![0.0f32; elems];

    let mut candidates = vec![1usize];
    for d in [seed_depth.saturating_sub(1), seed_depth, seed_depth + 1] {
        if (1..=max_depth).contains(&d) && !candidates.contains(&d) {
            candidates.push(d);
        }
    }

    let mut best = (1usize, f64::INFINITY);
    for &depth in &candidates {
        let mut min_ns = f64::INFINITY;
        for _ in 0..MEASURE_REPS {
            buf.copy_from_slice(&base);
            let t0 = Instant::now();
            run_measured(kind, &mut buf, n, &opts, plan, depth);
            min_ns = min_ns.min(t0.elapsed().as_nanos() as f64);
        }
        if min_ns < best.1 {
            best = (depth, min_ns);
        }
    }
    Measurement {
        fusion_depth: best.0,
        ns_per_elem: best.1 / elems as f64,
    }
}

fn run_measured(
    kind: KernelKind,
    buf: &mut [f32],
    n: usize,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    depth: usize,
) {
    use crate::hadamard::hadacore::fwht_hadacore_f32_planned_depth;
    match (&plan.hadacore, kind) {
        (Some(hp), KernelKind::HadaCore) => {
            fwht_hadacore_f32_planned_depth(buf, hp, opts, depth)
        }
        _ => crate::hadamard::fwht_f32(kind, buf, n, opts),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::roofline::{
        recommend_fusion_depth, recommend_fusion_depth_lanes,
    };

    fn cfg() -> ExecConfig {
        ExecConfig {
            threads: 8,
            chunks_per_thread: 4,
            min_chunk_elems: 1 << 14,
            tune: TunePolicy::Measure,
        }
    }

    #[test]
    fn fixed_depth_is_clamped_to_the_plan() {
        let c = ExecConfig { tune: TunePolicy::FixedDepth(9), ..cfg() };
        let t = tuning_for(&c, KernelKind::HadaCore, 4096, 8, DType::F32);
        assert_eq!(t.fusion_depth, 3, "4096 = 16^3 has 3 rounds");
        assert_eq!(t.source, TuneSource::Config);
        let c = ExecConfig { tune: TunePolicy::FixedDepth(2), ..cfg() };
        let t = tuning_for(&c, KernelKind::HadaCore, 4096, 8, DType::F32);
        assert_eq!(t.fusion_depth, 2);
    }

    #[test]
    fn off_policy_restores_pre_tuner_behaviour() {
        let c = ExecConfig { tune: TunePolicy::Off, ..cfg() };
        // both at a class boundary and off it: chunks must equal the
        // static policy for the *actual* row count
        for rows in [256usize, 33] {
            let t = tuning_for(&c, KernelKind::HadaCore, 4096, rows, DType::F32);
            assert_eq!(t.fusion_depth, 1);
            assert_eq!(t.chunk_rows, policy_chunk_rows(&c, rows, 4096));
            assert!(!t.chunk_pinned);
        }
    }

    #[test]
    fn butterfly_kernels_never_fuse() {
        let c = ExecConfig { tune: TunePolicy::Model, ..cfg() };
        let t = tuning_for(&c, KernelKind::Dao, 4096, 8, DType::F32);
        assert_eq!(t.fusion_depth, 1);
    }

    #[test]
    fn model_policy_is_deterministic_and_seeded_by_the_roofline() {
        let c = ExecConfig { tune: TunePolicy::Model, ..cfg() };
        let a = tuning_for(&c, KernelKind::HadaCore, 4096, 64, DType::F32);
        let b = tuning_for(&c, KernelKind::HadaCore, 4096, 64, DType::F32);
        assert_eq!(a.fusion_depth, b.fusion_depth);
        assert_eq!(a.chunk_rows, b.chunk_rows);
        // the seed is the *lane-aware* recommendation for whatever
        // backend is active in this process (under HADACORE_SIMD=off
        // the scalar compute floor suppresses fusion; wide vectors keep
        // the cache-budget depth), and never exceeds the cache budget
        let lanes = crate::hadamard::simd::active().lanes();
        assert_eq!(
            a.fusion_depth,
            recommend_fusion_depth_lanes(4096, FUSION_CACHE_BUDGET, lanes)
        );
        assert!(
            a.fusion_depth <= recommend_fusion_depth(4096, FUSION_CACHE_BUDGET)
        );
        assert_eq!(a.source, TuneSource::Model);
    }

    #[test]
    fn measured_policy_picks_a_valid_depth_and_sane_chunks() {
        let c = cfg();
        let t = tuning_for(&c, KernelKind::HadaCore, 1024, 64, DType::F32);
        assert!((1..=2).contains(&t.fusion_depth), "1024 has 2 rounds");
        assert_eq!(t.source, TuneSource::Measured);
        // refinement never shards coarser than the policy nor finer
        // than the floor
        let policy = policy_chunk_rows(&c, 64, 1024);
        let floor = (c.min_chunk_elems + 1023) / 1024;
        assert!(t.chunk_rows >= floor && t.chunk_rows <= policy);
    }

    #[test]
    fn measurements_are_memoized_per_key() {
        use super::super::plan::measured_key_count;
        // a (kernel, n) combination no other test measures, so the
        // check is immune to concurrently-running lib tests
        let a = measurement_for(KernelKind::Scalar, 40960, 1);
        let b = measurement_for(KernelKind::Scalar, 40960, 1);
        assert_eq!(a.fusion_depth, b.fusion_depth);
        // wall-clock timings are never bit-identical across two real
        // sweeps — equal bits means the second call hit the memo
        assert!(
            a.ns_per_elem.to_bits() == b.ns_per_elem.to_bits(),
            "second lookup re-measured: {} vs {}",
            a.ns_per_elem,
            b.ns_per_elem
        );
        assert!(measured_key_count() >= 1);
        // dtypes share the measurement; only the cost estimate (and so
        // possibly the chunk refinement) is rescaled — decisions for a
        // fixed input stay stable across repeated resolution
        let c = cfg();
        let t1 = tuning_for(&c, KernelKind::Scalar, 40960, 8, DType::BF16);
        let t2 = tuning_for(&c, KernelKind::Scalar, 40960, 8, DType::BF16);
        assert_eq!(t1.fusion_depth, t2.fusion_depth);
        assert_eq!(t1.chunk_rows, t2.chunk_rows);
    }

    #[test]
    fn chunk_pin_does_not_disable_fusion_resolution() {
        // the env-pin semantics are per-knob: a pinned chunk leaves the
        // depth to the policy (and vice versa). Env vars can't be set in
        // a shared test process, so the resolution is checked at the
        // policy layer: FixedDepth pins depth while the chunk still
        // follows policy, and the pinned flag is only set by the env.
        let c = ExecConfig { tune: TunePolicy::FixedDepth(3), ..cfg() };
        let t = tuning_for(&c, KernelKind::HadaCore, 4096, 128, DType::F32);
        assert_eq!(t.fusion_depth, 3);
        assert_eq!(t.chunk_rows, policy_chunk_rows(&c, 128, 4096));
        assert!(!t.chunk_pinned, "no env pin in this process");
    }

    #[test]
    fn chunk_refinement_is_clamped_to_the_policy_envelope() {
        let c = cfg();
        // absurdly slow per-element cost: wants 1-row chunks, floor wins
        let fine = refine_chunk_rows(&c, 1024, 256, 1e6);
        assert_eq!(fine, (c.min_chunk_elems + 255) / 256);
        // absurdly fast: wants huge chunks, policy wins
        let coarse = refine_chunk_rows(&c, 1024, 256, 1e-9);
        assert_eq!(coarse, policy_chunk_rows(&c, 1024, 256));
    }
}
