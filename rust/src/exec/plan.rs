//! Process-wide transform-plan and tuning-measurement caches.
//!
//! Building a [`HadaCorePlan`] rederives the canonical `n = B * 2^k`
//! base split, the `2^k = 2^m * 16^r` factorisation, the per-round
//! stride table, and the §3.3 residual factor matrix. None of that
//! depends on the data, only on the transform size — so the engine
//! memoizes one [`ExecPlan`] per `(kernel, n)` for the lifetime of the
//! process and hands out `Arc` clones. The key stays `(kernel, n)`
//! across the whole size family: base-40 sizes hash under their own `n`
//! even though their plan canonicalises to base 20 internally, so no
//! caller needs to know about canonicalisation. Per-batch dispatch
//! therefore performs **no allocation and no factor reconstruction**;
//! it is a hash lookup.
//!
//! The same module memoizes the autotuner's one-shot micro-measurement
//! ([`measurement_for`]) per `(kernel, n, simd backend)`: the fastest
//! fusion depth and the observed per-element cost are host physics —
//! of the *vector backend actually dispatched*, hence the backend in
//! the key — not engine configuration, so every engine in the process
//! shares them. The
//! measurement runs *outside* the cache lock (it takes ~a millisecond;
//! concurrent first lookups may both measure, first insert wins — a
//! benign race that trades a duplicated measurement for never blocking
//! other sizes' lookups).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::hadamard::hadacore::{HadaCoreConfig, HadaCorePlan};
use crate::hadamard::KernelKind;
use crate::util::lazy::Lazy;

use super::tune::{self, Measurement};

/// A cached execution plan for one `(kernel, n)` pair.
#[derive(Debug)]
pub struct ExecPlan {
    /// Kernel this plan drives.
    pub kind: KernelKind,
    /// Transform size.
    pub n: usize,
    /// Precomputed round structure (HadaCore only; the butterfly kernels
    /// carry no per-size state worth caching).
    pub hadacore: Option<HadaCorePlan>,
}

type Cache = Mutex<HashMap<(KernelKind, usize), Arc<ExecPlan>>>;

static CACHE: Lazy<Cache> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Get (building and caching on first use) the plan for `(kind, n)`.
///
/// `n` must be in the supported `B * 2^k` family within
/// [`crate::MAX_HADAMARD_SIZE`]; the engine validates dimensions before
/// calling this.
pub fn plan_for(kind: KernelKind, n: usize) -> Arc<ExecPlan> {
    let mut cache = CACHE.lock().unwrap();
    Arc::clone(cache.entry((kind, n)).or_insert_with(|| {
        Arc::new(ExecPlan {
            kind,
            n,
            hadacore: (kind == KernelKind::HadaCore)
                .then(|| HadaCorePlan::new(n, &HadaCoreConfig::default())),
        })
    }))
}

/// Number of plans currently cached (observability / tests).
pub fn cached_plan_count() -> usize {
    CACHE.lock().unwrap().len()
}

type TuneCache =
    Mutex<HashMap<(KernelKind, usize, crate::hadamard::simd::Backend), Measurement>>;

static TUNE_CACHE: Lazy<TuneCache> = Lazy::new(|| Mutex::new(HashMap::new()));

/// Get (measuring and memoizing on first use) the autotuner's
/// micro-measurement for `(kind, n)` **under the active SIMD backend**
/// — the memo key carries [`crate::hadamard::simd::active`], so a
/// measurement taken against AVX-512 butterflies is never replayed for
/// the scalar fallback (their depth/chunk optima differ; forcing a
/// backend mid-process re-measures rather than serving stale physics).
/// The sweep runs on the f32 compute image — 16-bit storage only
/// rescales the cost estimate at resolve time, so mixed-dtype traffic
/// at one size shares a single measurement. `seed_depth` is the
/// roofline model's proposal, used to narrow the candidate sweep on a
/// miss; hits ignore it.
pub fn measurement_for(kind: KernelKind, n: usize, seed_depth: usize) -> Measurement {
    let key = (kind, n, crate::hadamard::simd::active());
    if let Some(m) = TUNE_CACHE.lock().unwrap().get(&key) {
        return *m;
    }
    // measure without holding the lock (see the module doc)
    let plan = plan_for(kind, n);
    let measured = tune::measure_profile(kind, n, &plan, seed_depth);
    *TUNE_CACHE.lock().unwrap().entry(key).or_insert(measured)
}

/// Number of memoized tuning measurements (observability / tests).
pub fn measured_key_count() -> usize {
    TUNE_CACHE.lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_cached_and_shared() {
        let before = cached_plan_count();
        let a = plan_for(KernelKind::HadaCore, 1 << 14);
        let b = plan_for(KernelKind::HadaCore, 1 << 14);
        assert!(Arc::ptr_eq(&a, &b), "same (kind, n) must share one plan");
        assert_eq!(cached_plan_count(), before + 1);

        let c = plan_for(KernelKind::Dao, 1 << 14);
        assert!(c.hadacore.is_none());
        assert_eq!(cached_plan_count(), before + 2);

        let hp = a.hadacore.as_ref().expect("hadacore plan present");
        assert_eq!(hp.n(), 1 << 14);
    }

    #[test]
    fn non_pow2_sizes_cache_their_own_plans() {
        let before = cached_plan_count();
        let a = plan_for(KernelKind::HadaCore, 14336);
        let b = plan_for(KernelKind::HadaCore, 14336);
        assert!(Arc::ptr_eq(&a, &b));
        let hp = a.hadacore.as_ref().expect("hadacore plan present");
        assert_eq!(hp.n(), 14336);
        assert_eq!(hp.base(), 28);
        // 40960 canonicalises to base 20 internally but keys under its
        // own n — callers never see the canonicalisation
        let c = plan_for(KernelKind::HadaCore, 40960);
        assert_eq!(c.hadacore.as_ref().unwrap().base(), 20);
        assert_eq!(c.n, 40960);
        assert_eq!(cached_plan_count(), before + 2);
    }

    #[test]
    fn concurrent_lookups_converge() {
        let plans: Vec<Arc<ExecPlan>> = std::thread::scope(|s| {
            (0..8)
                .map(|_| s.spawn(|| plan_for(KernelKind::HadaCore, 1 << 13)))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p));
        }
    }
}
