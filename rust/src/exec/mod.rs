//! Batched multi-threaded execution engine.
//!
//! The serving layers below this module execute every batch on the
//! thread that picked it up, and the 16-bit path allocates a fresh f32
//! widening buffer per call — exactly the compute- and exchange-overhead
//! the paper restructures the transform to remove. This module is the
//! CPU-side answer, three pieces (design details in
//! `docs/ARCHITECTURE.md`):
//!
//! * `pool` (private) — a persistent std-thread worker pool. A `rows x n` batch
//!   is sharded into row chunks; workers claim chunks under one lock
//!   (chunk-granular work stealing), and the submitter blocks on a
//!   completion latch. Small batches never pay the handoff: below the
//!   sharding threshold they run inline on the submitting thread.
//! * **per-thread workspaces** — each worker owns a reusable f32 scratch
//!   buffer, so the f16/bf16 widen-compute-narrow path performs no heap
//!   allocation in steady state ([`ExecStats::scratch_grows`] counts the
//!   warmup growths and then stays flat). Inline (non-sharded) 16-bit
//!   runs use a thread-local workspace on the submitting thread, so
//!   concurrent small batches never serialize on a shared buffer.
//! * **fused quantize epilogue** — [`ExecEngine::run_with_epilogue`]
//!   executes a [`Epilogue`] inside the same chunk traversal as the
//!   transform: rotate, amax-reduce, and round while the chunk is
//!   cache-hot, instead of callers making a second full pass over the
//!   rotated rows. Per-tensor FP8 needs a global amax, so the engine runs
//!   a **two-phase sharded job** over the same chunk-claiming pool:
//!   phase 1 transforms each chunk and merges its max-abs into a shared
//!   accumulator; phase 2 scales + rounds each chunk. Grouped INT8 is
//!   single-phase (`group` divides `n`, so scales never cross a chunk).
//!   Outputs are bit-identical to the unfused reference (transform then
//!   [`crate::quant::fp8_quantize_slice`] /
//!   [`crate::quant::int_quantize_grouped`]).
//! * [`plan`] — a process-wide cache memoizing the per-size round
//!   structure (Sylvester factorisation, stride tables, §3.3 residual
//!   factor), so per-batch dispatch rebuilds nothing.
//! * [`tune`] — a roofline-guided autotuner: per batch shape it picks
//!   the HadaCore **round-fusion depth** (how many consecutive 16×16
//!   rounds run per cache-blocked tile — one read and one write of the
//!   tile instead of one per round) and refines the pool's chunk
//!   height, seeding from the `gpu_model` roofline and confirming with
//!   a one-shot micro-measurement memoized per `(kernel, n)` next to
//!   the plan cache. Fused execution is bit-identical to unfused at
//!   every depth; `HADACORE_TUNE` / `HADACORE_FUSION_DEPTH` /
//!   `HADACORE_CHUNK_ROWS` pin the decisions for reproducible runs.
//!
//! ```no_run
//! use hadacore::exec::ExecEngine;
//! use hadacore::hadamard::{FwhtOptions, KernelKind};
//! use hadacore::quant::{Epilogue, Fp8Format};
//!
//! let engine = ExecEngine::default(); // one lane per core (capped at 16)
//! let (rows, n) = (256, 4096);
//! let mut batch = vec![1.0f32; rows * n];
//! engine.run(KernelKind::HadaCore, &mut batch, n, &FwhtOptions::normalized(n));
//!
//! // fused rotate -> fp8-quantize in one pass over each chunk
//! let mut batch = vec![1.0f32; rows * n];
//! let scales = engine.run_with_epilogue(
//!     KernelKind::HadaCore,
//!     &mut batch,
//!     n,
//!     &FwhtOptions::normalized(n),
//!     Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
//! );
//! assert!(scales.per_tensor().is_some());
//! ```

pub mod plan;
mod pool;
pub mod tune;

pub use plan::{cached_plan_count, measured_key_count, plan_for, ExecPlan};
pub use tune::{tuning_for, TunePolicy, TuneSource, Tuning};

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use crate::hadamard::hadacore::fwht_hadacore_f32_planned_depth;
use crate::hadamard::{
    apply_signs, fwht_f32, validate_dims, FwhtOptions, KernelKind, Prologue,
};
use crate::quant::{
    amax_slice, fp8_apply_slice, int_group_apply_slice, Epilogue, Fp8Format,
    IntBits, QuantScales,
};
use crate::util::f16::{Element, BF16, F16};

use pool::{JobSpec, WorkerPool};

/// A batch buffer's base pointer, tagged with its storage dtype so it can
/// cross the worker-thread boundary. Implementation detail of the
/// engine's sharding; public only because [`ExecElement`] mentions it.
#[doc(hidden)]
#[derive(Clone, Copy)]
pub enum Payload {
    F32(*mut f32),
    F16(*mut F16),
    BF16(*mut BF16),
}

// SAFETY: a Payload is only ever dereferenced through `execute_range`,
// whose callers guarantee exclusive, disjoint access (see pool.rs).
unsafe impl Send for Payload {}

/// One contiguous run of f32 rows inside a scatter-gather batch: the
/// serving layer's zero-copy path hands the engine one region per
/// request buffer instead of gathering them into a batch `Vec` (see
/// [`ExecEngine::run_f32_regions`]).
#[derive(Clone, Copy, Debug)]
pub struct RowRegion {
    /// Base pointer of the region (`rows * n` contiguous f32).
    pub ptr: *mut f32,
    /// Rows in this region.
    pub rows: usize,
}

// SAFETY: a RowRegion is only dereferenced through
// `execute_regions_range`, whose callers guarantee the regions are
// valid, mutually disjoint, and exclusively borrowed for the job.
unsafe impl Send for RowRegion {}
unsafe impl Sync for RowRegion {}

/// Raw view of a caller-owned `&[RowRegion]` slice, shipped to pool
/// workers inside a [`pool::JobSpec`]. The submitter blocks on the job's
/// latch, so the slice outlives every worker access.
#[derive(Clone, Copy)]
pub(crate) struct RegionsRef {
    pub(crate) base: *const RowRegion,
    pub(crate) len: usize,
}

// SAFETY: see RowRegion — the submitter keeps the slice alive and the
// regions exclusively borrowed until the job's latch opens.
unsafe impl Send for RegionsRef {}

impl RegionsRef {
    /// # Safety
    /// The originating slice must still be live (guaranteed by the
    /// blocking submit).
    pub(crate) unsafe fn as_slice(&self) -> &[RowRegion] {
        std::slice::from_raw_parts(self.base, self.len)
    }
}

/// Storage dtypes the engine can execute: `f32` directly, [`F16`] and
/// [`BF16`] through the per-thread f32 workspace.
pub trait ExecElement: Element {
    #[doc(hidden)]
    fn payload(base: *mut Self) -> Payload;
}

impl ExecElement for f32 {
    fn payload(base: *mut Self) -> Payload {
        Payload::F32(base)
    }
}

impl ExecElement for F16 {
    fn payload(base: *mut Self) -> Payload {
        Payload::F16(base)
    }
}

impl ExecElement for BF16 {
    fn payload(base: *mut Self) -> Payload {
        Payload::BF16(base)
    }
}

/// Shared nonnegative-f32 max accumulator — the phase-1 reduction target
/// of the per-tensor epilogue. Nonnegative IEEE floats order identically
/// to their bit patterns, so `fetch_max` on the bits is an exact float
/// max; merged per-chunk maxima therefore equal the sequential fold of
/// [`crate::quant::fp8_quantize_slice`] bit-for-bit. Relaxed ordering is
/// sufficient: the job's completion latch provides the happens-before
/// edge to the submitting thread.
pub(crate) struct AmaxCell(AtomicU32);

impl AmaxCell {
    fn new() -> AmaxCell {
        AmaxCell(AtomicU32::new(0))
    }

    /// Re-arm for the next job (same reuse contract as the pool's
    /// submit latch: the previous job's workers have all finished).
    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }

    pub(crate) fn merge(&self, v: f32) {
        debug_assert!(v >= 0.0, "amax must be nonnegative");
        self.0.fetch_max(v.to_bits(), Ordering::Relaxed);
    }

    fn get(&self) -> f32 {
        f32::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Base pointer of the per-group scale output vector for the grouped
/// epilogue. Distinct chunks write disjoint slot ranges (chunks cover
/// whole rows and `group` divides `n`) — the same disjointness argument
/// as [`Payload`].
#[derive(Clone, Copy)]
pub(crate) struct ScalesPtr(pub(crate) *mut f32);

// SAFETY: only dereferenced through `group_quant_range`, whose callers
// guarantee disjoint slot ranges per chunk (see the type doc).
unsafe impl Send for ScalesPtr {}

/// What a claimed chunk executes. `Rotate` is the plain transform; the
/// other stages realise the fused quantize epilogue (module doc).
#[derive(Clone)]
pub(crate) enum ChunkStage {
    /// Transform each row of the chunk.
    Rotate,
    /// Epilogue phase 1: transform, then merge the chunk's max-abs into
    /// the shared accumulator.
    RotateAmax { amax: Arc<AmaxCell> },
    /// Single-phase grouped-INT8 epilogue: transform, then quantise each
    /// `group`-sized run and record its scale.
    RotateGroupQuant { group: usize, scales: ScalesPtr },
    /// Epilogue phase 2: scale + round every element under the global
    /// per-tensor scale (no transform — the rows are already rotated).
    QuantFp8 { scale: f32, fmt: Fp8Format },
}

/// Engine counters (all monotonically increasing) — registry-backed
/// handles into the `hadacore_exec_*` namespace, so every engine's
/// counts also render in the `/metrics` exposition (summed when a
/// process runs several engines).
#[derive(Debug)]
pub struct ExecStats {
    /// Batches sharded across the worker pool.
    pub jobs: Arc<AtomicU64>,
    /// Batches executed inline on the submitting thread (too small to
    /// shard, or a single-threaded engine).
    pub inline_runs: Arc<AtomicU64>,
    /// Chunks executed (an inline run counts as one chunk).
    pub chunks: Arc<AtomicU64>,
    /// Growth events of the reusable f32 workspaces. Flat counter ==
    /// zero-allocation steady state on the 16-bit path.
    pub scratch_grows: Arc<AtomicU64>,
    /// Runs that executed a fused quantize epilogue (inline or sharded).
    pub epilogue_runs: Arc<AtomicU64>,
    /// Runs that executed a fused sign-flip prologue (inline or sharded).
    pub prologue_runs: Arc<AtomicU64>,
    /// Runs whose tuned fusion depth was > 1 (multi-round tiles).
    pub fused_runs: Arc<AtomicU64>,
    /// Per-chunk execution latency (`hadacore_exec_chunk_us`) — the
    /// paper-motivated stage-level measurement: batch latency tells you
    /// *that* a batch was slow, chunk latency tells you *which shard*.
    pub chunk_us: Arc<crate::coordinator::metrics::Histogram>,
}

impl Default for ExecStats {
    fn default() -> Self {
        let r = crate::obs::registry();
        // process-wide computed series whose sources of truth predate
        // the registry (SIMD dispatch tables, tuner provenance counts):
        // registered once, with the first engine — sampled at render
        // time, so those hot paths stay untouched
        static PROCESS_SERIES: std::sync::Once = std::sync::Once::new();
        PROCESS_SERIES.call_once(|| {
            for b in crate::hadamard::simd::Backend::all() {
                r.labeled_counter_fn(
                    "hadacore_simd_dispatch_total",
                    "kernel dispatches served, per SIMD backend",
                    "backend",
                    b.name(),
                    move || crate::hadamard::simd::dispatch_count(b),
                );
            }
            for s in tune::TuneSource::ALL {
                r.labeled_counter_fn(
                    "hadacore_tune_decisions_total",
                    "resolved tuning decisions, per provenance",
                    "source",
                    s.name(),
                    move || tune::decision_count(s),
                );
            }
        });
        ExecStats {
            jobs: r.counter("hadacore_exec_jobs_total", "batches sharded across the pool"),
            inline_runs: r.counter(
                "hadacore_exec_inline_total",
                "batches executed inline on the submitting thread",
            ),
            chunks: r.counter("hadacore_exec_chunks_total", "chunks executed"),
            scratch_grows: r.counter(
                "hadacore_exec_scratch_grows_total",
                "growth events of the reusable f32 workspaces",
            ),
            epilogue_runs: r.counter(
                "hadacore_exec_epilogue_runs_total",
                "runs with a fused quantize epilogue",
            ),
            prologue_runs: r.counter(
                "hadacore_exec_prologue_runs_total",
                "runs with a fused sign-flip prologue",
            ),
            fused_runs: r.counter(
                "hadacore_exec_fused_runs_total",
                "runs whose tuned fusion depth was > 1",
            ),
            chunk_us: r.histogram_us("hadacore_exec_chunk_us", "per-chunk execution latency"),
        }
    }
}

/// Point-in-time copy of [`ExecStats`], plus the process-wide SIMD
/// dispatch state (which vector backend the butterfly kernels run on,
/// and how many dispatches it has served — the non-vacuity signal the
/// forced-dispatch test matrix asserts on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecStatsSnapshot {
    pub jobs: u64,
    pub inline_runs: u64,
    pub chunks: u64,
    pub scratch_grows: u64,
    pub epilogue_runs: u64,
    pub prologue_runs: u64,
    pub fused_runs: u64,
    /// Name of the active [`crate::hadamard::simd::Backend`]
    /// (process-wide, not per-engine).
    pub simd_backend: &'static str,
    /// Kernel dispatches the active backend has served so far
    /// (process-wide monotone counter).
    pub simd_dispatches: u64,
}

impl ExecStats {
    fn snapshot(&self) -> ExecStatsSnapshot {
        let backend = crate::hadamard::simd::active();
        ExecStatsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            inline_runs: self.inline_runs.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            scratch_grows: self.scratch_grows.load(Ordering::Relaxed),
            epilogue_runs: self.epilogue_runs.load(Ordering::Relaxed),
            prologue_runs: self.prologue_runs.load(Ordering::Relaxed),
            fused_runs: self.fused_runs.load(Ordering::Relaxed),
            simd_backend: backend.name(),
            simd_dispatches: crate::hadamard::simd::dispatch_count(backend),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    /// Compute lanes (worker threads). `1` runs everything inline on the
    /// submitting thread with no pool.
    pub threads: usize,
    /// Target chunks per lane per batch. More chunks balance uneven
    /// progress better; fewer chunks lower claim overhead.
    pub chunks_per_thread: usize,
    /// Minimum elements per chunk. Batches smaller than one chunk run
    /// inline — the thread handoff costs more than the transform.
    pub min_chunk_elems: usize,
    /// How fusion depth and chunk refinement are chosen (see
    /// [`tune`]). `HADACORE_TUNE` / `HADACORE_FUSION_DEPTH` /
    /// `HADACORE_CHUNK_ROWS` env vars override this at runtime for
    /// reproducible runs.
    pub tune: TunePolicy,
}

impl ExecConfig {
    /// The CLI-flag convention shared by the binary, examples, and
    /// benches: `0` = the default lane policy (per-core, capped at 16),
    /// anything else overrides the lane count exactly.
    pub fn with_lanes(lanes: usize) -> ExecConfig {
        if lanes == 0 {
            ExecConfig::default()
        } else {
            ExecConfig { threads: lanes, ..ExecConfig::default() }
        }
    }
}

impl Default for ExecConfig {
    /// One lane per available core, capped at 16 — the transform is
    /// memory-bound well before that on typical hosts; raise `threads`
    /// explicitly to use more. Tuning defaults to the measured policy.
    fn default() -> Self {
        ExecConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(16),
            chunks_per_thread: 4,
            min_chunk_elems: 1 << 14, // 16K elements = 64 KiB of f32
            tune: TunePolicy::Measure,
        }
    }
}

/// Capacity (in f32 elements) the inline workspace may retain between
/// runs: 16 MiB per thread. A pool-less engine runs *every* batch
/// inline, so without a bound a one-off huge 16-bit batch would pin its
/// widening buffer for the submitting thread's lifetime.
const INLINE_SCRATCH_RETAIN_ELEMS: usize = 1 << 22;

thread_local! {
    // Reusable widen/narrow workspace for inline (non-sharded) 16-bit
    // runs — one per *submitting* thread, so concurrent small f16/bf16
    // batches never serialize on a shared buffer (a `Mutex<Vec<f32>>`
    // here would funnel every inline submitter through one lock,
    // contradicting the pool's stay-parallel design). Growth is still
    // counted through `ExecStats::scratch_grows` by `widen_run_narrow`;
    // retention is bounded by `INLINE_SCRATCH_RETAIN_ELEMS`.
    static INLINE_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };

    // Reusable per-tensor amax accumulator for the two-phase FP8
    // epilogue — one per submitting thread, re-armed per job (the
    // blocking submit guarantees the previous job's workers are done),
    // so steady-state FP8 serving allocates no per-job Arc.
    static SUBMIT_AMAX: RefCell<Option<Arc<AmaxCell>>> = const { RefCell::new(None) };
}

/// This submitter's reusable amax cell, re-armed to zero.
fn recycled_amax_cell() -> Arc<AmaxCell> {
    SUBMIT_AMAX.with(|cell| {
        let mut slot = cell.borrow_mut();
        match slot.as_ref() {
            Some(amax) => {
                amax.reset();
                Arc::clone(amax)
            }
            None => {
                let amax = Arc::new(AmaxCell::new());
                *slot = Some(Arc::clone(&amax));
                amax
            }
        }
    })
}

/// The batched execution engine. One instance owns one worker pool;
/// cheap to share behind an [`Arc`] — every method takes `&self`.
pub struct ExecEngine {
    cfg: ExecConfig,
    pool: Option<WorkerPool>,
    stats: Arc<ExecStats>,
}

impl Default for ExecEngine {
    fn default() -> Self {
        ExecEngine::new(ExecConfig::default())
    }
}

impl ExecEngine {
    /// Start an engine (spawns `cfg.threads` workers when `> 1`).
    pub fn new(cfg: ExecConfig) -> ExecEngine {
        let cfg = ExecConfig { threads: cfg.threads.max(1), ..cfg };
        let stats = Arc::new(ExecStats::default());
        let pool = (cfg.threads > 1)
            .then(|| WorkerPool::new(cfg.threads, Arc::clone(&stats)));
        ExecEngine { cfg, pool, stats }
    }

    /// An engine with no pool: every batch runs inline on the caller.
    /// The single-thread baseline the benches compare against, and the
    /// deterministic-scheduling arm of the parity tests.
    pub fn single_threaded() -> ExecEngine {
        ExecEngine::new(ExecConfig { threads: 1, ..ExecConfig::default() })
    }

    /// Configured lane count.
    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ExecStatsSnapshot {
        self.stats.snapshot()
    }

    /// Transform every `n`-sized row of `data` in place with `kind`,
    /// sharding across the worker pool when the batch is large enough.
    ///
    /// Bit-identical to calling the kernel directly on the whole buffer
    /// (row transforms are independent, and the HadaCore plan replays the
    /// exact pass structure of the unplanned path).
    ///
    /// Panics if `data.len()` is not a `rows * n` multiple or `n` is
    /// outside the supported `B * 2^k` size family — callers on the
    /// serving path have already validated via the router.
    pub fn run<E: ExecElement>(
        &self,
        kind: KernelKind,
        data: &mut [E],
        n: usize,
        opts: &FwhtOptions,
    ) {
        self.run_with_stages(kind, data, n, opts, Prologue::None, Epilogue::None);
    }

    /// [`ExecEngine::run`] plus a fused quantize [`Epilogue`], executed
    /// inside the same chunk traversal as the transform (module doc).
    /// Returns the scale(s) the epilogue produced.
    ///
    /// Bit-identical to the unfused reference — [`ExecEngine::run`]
    /// followed by [`crate::quant::fp8_quantize_slice`] (per-tensor) or
    /// [`crate::quant::int_quantize_grouped`] (per-group) over the whole
    /// buffer; for 16-bit storage the reference widens the transformed
    /// buffer, quantises in f32, and narrows back.
    ///
    /// Panics on invalid dimensions (as [`ExecEngine::run`]) or an
    /// [`Epilogue`] that fails [`Epilogue::validate`] for `n` — serving
    /// callers have already validated both at admission.
    pub fn run_with_epilogue<E: ExecElement>(
        &self,
        kind: KernelKind,
        data: &mut [E],
        n: usize,
        opts: &FwhtOptions,
        epilogue: Epilogue,
    ) -> QuantScales {
        self.run_with_stages(kind, data, n, opts, Prologue::None, epilogue)
    }

    /// The full fused pipeline: an optional randomized-rotation
    /// [`Prologue`] (seeded ±1 sign flip applied to each chunk's rows in
    /// the same traversal that transforms them — for 16-bit storage the
    /// flip rides the widening copy, so it costs zero extra passes), the
    /// transform, and an optional quantize [`Epilogue`].
    ///
    /// The prologue is bit-identical to the unfused reference —
    /// [`crate::hadamard::apply_signs`] over the whole buffer followed by
    /// the plain engine run: a ±1.0 multiply is exact and commutes with
    /// widening, so fusing it changes no bits (enforced by
    /// `rust/tests/rotation_parity.rs`).
    ///
    /// Panics on invalid dimensions, epilogue, or prologue — serving
    /// callers have already validated all three at admission.
    pub fn run_with_stages<E: ExecElement>(
        &self,
        kind: KernelKind,
        data: &mut [E],
        n: usize,
        opts: &FwhtOptions,
        prologue: Prologue,
        epilogue: Epilogue,
    ) -> QuantScales {
        let rows = validate_dims(data.len(), n).expect("invalid dimensions");
        if let Err(e) = epilogue.validate(n) {
            panic!("invalid epilogue: {e}");
        }
        if let Err(e) = prologue.validate(n) {
            panic!("invalid prologue: {e}");
        }
        if !epilogue.is_none() {
            self.stats.epilogue_runs.fetch_add(1, Ordering::Relaxed);
        }
        if !prologue.is_none() {
            self.stats.prologue_runs.fetch_add(1, Ordering::Relaxed);
        }
        // the sign vector is served from the process-wide (seed, n)
        // cache — zero-alloc after warmup; chunks share the Arc
        let signs: Option<Arc<Vec<f32>>> = prologue.signs_cached(n);
        let plan = plan_for(kind, n);
        // the autotuned fusion depth + chunk refinement for this shape
        // (memoized; a hash lookup after first use). An env-pinned chunk
        // wins outright; otherwise the refined chunk never shards
        // coarser than the static per-batch balance policy.
        let tuning = tune::tuning_for_plan(&self.cfg, &plan, rows, E::DTYPE);
        let chunk_rows = if tuning.chunk_pinned {
            tuning.chunk_rows
        } else {
            tuning.chunk_rows.min(self.chunk_rows_for(rows, n)).max(1)
        };
        let fusion_depth = tuning.fusion_depth;
        if fusion_depth > 1 {
            self.stats.fused_runs.fetch_add(1, Ordering::Relaxed);
        }
        let chunks = (rows + chunk_rows - 1) / chunk_rows;
        let payload = E::payload(data.as_mut_ptr());
        match &self.pool {
            Some(pool) if chunks > 1 => {
                self.stats.jobs.fetch_add(1, Ordering::Relaxed);
                let trace = crate::obs::trace::current().0;
                let spec = |stage: ChunkStage| JobSpec {
                    payload,
                    rows,
                    n,
                    chunk_rows,
                    kind,
                    opts: *opts,
                    plan: Arc::clone(&plan),
                    fusion_depth,
                    signs: signs.clone(),
                    stage,
                    regions: None,
                    trace,
                };
                // SAFETY (all submissions below): `data` is a `&mut`
                // borrow we hold for the whole call, covering exactly
                // `rows * n` elements; each submission blocks until its
                // chunks complete, so the phases never overlap.
                match epilogue {
                    Epilogue::None => {
                        unsafe { pool.submit_and_wait(spec(ChunkStage::Rotate)) };
                        QuantScales::None
                    }
                    Epilogue::QuantFp8 { fmt } => {
                        // phase 1: rotate + merge per-chunk amax into the
                        // shared accumulator (reused across this
                        // submitter's jobs — no per-job allocation)
                        let amax = recycled_amax_cell();
                        unsafe {
                            pool.submit_and_wait(spec(ChunkStage::RotateAmax {
                                amax: Arc::clone(&amax),
                            }))
                        };
                        let amax = amax.get();
                        if amax == 0.0 {
                            // matches fp8_quantize_slice: all-zero data is
                            // left untouched and the scale is 1
                            return QuantScales::PerTensor(1.0);
                        }
                        let scale = amax / fmt.max_finite();
                        // phase 2: scale + round each chunk
                        unsafe {
                            pool.submit_and_wait(spec(ChunkStage::QuantFp8 {
                                scale,
                                fmt,
                            }))
                        };
                        QuantScales::PerTensor(scale)
                    }
                    Epilogue::QuantInt8 { group } => {
                        // recycled vector: the serve writer returns it
                        // to the scale pool after framing, so steady
                        // grouped-INT8 traffic allocates no scales
                        let mut scales = crate::util::pool::scale_pool()
                            .get_zeroed(rows * n / group);
                        // SAFETY of ScalesPtr: `scales` outlives the
                        // blocking submission and chunks write disjoint
                        // slot ranges (group divides n).
                        unsafe {
                            pool.submit_and_wait(spec(
                                ChunkStage::RotateGroupQuant {
                                    group,
                                    scales: ScalesPtr(scales.as_mut_ptr()),
                                },
                            ))
                        };
                        QuantScales::PerGroup(scales)
                    }
                }
            }
            _ => {
                self.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
                // the inline path is "one chunk on the submitting
                // thread": it still lands in the chunk-latency histogram
                // and the span chain, so traces and the exec_chunk_us
                // metric look the same whether or not a pool ran
                let trace = crate::obs::trace::current();
                crate::obs::trace::event(trace, crate::obs::Stage::ExecStart, 0);
                let chunk_start = std::time::Instant::now();
                let scales = match payload {
                    // f32 never touches scratch — no workspace borrow
                    Payload::F32(_) => {
                        let mut unused = Vec::new();
                        // SAFETY: whole buffer as one chunk, under our `&mut`.
                        unsafe {
                            run_inline(
                                payload,
                                rows,
                                n,
                                kind,
                                opts,
                                &plan,
                                fusion_depth,
                                &self.stats,
                                signs.as_deref().map(Vec::as_slice),
                                epilogue,
                                &mut unused,
                            )
                        }
                    }
                    // 16-bit storage widens through the submitting
                    // thread's own workspace (no shared lock)
                    _ => INLINE_SCRATCH.with(|cell| {
                        let mut scratch = cell.borrow_mut();
                        // SAFETY: whole buffer as one chunk, under our `&mut`.
                        let scales = unsafe {
                            run_inline(
                                payload,
                                rows,
                                n,
                                kind,
                                opts,
                                &plan,
                                fusion_depth,
                                &self.stats,
                                signs.as_deref().map(Vec::as_slice),
                                epilogue,
                                &mut scratch,
                            )
                        };
                        if scratch.capacity() > INLINE_SCRATCH_RETAIN_ELEMS {
                            scratch.clear();
                            scratch.shrink_to(INLINE_SCRATCH_RETAIN_ELEMS);
                        }
                        scales
                    }),
                };
                self.stats
                    .chunk_us
                    .record(chunk_start.elapsed().as_micros() as u64);
                crate::obs::trace::event(trace, crate::obs::Stage::ExecEnd, 0);
                scales
            }
        }
    }

    /// [`ExecEngine::run`] monomorphised for `f32` — the coordinator's
    /// native-batch entry point.
    pub fn run_f32(
        &self,
        kind: KernelKind,
        data: &mut [f32],
        n: usize,
        opts: &FwhtOptions,
    ) {
        self.run::<f32>(kind, data, n, opts);
    }

    /// [`ExecEngine::run_with_epilogue`] monomorphised for `f32`.
    pub fn run_f32_with_epilogue(
        &self,
        kind: KernelKind,
        data: &mut [f32],
        n: usize,
        opts: &FwhtOptions,
        epilogue: Epilogue,
    ) -> QuantScales {
        self.run_with_epilogue::<f32>(kind, data, n, opts, epilogue)
    }

    /// [`ExecEngine::run_with_stages`] monomorphised for `f32`.
    pub fn run_f32_with_stages(
        &self,
        kind: KernelKind,
        data: &mut [f32],
        n: usize,
        opts: &FwhtOptions,
        prologue: Prologue,
        epilogue: Epilogue,
    ) -> QuantScales {
        self.run_with_stages::<f32>(kind, data, n, opts, prologue, epilogue)
    }

    /// Transform a **scatter-gather batch** of f32 row regions in place:
    /// the rows are the logical concatenation of `regions`, chunked and
    /// sharded exactly like a contiguous batch of the same total row
    /// count. This is the coordinator's zero-copy native path — one
    /// region per request buffer, no gather copy, no scatter copy.
    ///
    /// Row transforms are independent, so the output of every region is
    /// bit-identical to running the engine on that region's buffer
    /// alone (and to the gathered-batch result the serving layer
    /// produced before pooling).
    ///
    /// Only the plain-rotate stage (optionally with a sign-flip
    /// `prologue`) is supported; quantize epilogues are per-request on
    /// the serving path and use [`ExecEngine::run_f32_with_stages`] on
    /// the request's own buffer.
    ///
    /// # Safety
    ///
    /// Every region must point at `rows * n` valid f32 elements, the
    /// regions must be mutually disjoint, and the caller must hold
    /// exclusive access to all of them for the duration of the call
    /// (it blocks until every chunk has executed).
    #[doc(hidden)]
    pub unsafe fn run_f32_regions(
        &self,
        kind: KernelKind,
        regions: &[RowRegion],
        n: usize,
        opts: &FwhtOptions,
        prologue: Prologue,
    ) {
        let rows: usize = regions.iter().map(|r| r.rows).sum();
        if rows == 0 {
            return;
        }
        validate_dims(rows * n, n).expect("invalid dimensions");
        if let Err(e) = prologue.validate(n) {
            panic!("invalid prologue: {e}");
        }
        if !prologue.is_none() {
            self.stats.prologue_runs.fetch_add(1, Ordering::Relaxed);
        }
        let signs: Option<Arc<Vec<f32>>> = prologue.signs_cached(n);
        let plan = plan_for(kind, n);
        let tuning =
            tune::tuning_for_plan(&self.cfg, &plan, rows, <f32 as Element>::DTYPE);
        let chunk_rows = if tuning.chunk_pinned {
            tuning.chunk_rows
        } else {
            tuning.chunk_rows.min(self.chunk_rows_for(rows, n)).max(1)
        };
        let fusion_depth = tuning.fusion_depth;
        if fusion_depth > 1 {
            self.stats.fused_runs.fetch_add(1, Ordering::Relaxed);
        }
        let chunks = (rows + chunk_rows - 1) / chunk_rows;
        match &self.pool {
            Some(pool) if chunks > 1 => {
                self.stats.jobs.fetch_add(1, Ordering::Relaxed);
                // SAFETY: forwards the caller's contract; the submit
                // blocks, so `regions` outlives every worker access.
                pool.submit_and_wait(JobSpec {
                    // never dereferenced on the regions path
                    payload: Payload::F32(std::ptr::null_mut()),
                    rows,
                    n,
                    chunk_rows,
                    kind,
                    opts: *opts,
                    plan,
                    fusion_depth,
                    signs,
                    stage: ChunkStage::Rotate,
                    regions: Some(RegionsRef {
                        base: regions.as_ptr(),
                        len: regions.len(),
                    }),
                    trace: crate::obs::trace::current().0,
                });
            }
            _ => {
                self.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
                let trace = crate::obs::trace::current();
                crate::obs::trace::event(trace, crate::obs::Stage::ExecStart, 0);
                let chunk_start = std::time::Instant::now();
                // SAFETY: whole logical batch as one chunk, under the
                // caller's exclusive borrow of every region.
                execute_regions_range(
                    regions,
                    0,
                    rows,
                    n,
                    kind,
                    opts,
                    &plan,
                    fusion_depth,
                    signs.as_deref().map(Vec::as_slice),
                    &self.stats,
                );
                self.stats
                    .chunk_us
                    .record(chunk_start.elapsed().as_micros() as u64);
                crate::obs::trace::event(trace, crate::obs::Stage::ExecEnd, 0);
            }
        }
    }

    /// Rows per chunk for a `rows x n` batch under the static balance
    /// policy: enough chunks to balance the lanes, but never chunks
    /// smaller than `min_chunk_elems`. Delegates to the shared
    /// [`tune::policy_chunk_rows`] so the tuner's refinement envelope
    /// and the engine's policy can never drift apart.
    fn chunk_rows_for(&self, rows: usize, n: usize) -> usize {
        tune::policy_chunk_rows(&self.cfg, rows, n)
    }
}

/// Execute rows `[start_row, start_row + rows_here)` of a payload buffer:
/// direct for f32, widen-compute-narrow through `scratch` for 16-bit
/// storage. Shared by pool workers and the inline path.
///
/// `signs` (length `n`, from [`Prologue::signs`]) is the fused sign-flip
/// prologue: chunks cover whole rows, so applying it per chunk equals
/// applying it to the whole buffer. For f32 it is one in-place multiply
/// pass; for 16-bit storage it rides the widening copy, costing nothing.
///
/// # Safety
///
/// `payload` must point at a buffer of at least
/// `(start_row + rows_here) * n` elements of the tagged dtype, and no
/// other thread may access the addressed row range for the duration.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn execute_range(
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
    kind: KernelKind,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
    signs: Option<&[f32]>,
    scratch: &mut Vec<f32>,
    stats: &ExecStats,
) {
    let offset = start_row * n;
    let len = rows_here * n;
    stats.chunks.fetch_add(1, Ordering::Relaxed);
    match payload {
        Payload::F32(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            if let Some(s) = signs {
                apply_signs(data, s);
            }
            run_f32_slice(kind, data, n, opts, plan, fusion_depth);
        }
        Payload::F16(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            widen_run_narrow(
                kind, data, n, opts, plan, fusion_depth, signs, scratch, stats,
            );
        }
        Payload::BF16(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            widen_run_narrow(
                kind, data, n, opts, plan, fusion_depth, signs, scratch, stats,
            );
        }
    }
}

/// Execute rows `[start_row, start_row + rows_here)` of the **logical
/// concatenation** of `regions`: the scatter-gather analogue of
/// [`execute_range`], shared by pool workers (regions jobs) and the
/// inline path of [`ExecEngine::run_f32_regions`]. Row transforms are
/// independent, so splitting a chunk across region boundaries is
/// bit-identical to transforming a gathered copy.
///
/// # Safety
///
/// Every region must point at `rows * n` valid f32 elements; the regions
/// must be mutually disjoint; and no other thread may access the
/// addressed logical row range for the duration (chunk claims are unique
/// and row-disjoint, so concurrent chunks of the same job are fine).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn execute_regions_range(
    regions: &[RowRegion],
    start_row: usize,
    rows_here: usize,
    n: usize,
    kind: KernelKind,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
    signs: Option<&[f32]>,
    stats: &ExecStats,
) {
    stats.chunks.fetch_add(1, Ordering::Relaxed);
    let end_row = start_row + rows_here;
    // running cursor: the first logical row of the current region
    let mut region_start = 0usize;
    for r in regions {
        let region_end = region_start + r.rows;
        let lo = start_row.max(region_start);
        let hi = end_row.min(region_end);
        if lo < hi {
            let data = std::slice::from_raw_parts_mut(
                r.ptr.add((lo - region_start) * n),
                (hi - lo) * n,
            );
            if let Some(s) = signs {
                apply_signs(data, s);
            }
            run_f32_slice(kind, data, n, opts, plan, fusion_depth);
        }
        region_start = region_end;
        if region_start >= end_row {
            break;
        }
    }
}

/// Execute one claimed chunk under its [`ChunkStage`]. Shared by pool
/// workers; the inline path uses [`run_inline`] (whole buffer, one chunk).
///
/// # Safety
///
/// Same contract as [`execute_range`]; additionally, for
/// [`ChunkStage::RotateGroupQuant`] the scale pointer must address a
/// buffer of `rows * n / group` slots that outlives the job, with no
/// other thread touching this chunk's slot range.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn execute_stage(
    stage: &ChunkStage,
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
    kind: KernelKind,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
    signs: Option<&[f32]>,
    scratch: &mut Vec<f32>,
    stats: &ExecStats,
) {
    match stage {
        ChunkStage::Rotate => {
            execute_range(
                payload, start_row, rows_here, n, kind, opts, plan,
                fusion_depth, signs, scratch, stats,
            );
        }
        ChunkStage::RotateAmax { amax } => {
            execute_range(
                payload, start_row, rows_here, n, kind, opts, plan,
                fusion_depth, signs, scratch, stats,
            );
            amax.merge(amax_range(payload, start_row, rows_here, n));
        }
        ChunkStage::RotateGroupQuant { group, scales } => {
            execute_range(
                payload, start_row, rows_here, n, kind, opts, plan,
                fusion_depth, signs, scratch, stats,
            );
            group_quant_range(payload, start_row, rows_here, n, *group, scales.0);
        }
        // phase 2 of per-tensor FP8: the prologue already ran in phase 1
        ChunkStage::QuantFp8 { scale, fmt } => {
            quant_fp8_range(payload, start_row, rows_here, n, *scale, *fmt);
        }
    }
}

/// The inline (non-sharded) path: transform the whole buffer as one
/// chunk, then run the epilogue over it. Returns the epilogue's scales.
///
/// # Safety
///
/// Same contract as [`execute_range`] with `start_row = 0` and
/// `rows_here = rows`.
#[allow(clippy::too_many_arguments)]
unsafe fn run_inline(
    payload: Payload,
    rows: usize,
    n: usize,
    kind: KernelKind,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
    stats: &ExecStats,
    signs: Option<&[f32]>,
    epilogue: Epilogue,
    scratch: &mut Vec<f32>,
) -> QuantScales {
    execute_range(
        payload, 0, rows, n, kind, opts, plan, fusion_depth, signs, scratch,
        stats,
    );
    match epilogue {
        Epilogue::None => QuantScales::None,
        Epilogue::QuantFp8 { fmt } => {
            let amax = amax_range(payload, 0, rows, n);
            if amax == 0.0 {
                return QuantScales::PerTensor(1.0);
            }
            let scale = amax / fmt.max_finite();
            quant_fp8_range(payload, 0, rows, n, scale, fmt);
            QuantScales::PerTensor(scale)
        }
        Epilogue::QuantInt8 { group } => {
            // same recycled source as the pooled path above
            let mut scales =
                crate::util::pool::scale_pool().get_zeroed(rows * n / group);
            group_quant_range(payload, 0, rows, n, group, scales.as_mut_ptr());
            QuantScales::PerGroup(scales)
        }
    }
}

/// Max-abs over the addressed range, widening 16-bit storage. `max` over
/// a finite nonnegative set is exact under any association, so per-chunk
/// maxima merged through [`AmaxCell`] equal the sequential fold of the
/// unfused reference bit-for-bit (NaNs are ignored by `f32::max` on both
/// paths).
///
/// # Safety
///
/// Same addressing contract as [`execute_range`] (shared access
/// suffices — this stage only reads).
unsafe fn amax_range(
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
) -> f32 {
    let offset = start_row * n;
    let len = rows_here * n;
    match payload {
        Payload::F32(base) => {
            amax_slice(std::slice::from_raw_parts(base.add(offset), len))
        }
        Payload::F16(base) => {
            amax_slice(std::slice::from_raw_parts(base.add(offset), len))
        }
        Payload::BF16(base) => {
            amax_slice(std::slice::from_raw_parts(base.add(offset), len))
        }
    }
}

/// Phase-2 per-tensor FP8 rounding of the addressed range.
///
/// # Safety
///
/// Same addressing contract as [`execute_range`].
unsafe fn quant_fp8_range(
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
    scale: f32,
    fmt: Fp8Format,
) {
    let offset = start_row * n;
    let len = rows_here * n;
    match payload {
        Payload::F32(base) => fp8_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            scale,
            fmt,
        ),
        Payload::F16(base) => fp8_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            scale,
            fmt,
        ),
        Payload::BF16(base) => fp8_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            scale,
            fmt,
        ),
    }
}

/// Grouped-INT8 quantisation of the addressed range; group `g`'s scale
/// lands in `scales_base.add(g)`. Chunks cover whole rows and `group`
/// divides `n`, so `offset` is group-aligned and distinct chunks write
/// disjoint scale slots.
///
/// # Safety
///
/// Same addressing contract as [`execute_range`]; `scales_base` must
/// address `rows * n / group` slots valid for the duration, with this
/// chunk's slot range untouched by other threads.
unsafe fn group_quant_range(
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
    group: usize,
    scales_base: *mut f32,
) {
    let offset = start_row * n;
    let len = rows_here * n;
    let scales =
        std::slice::from_raw_parts_mut(scales_base.add(offset / group), len / group);
    match payload {
        Payload::F32(base) => int_group_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            group,
            IntBits::Int8,
            scales,
        ),
        Payload::F16(base) => int_group_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            group,
            IntBits::Int8,
            scales,
        ),
        Payload::BF16(base) => int_group_apply_slice(
            std::slice::from_raw_parts_mut(base.add(offset), len),
            group,
            IntBits::Int8,
            scales,
        ),
    }
}

fn run_f32_slice(
    kind: KernelKind,
    data: &mut [f32],
    n: usize,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
) {
    match (&plan.hadacore, kind) {
        (Some(hp), KernelKind::HadaCore) => {
            fwht_hadacore_f32_planned_depth(data, hp, opts, fusion_depth)
        }
        _ => fwht_f32(kind, data, n, opts),
    }
}

/// The 16-bit chunk path with the reusable workspace: widen into
/// `scratch`, transform in f32, narrow back with round-to-nearest-even.
/// A sign-flip prologue rides the widening copy (16-bit → f32 widening
/// is exact and ±1.0 multiply is exact, so fused == premultiplied
/// bit-for-bit). Capacity growth (an allocation) is counted; in steady
/// state the counter is flat.
#[allow(clippy::too_many_arguments)]
fn widen_run_narrow<E: Element>(
    kind: KernelKind,
    data: &mut [E],
    n: usize,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    fusion_depth: usize,
    signs: Option<&[f32]>,
    scratch: &mut Vec<f32>,
    stats: &ExecStats,
) {
    let cap_before = scratch.capacity();
    scratch.clear();
    match signs {
        // chunks cover whole rows, so data.len() is a multiple of n and
        // the cycled sign vector stays row-aligned
        Some(s) => scratch.extend(
            data.iter().zip(s.iter().cycle()).map(|(v, sg)| v.to_f32() * sg),
        ),
        None => scratch.extend(data.iter().map(|v| v.to_f32())),
    }
    run_f32_slice(kind, scratch.as_mut_slice(), n, opts, plan, fusion_depth);
    for (dst, src) in data.iter_mut().zip(scratch.iter()) {
        *dst = E::from_f32(*src);
    }
    if scratch.capacity() != cap_before {
        stats.scratch_grows.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::fwht_generic;
    use crate::util::rng::Rng;

    fn pooled() -> ExecEngine {
        ExecEngine::new(ExecConfig {
            threads: 4,
            chunks_per_thread: 2,
            min_chunk_elems: 1024, // shard even smallish test batches
            ..ExecConfig::default()
        })
    }

    #[test]
    fn pooled_f32_is_bit_identical_to_direct() {
        let engine = pooled();
        let mut rng = Rng::new(1);
        for (rows, n) in [(1usize, 256usize), (7, 512), (33, 1024), (64, 4096)] {
            let x = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            for kind in KernelKind::all() {
                let mut direct = x.clone();
                crate::hadamard::fwht_f32(kind, &mut direct, n, &opts);
                let mut sharded = x.clone();
                engine.run_f32(kind, &mut sharded, n, &opts);
                assert_eq!(direct, sharded, "kind={kind:?} rows={rows} n={n}");
            }
        }
        assert!(engine.stats().jobs > 0, "large batches must use the pool");
    }

    /// The scatter-gather path must be bit-identical to gathering the
    /// same rows into one contiguous batch — both sharded (pool) and
    /// inline, with and without a sign-flip prologue.
    #[test]
    fn regions_are_bit_identical_to_gathered() {
        let mut rng = Rng::new(7);
        let n = 1024usize;
        for (engine, prologue) in [
            (pooled(), Prologue::None),
            (pooled(), Prologue::SignFlip { seed: 0x5eed }),
            (ExecEngine::single_threaded(), Prologue::SignFlip { seed: 9 }),
        ] {
            // uneven region heights so chunks straddle region boundaries
            let mut bufs: Vec<Vec<f32>> = [3usize, 8, 1, 5]
                .iter()
                .map(|&rows| rng.normal_vec(rows * n))
                .collect();
            let mut gathered: Vec<f32> =
                bufs.iter().flat_map(|b| b.iter().copied()).collect();
            let opts = FwhtOptions::normalized(n);
            engine.run_f32_with_stages(
                KernelKind::HadaCore,
                &mut gathered,
                n,
                &opts,
                prologue,
                Epilogue::None,
            );
            let regions: Vec<RowRegion> = bufs
                .iter_mut()
                .map(|b| RowRegion { ptr: b.as_mut_ptr(), rows: b.len() / n })
                .collect();
            // SAFETY: each region points at its own live Vec, regions are
            // disjoint, and `bufs` outlives the blocking call.
            unsafe {
                engine.run_f32_regions(
                    KernelKind::HadaCore,
                    &regions,
                    n,
                    &opts,
                    prologue,
                );
            }
            let scattered: Vec<f32> =
                bufs.iter().flat_map(|b| b.iter().copied()).collect();
            assert_eq!(gathered, scattered, "prologue={prologue:?}");
        }
    }

    #[test]
    fn pooled_16bit_is_bit_identical_to_direct() {
        let engine = pooled();
        let mut rng = Rng::new(2);
        let (rows, n) = (33usize, 512usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let base16: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let mut direct = base16.clone();
        fwht_generic(KernelKind::HadaCore, &mut direct, n, &opts);
        let mut sharded = base16;
        engine.run(KernelKind::HadaCore, &mut sharded, n, &opts);
        assert_eq!(direct, sharded);

        let basebf: Vec<BF16> = x.iter().map(|&v| BF16::from_f32(v)).collect();
        let mut direct = basebf.clone();
        fwht_generic(KernelKind::Dao, &mut direct, n, &opts);
        let mut sharded = basebf;
        engine.run(KernelKind::Dao, &mut sharded, n, &opts);
        assert_eq!(direct, sharded);
    }

    #[test]
    fn small_batches_run_inline() {
        let engine = pooled();
        let n = 256;
        let mut data = vec![1.0f32; n]; // one row, far below min_chunk_elems
        engine.run_f32(KernelKind::HadaCore, &mut data, n, &FwhtOptions::raw());
        let s = engine.stats();
        assert_eq!(s.jobs, 0);
        assert_eq!(s.inline_runs, 1);
        // raw transform of all-ones: first element n, rest 0
        assert!((data[0] - n as f32).abs() < 1e-3);
        assert!(data[1..].iter().all(|v| v.abs() < 1e-3));
    }

    #[test]
    fn single_threaded_engine_has_no_pool() {
        let engine = ExecEngine::single_threaded();
        assert_eq!(engine.threads(), 1);
        let mut rng = Rng::new(3);
        let (rows, n) = (16usize, 1024usize);
        let x = rng.normal_vec(rows * n);
        let mut got = x.clone();
        engine.run_f32(KernelKind::HadaCore, &mut got, n, &FwhtOptions::raw());
        let mut want = x;
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::raw(),
        );
        assert_eq!(got, want);
        assert_eq!(engine.stats().jobs, 0);
    }

    #[test]
    fn scratch_allocation_is_bounded_in_steady_state() {
        let engine = pooled();
        let mut rng = Rng::new(4);
        let (rows, n) = (32usize, 1024usize);
        let base: Vec<F16> = rng
            .normal_vec(rows * n)
            .iter()
            .map(|&v| F16::from_f32(v))
            .collect();
        let opts = FwhtOptions::normalized(n);
        for _ in 0..20 {
            let mut batch = base.clone();
            engine.run(KernelKind::HadaCore, &mut batch, n, &opts);
        }
        let s = engine.stats();
        // every worker grows its workspace at most once for a fixed batch
        // shape; everything after warmup reuses it
        assert!(
            s.scratch_grows <= engine.threads() as u64,
            "scratch grew {} times across {} chunks — not reusing workspaces",
            s.scratch_grows,
            s.chunks,
        );
        assert!(s.chunks > s.scratch_grows, "chunks must vastly outnumber grows");
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let engine = std::sync::Arc::new(pooled());
        let mut rng = Rng::new(5);
        let n = 512;
        let inputs: Vec<Vec<f32>> =
            (0..8).map(|_| rng.normal_vec(16 * n)).collect();
        let outputs: Vec<Vec<f32>> = std::thread::scope(|s| {
            inputs
                .iter()
                .map(|x| {
                    let engine = std::sync::Arc::clone(&engine);
                    s.spawn(move || {
                        let mut data = x.clone();
                        engine.run_f32(
                            KernelKind::HadaCore,
                            &mut data,
                            n,
                            &FwhtOptions::normalized(n),
                        );
                        data
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (x, got) in inputs.iter().zip(outputs.iter()) {
            let mut want = x.clone();
            crate::hadamard::fwht_f32(
                KernelKind::HadaCore,
                &mut want,
                n,
                &FwhtOptions::normalized(n),
            );
            assert_eq!(&want, got);
        }
    }

    #[test]
    fn fused_fp8_matches_unfused_two_pass() {
        let engine = pooled();
        let mut rng = Rng::new(11);
        let (rows, n) = (33usize, 1024usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        for kind in KernelKind::all() {
            let mut unfused = x.clone();
            engine.run_f32(kind, &mut unfused, n, &opts);
            let want_scale =
                crate::quant::fp8_quantize_slice(&mut unfused, Fp8Format::E4M3);

            let mut fused = x.clone();
            let scales = engine.run_with_epilogue(
                kind,
                &mut fused,
                n,
                &opts,
                Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
            );
            assert_eq!(scales, QuantScales::PerTensor(want_scale), "kind={kind:?}");
            assert_eq!(unfused, fused, "kind={kind:?}");
        }
        let s = engine.stats();
        assert_eq!(s.epilogue_runs, KernelKind::all().len() as u64);
        assert!(s.jobs > 0, "a 33x1024 batch must shard on this engine");
    }

    #[test]
    fn fused_fp8_16bit_matches_widened_reference() {
        let engine = pooled();
        let mut rng = Rng::new(12);
        let (rows, n) = (17usize, 512usize);
        let x = rng.normal_vec(rows * n);
        let base: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let opts = FwhtOptions::normalized(n);

        let mut unfused = base.clone();
        engine.run(KernelKind::HadaCore, &mut unfused, n, &opts);
        let mut widened: Vec<f32> = unfused.iter().map(|v| v.to_f32()).collect();
        let want_scale =
            crate::quant::fp8_quantize_slice(&mut widened, Fp8Format::E5M2);
        let want: Vec<F16> = widened.iter().map(|&v| F16::from_f32(v)).collect();

        let mut fused = base;
        let scales = engine.run_with_epilogue(
            KernelKind::HadaCore,
            &mut fused,
            n,
            &opts,
            Epilogue::QuantFp8 { fmt: Fp8Format::E5M2 },
        );
        assert_eq!(scales, QuantScales::PerTensor(want_scale));
        assert_eq!(want, fused);
    }

    #[test]
    fn fused_int8_group_matches_reference() {
        let engine = pooled();
        let mut rng = Rng::new(13);
        let (rows, n, group) = (19usize, 512usize, 64usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let mut unfused = x.clone();
        engine.run_f32(KernelKind::Dao, &mut unfused, n, &opts);
        let want_scales =
            crate::quant::int_quantize_grouped(&mut unfused, group, IntBits::Int8);

        let mut fused = x;
        let scales = engine.run_with_epilogue(
            KernelKind::Dao,
            &mut fused,
            n,
            &opts,
            Epilogue::QuantInt8 { group },
        );
        assert_eq!(scales, QuantScales::PerGroup(want_scales));
        assert_eq!(unfused, fused);
    }

    #[test]
    fn fused_prologue_matches_premultiplied_reference() {
        // the sign-flip prologue fused into the chunk traversal must be
        // bit-identical to applying D over the whole buffer first and
        // then running the plain engine — sharded and inline alike
        let engine = pooled();
        let mut rng = Rng::new(21);
        let seed = 0xD1A6_0001u64;
        for (rows, n) in [(1usize, 256usize), (33, 1024), (9, 4096)] {
            let x = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            let signs = crate::hadamard::sign_vector(seed, n);
            for kind in KernelKind::all() {
                let mut unfused = x.clone();
                apply_signs(&mut unfused, &signs);
                engine.run_f32(kind, &mut unfused, n, &opts);

                let mut fused = x.clone();
                engine.run_f32_with_stages(
                    kind,
                    &mut fused,
                    n,
                    &opts,
                    Prologue::SignFlip { seed },
                    Epilogue::None,
                );
                assert_eq!(unfused, fused, "kind={kind:?} rows={rows} n={n}");
            }
        }
        let s = engine.stats();
        assert_eq!(s.prologue_runs, 3 * KernelKind::all().len() as u64);
        assert!(s.jobs > 0, "the 33x1024 batches must shard on this engine");
        assert!(s.inline_runs > 0, "the 1x256 batches must run inline");
    }

    #[test]
    fn fused_prologue_16bit_rides_the_widening_copy() {
        // 16-bit storage: the fused flip happens on the widened f32
        // values; the reference flips the narrow values up front. Both
        // are exact (±1 multiply commutes with exact widening), so the
        // outputs must agree bit for bit.
        let engine = pooled();
        let mut rng = Rng::new(22);
        let seed = 0xD1A6_0002u64;
        let (rows, n) = (33usize, 512usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        let signs = crate::hadamard::sign_vector(seed, n);

        let base16: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let mut unfused: Vec<F16> = base16
            .iter()
            .zip(signs.iter().cycle())
            .map(|(v, sg)| F16::from_f32(v.to_f32() * sg))
            .collect();
        engine.run(KernelKind::HadaCore, &mut unfused, n, &opts);
        let mut fused = base16;
        engine.run_with_stages(
            KernelKind::HadaCore,
            &mut fused,
            n,
            &opts,
            Prologue::SignFlip { seed },
            Epilogue::None,
        );
        assert_eq!(unfused, fused);

        let basebf: Vec<BF16> = x.iter().map(|&v| BF16::from_f32(v)).collect();
        let mut unfused: Vec<BF16> = basebf
            .iter()
            .zip(signs.iter().cycle())
            .map(|(v, sg)| BF16::from_f32(v.to_f32() * sg))
            .collect();
        engine.run(KernelKind::Dao, &mut unfused, n, &opts);
        let mut fused = basebf;
        engine.run_with_stages(
            KernelKind::Dao,
            &mut fused,
            n,
            &opts,
            Prologue::SignFlip { seed },
            Epilogue::None,
        );
        assert_eq!(unfused, fused);
    }

    #[test]
    fn prologue_composes_with_fused_epilogues() {
        // rotate-with-prologue + quantize epilogue in one engine call
        // equals the unfused premultiply + plain epilogue run
        let engine = pooled();
        let mut rng = Rng::new(23);
        let seed = 0xD1A6_0003u64;
        let (rows, n, group) = (19usize, 512usize, 64usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        let signs = crate::hadamard::sign_vector(seed, n);

        for epilogue in [
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
            Epilogue::QuantInt8 { group },
        ] {
            let mut unfused = x.clone();
            apply_signs(&mut unfused, &signs);
            let want_scales = engine.run_f32_with_epilogue(
                KernelKind::HadaCore,
                &mut unfused,
                n,
                &opts,
                epilogue,
            );

            let mut fused = x.clone();
            let scales = engine.run_f32_with_stages(
                KernelKind::HadaCore,
                &mut fused,
                n,
                &opts,
                Prologue::SignFlip { seed },
                epilogue,
            );
            assert_eq!(scales, want_scales, "{epilogue:?}");
            assert_eq!(unfused, fused, "{epilogue:?}");
        }
    }

    #[test]
    fn fused_epilogue_inline_path() {
        // one small row runs inline; the epilogue must still apply
        let engine = pooled();
        let n = 256;
        let mut data = vec![1.0f32; n];
        let scales = engine.run_with_epilogue(
            KernelKind::HadaCore,
            &mut data,
            n,
            &FwhtOptions::raw(),
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
        );
        // raw all-ones transform: amax = n = 256 > 448? no: scale = 256/448
        let scale = 256.0 / 448.0;
        assert_eq!(scales, QuantScales::PerTensor(scale));
        let s = engine.stats();
        assert_eq!(s.inline_runs, 1);
        assert_eq!(s.jobs, 0);
        assert_eq!(s.epilogue_runs, 1);
    }

    #[test]
    fn fused_fp8_zero_batch_scale_is_one() {
        let engine = pooled();
        let (rows, n) = (33usize, 512usize);
        let mut data = vec![0.0f32; rows * n];
        let scales = engine.run_with_epilogue(
            KernelKind::HadaCore,
            &mut data,
            n,
            &FwhtOptions::normalized(n),
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
        );
        assert_eq!(scales, QuantScales::PerTensor(1.0));
        assert!(data.iter().all(|v| *v == 0.0));
    }

    #[test]
    #[should_panic(expected = "invalid epilogue")]
    fn misaligned_group_panics() {
        let engine = ExecEngine::single_threaded();
        let mut data = vec![0.0f32; 256];
        engine.run_with_epilogue(
            KernelKind::HadaCore,
            &mut data,
            256,
            &FwhtOptions::raw(),
            Epilogue::QuantInt8 { group: 48 },
        );
    }

    #[test]
    fn concurrent_inline_16bit_batches_stay_correct() {
        // small f16 batches run inline on the submitting threads; each
        // thread uses its own thread-local workspace (no shared lock)
        let engine = std::sync::Arc::new(pooled());
        let mut rng = Rng::new(14);
        let n = 256; // one row: far below the sharding threshold
        let inputs: Vec<Vec<F16>> = (0..8)
            .map(|_| {
                rng.normal_vec(n).iter().map(|&v| F16::from_f32(v)).collect()
            })
            .collect();
        let opts = FwhtOptions::normalized(n);
        let outputs: Vec<Vec<F16>> = std::thread::scope(|s| {
            inputs
                .iter()
                .map(|x| {
                    let engine = std::sync::Arc::clone(&engine);
                    s.spawn(move || {
                        let mut data = x.clone();
                        engine.run(KernelKind::HadaCore, &mut data, n, &opts);
                        data
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (x, got) in inputs.iter().zip(outputs.iter()) {
            let mut want = x.clone();
            fwht_generic(KernelKind::HadaCore, &mut want, n, &opts);
            assert_eq!(&want, got);
        }
        assert_eq!(engine.stats().inline_runs, 8);
    }

    #[test]
    fn inline_scratch_retention_is_bounded() {
        // a pool-less engine runs even huge 16-bit batches inline; the
        // widening buffer must not stay pinned past the retention cap
        let engine = ExecEngine::single_threaded();
        let n = 1 << 15;
        let rows = 130; // 130 * 32768 = 4.26M elems > INLINE_SCRATCH_RETAIN_ELEMS
        assert!(rows * n > INLINE_SCRATCH_RETAIN_ELEMS);
        let mut data: Vec<F16> = vec![F16::from_f32(1.0); rows * n];
        engine.run(KernelKind::Dao, &mut data, n, &FwhtOptions::normalized(n));
        INLINE_SCRATCH.with(|cell| {
            assert!(
                cell.borrow().capacity() <= INLINE_SCRATCH_RETAIN_ELEMS,
                "inline scratch retained {} elems",
                cell.borrow().capacity()
            );
        });
    }

    #[test]
    fn chunk_rows_policy() {
        let engine = ExecEngine::new(ExecConfig {
            threads: 8,
            chunks_per_thread: 4,
            min_chunk_elems: 1 << 14,
            ..ExecConfig::default()
        });
        // balance: 256 rows over 32 target chunks
        assert_eq!(engine.chunk_rows_for(256, 4096), 8);
        // floor: chunks never smaller than min_chunk_elems
        assert_eq!(engine.chunk_rows_for(256, 256), 64);
        // tiny batches: one chunk
        assert_eq!(engine.chunk_rows_for(1, 256), 64);
    }

    #[test]
    fn forced_fusion_depths_are_bit_identical_through_the_engine() {
        // every config-forced depth must reproduce the depth-1 engine
        // output bit for bit, sharded and inline alike
        let mut rng = Rng::new(0xF1);
        for (rows, n) in [(33usize, 1024usize), (1, 4096), (5, 14336)] {
            let x = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            let mut want = x.clone();
            fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
            for depth in 1..=4 {
                let engine = ExecEngine::new(ExecConfig {
                    threads: 4,
                    chunks_per_thread: 2,
                    min_chunk_elems: 1024,
                    tune: TunePolicy::FixedDepth(depth),
                });
                let mut got = x.clone();
                engine.run_f32(KernelKind::HadaCore, &mut got, n, &opts);
                assert_eq!(want, got, "rows={rows} n={n} depth={depth}");
                if depth > 1 {
                    assert_eq!(engine.stats().fused_runs, 1, "depth={depth}");
                }
            }
        }
    }

    #[test]
    fn tuned_default_engine_matches_direct_kernels() {
        // the measured policy may pick any depth — outputs must still be
        // bit-identical to the direct (unfused) kernel call
        let engine = pooled();
        let mut rng = Rng::new(0xF2);
        let (rows, n) = (17usize, 8192usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        for kind in KernelKind::all() {
            let mut direct = x.clone();
            fwht_f32(kind, &mut direct, n, &opts);
            let mut tuned = x.clone();
            engine.run_f32(kind, &mut tuned, n, &opts);
            assert_eq!(direct, tuned, "kind={kind:?}");
        }
    }
}
