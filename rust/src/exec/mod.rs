//! Batched multi-threaded execution engine.
//!
//! The serving layers below this module execute every batch on the
//! thread that picked it up, and the 16-bit path allocates a fresh f32
//! widening buffer per call — exactly the compute- and exchange-overhead
//! the paper restructures the transform to remove. This module is the
//! CPU-side answer, three pieces (design details in
//! `docs/ARCHITECTURE.md`):
//!
//! * `pool` (private) — a persistent std-thread worker pool. A `rows x n` batch
//!   is sharded into row chunks; workers claim chunks under one lock
//!   (chunk-granular work stealing), and the submitter blocks on a
//!   completion latch. Small batches never pay the handoff: below the
//!   sharding threshold they run inline on the submitting thread.
//! * **per-thread workspaces** — each worker owns a reusable f32 scratch
//!   buffer, so the f16/bf16 widen-compute-narrow path performs no heap
//!   allocation in steady state ([`ExecStats::scratch_grows`] counts the
//!   warmup growths and then stays flat).
//! * [`plan`] — a process-wide cache memoizing the per-size round
//!   structure (Sylvester factorisation, stride tables, §3.3 residual
//!   factor), so per-batch dispatch rebuilds nothing.
//!
//! ```no_run
//! use hadacore::exec::ExecEngine;
//! use hadacore::hadamard::{FwhtOptions, KernelKind};
//!
//! let engine = ExecEngine::default(); // one lane per core (capped at 16)
//! let (rows, n) = (256, 4096);
//! let mut batch = vec![1.0f32; rows * n];
//! engine.run(KernelKind::HadaCore, &mut batch, n, &FwhtOptions::normalized(n));
//! ```

pub mod plan;
mod pool;

pub use plan::{cached_plan_count, plan_for, ExecPlan};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::hadamard::hadacore::fwht_hadacore_f32_planned;
use crate::hadamard::{fwht_f32, validate_dims, FwhtOptions, KernelKind};
use crate::util::f16::{Element, BF16, F16};

use pool::{JobSpec, WorkerPool};

/// A batch buffer's base pointer, tagged with its storage dtype so it can
/// cross the worker-thread boundary. Implementation detail of the
/// engine's sharding; public only because [`ExecElement`] mentions it.
#[doc(hidden)]
#[derive(Clone, Copy)]
pub enum Payload {
    F32(*mut f32),
    F16(*mut F16),
    BF16(*mut BF16),
}

// SAFETY: a Payload is only ever dereferenced through `execute_range`,
// whose callers guarantee exclusive, disjoint access (see pool.rs).
unsafe impl Send for Payload {}

/// Storage dtypes the engine can execute: `f32` directly, [`F16`] and
/// [`BF16`] through the per-thread f32 workspace.
pub trait ExecElement: Element {
    #[doc(hidden)]
    fn payload(base: *mut Self) -> Payload;
}

impl ExecElement for f32 {
    fn payload(base: *mut Self) -> Payload {
        Payload::F32(base)
    }
}

impl ExecElement for F16 {
    fn payload(base: *mut Self) -> Payload {
        Payload::F16(base)
    }
}

impl ExecElement for BF16 {
    fn payload(base: *mut Self) -> Payload {
        Payload::BF16(base)
    }
}

/// Engine counters (all monotonically increasing).
#[derive(Debug, Default)]
pub struct ExecStats {
    /// Batches sharded across the worker pool.
    pub jobs: AtomicU64,
    /// Batches executed inline on the submitting thread (too small to
    /// shard, or a single-threaded engine).
    pub inline_runs: AtomicU64,
    /// Chunks executed (an inline run counts as one chunk).
    pub chunks: AtomicU64,
    /// Growth events of the reusable f32 workspaces. Flat counter ==
    /// zero-allocation steady state on the 16-bit path.
    pub scratch_grows: AtomicU64,
}

/// Point-in-time copy of [`ExecStats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecStatsSnapshot {
    pub jobs: u64,
    pub inline_runs: u64,
    pub chunks: u64,
    pub scratch_grows: u64,
}

impl ExecStats {
    fn snapshot(&self) -> ExecStatsSnapshot {
        ExecStatsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            inline_runs: self.inline_runs.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            scratch_grows: self.scratch_grows.load(Ordering::Relaxed),
        }
    }
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    /// Compute lanes (worker threads). `1` runs everything inline on the
    /// submitting thread with no pool.
    pub threads: usize,
    /// Target chunks per lane per batch. More chunks balance uneven
    /// progress better; fewer chunks lower claim overhead.
    pub chunks_per_thread: usize,
    /// Minimum elements per chunk. Batches smaller than one chunk run
    /// inline — the thread handoff costs more than the transform.
    pub min_chunk_elems: usize,
}

impl Default for ExecConfig {
    /// One lane per available core, capped at 16 — the transform is
    /// memory-bound well before that on typical hosts; raise `threads`
    /// explicitly to use more.
    fn default() -> Self {
        ExecConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(16),
            chunks_per_thread: 4,
            min_chunk_elems: 1 << 14, // 16K elements = 64 KiB of f32
        }
    }
}

/// The batched execution engine. One instance owns one worker pool;
/// cheap to share behind an [`Arc`] — every method takes `&self`.
pub struct ExecEngine {
    cfg: ExecConfig,
    pool: Option<WorkerPool>,
    inline_scratch: Mutex<Vec<f32>>,
    stats: Arc<ExecStats>,
}

impl Default for ExecEngine {
    fn default() -> Self {
        ExecEngine::new(ExecConfig::default())
    }
}

impl ExecEngine {
    /// Start an engine (spawns `cfg.threads` workers when `> 1`).
    pub fn new(cfg: ExecConfig) -> ExecEngine {
        let cfg = ExecConfig { threads: cfg.threads.max(1), ..cfg };
        let stats = Arc::new(ExecStats::default());
        let pool = (cfg.threads > 1)
            .then(|| WorkerPool::new(cfg.threads, Arc::clone(&stats)));
        ExecEngine { cfg, pool, inline_scratch: Mutex::new(Vec::new()), stats }
    }

    /// An engine with no pool: every batch runs inline on the caller.
    /// The single-thread baseline the benches compare against, and the
    /// deterministic-scheduling arm of the parity tests.
    pub fn single_threaded() -> ExecEngine {
        ExecEngine::new(ExecConfig { threads: 1, ..ExecConfig::default() })
    }

    /// Configured lane count.
    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ExecStatsSnapshot {
        self.stats.snapshot()
    }

    /// Transform every `n`-sized row of `data` in place with `kind`,
    /// sharding across the worker pool when the batch is large enough.
    ///
    /// Bit-identical to calling the kernel directly on the whole buffer
    /// (row transforms are independent, and the HadaCore plan replays the
    /// exact pass structure of the unplanned path).
    ///
    /// Panics if `data.len()` is not a `rows * n` multiple or `n` is not
    /// a supported power of two — callers on the serving path have
    /// already validated via the router.
    pub fn run<E: ExecElement>(
        &self,
        kind: KernelKind,
        data: &mut [E],
        n: usize,
        opts: &FwhtOptions,
    ) {
        let rows = validate_dims(data.len(), n).expect("invalid dimensions");
        let plan = plan_for(kind, n);
        let chunk_rows = self.chunk_rows_for(rows, n);
        let chunks = (rows + chunk_rows - 1) / chunk_rows;
        match &self.pool {
            Some(pool) if chunks > 1 => {
                self.stats.jobs.fetch_add(1, Ordering::Relaxed);
                let spec = JobSpec {
                    payload: E::payload(data.as_mut_ptr()),
                    rows,
                    n,
                    chunk_rows,
                    kind,
                    opts: *opts,
                    plan,
                };
                // SAFETY: `data` is a `&mut` borrow we hold for the whole
                // call, covering exactly `rows * n` elements.
                unsafe { pool.submit_and_wait(spec) };
            }
            _ => {
                self.stats.inline_runs.fetch_add(1, Ordering::Relaxed);
                let payload = E::payload(data.as_mut_ptr());
                match payload {
                    // f32 never touches scratch — skip the shared lock so
                    // concurrent submitters' small batches stay parallel
                    Payload::F32(_) => {
                        let mut unused = Vec::new();
                        // SAFETY: whole buffer as one chunk, under our `&mut`.
                        unsafe {
                            execute_range(
                                payload,
                                0,
                                rows,
                                n,
                                kind,
                                opts,
                                &plan,
                                &mut unused,
                                &self.stats,
                            );
                        }
                    }
                    _ => {
                        let mut scratch = self.inline_scratch.lock().unwrap();
                        // SAFETY: whole buffer as one chunk, under our `&mut`.
                        unsafe {
                            execute_range(
                                payload,
                                0,
                                rows,
                                n,
                                kind,
                                opts,
                                &plan,
                                &mut scratch,
                                &self.stats,
                            );
                        }
                    }
                }
            }
        }
    }

    /// [`ExecEngine::run`] monomorphised for `f32` — the coordinator's
    /// native-batch entry point.
    pub fn run_f32(
        &self,
        kind: KernelKind,
        data: &mut [f32],
        n: usize,
        opts: &FwhtOptions,
    ) {
        self.run::<f32>(kind, data, n, opts);
    }

    /// Rows per chunk for a `rows x n` batch: enough chunks to balance
    /// the lanes, but never chunks smaller than `min_chunk_elems`.
    fn chunk_rows_for(&self, rows: usize, n: usize) -> usize {
        let target_chunks =
            (self.cfg.threads * self.cfg.chunks_per_thread.max(1)).max(1);
        let by_balance = (rows + target_chunks - 1) / target_chunks;
        let min_rows = (self.cfg.min_chunk_elems + n - 1) / n;
        by_balance.max(min_rows).max(1)
    }
}

/// Execute rows `[start_row, start_row + rows_here)` of a payload buffer:
/// direct for f32, widen-compute-narrow through `scratch` for 16-bit
/// storage. Shared by pool workers and the inline path.
///
/// # Safety
///
/// `payload` must point at a buffer of at least
/// `(start_row + rows_here) * n` elements of the tagged dtype, and no
/// other thread may access the addressed row range for the duration.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn execute_range(
    payload: Payload,
    start_row: usize,
    rows_here: usize,
    n: usize,
    kind: KernelKind,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    scratch: &mut Vec<f32>,
    stats: &ExecStats,
) {
    let offset = start_row * n;
    let len = rows_here * n;
    stats.chunks.fetch_add(1, Ordering::Relaxed);
    match payload {
        Payload::F32(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            run_f32_slice(kind, data, n, opts, plan);
        }
        Payload::F16(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            widen_run_narrow(kind, data, n, opts, plan, scratch, stats);
        }
        Payload::BF16(base) => {
            let data = std::slice::from_raw_parts_mut(base.add(offset), len);
            widen_run_narrow(kind, data, n, opts, plan, scratch, stats);
        }
    }
}

fn run_f32_slice(
    kind: KernelKind,
    data: &mut [f32],
    n: usize,
    opts: &FwhtOptions,
    plan: &ExecPlan,
) {
    match (&plan.hadacore, kind) {
        (Some(hp), KernelKind::HadaCore) => fwht_hadacore_f32_planned(data, hp, opts),
        _ => fwht_f32(kind, data, n, opts),
    }
}

/// The 16-bit chunk path with the reusable workspace: widen into
/// `scratch`, transform in f32, narrow back with round-to-nearest-even.
/// Capacity growth (an allocation) is counted; in steady state the
/// counter is flat.
fn widen_run_narrow<E: Element>(
    kind: KernelKind,
    data: &mut [E],
    n: usize,
    opts: &FwhtOptions,
    plan: &ExecPlan,
    scratch: &mut Vec<f32>,
    stats: &ExecStats,
) {
    let cap_before = scratch.capacity();
    scratch.clear();
    scratch.extend(data.iter().map(|v| v.to_f32()));
    run_f32_slice(kind, scratch.as_mut_slice(), n, opts, plan);
    for (dst, src) in data.iter_mut().zip(scratch.iter()) {
        *dst = E::from_f32(*src);
    }
    if scratch.capacity() != cap_before {
        stats.scratch_grows.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::fwht_generic;
    use crate::util::rng::Rng;

    fn pooled() -> ExecEngine {
        ExecEngine::new(ExecConfig {
            threads: 4,
            chunks_per_thread: 2,
            min_chunk_elems: 1024, // shard even smallish test batches
        })
    }

    #[test]
    fn pooled_f32_is_bit_identical_to_direct() {
        let engine = pooled();
        let mut rng = Rng::new(1);
        for (rows, n) in [(1usize, 256usize), (7, 512), (33, 1024), (64, 4096)] {
            let x = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            for kind in KernelKind::all() {
                let mut direct = x.clone();
                crate::hadamard::fwht_f32(kind, &mut direct, n, &opts);
                let mut sharded = x.clone();
                engine.run_f32(kind, &mut sharded, n, &opts);
                assert_eq!(direct, sharded, "kind={kind:?} rows={rows} n={n}");
            }
        }
        assert!(engine.stats().jobs > 0, "large batches must use the pool");
    }

    #[test]
    fn pooled_16bit_is_bit_identical_to_direct() {
        let engine = pooled();
        let mut rng = Rng::new(2);
        let (rows, n) = (33usize, 512usize);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let base16: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let mut direct = base16.clone();
        fwht_generic(KernelKind::HadaCore, &mut direct, n, &opts);
        let mut sharded = base16;
        engine.run(KernelKind::HadaCore, &mut sharded, n, &opts);
        assert_eq!(direct, sharded);

        let basebf: Vec<BF16> = x.iter().map(|&v| BF16::from_f32(v)).collect();
        let mut direct = basebf.clone();
        fwht_generic(KernelKind::Dao, &mut direct, n, &opts);
        let mut sharded = basebf;
        engine.run(KernelKind::Dao, &mut sharded, n, &opts);
        assert_eq!(direct, sharded);
    }

    #[test]
    fn small_batches_run_inline() {
        let engine = pooled();
        let n = 256;
        let mut data = vec![1.0f32; n]; // one row, far below min_chunk_elems
        engine.run_f32(KernelKind::HadaCore, &mut data, n, &FwhtOptions::raw());
        let s = engine.stats();
        assert_eq!(s.jobs, 0);
        assert_eq!(s.inline_runs, 1);
        // raw transform of all-ones: first element n, rest 0
        assert!((data[0] - n as f32).abs() < 1e-3);
        assert!(data[1..].iter().all(|v| v.abs() < 1e-3));
    }

    #[test]
    fn single_threaded_engine_has_no_pool() {
        let engine = ExecEngine::single_threaded();
        assert_eq!(engine.threads(), 1);
        let mut rng = Rng::new(3);
        let (rows, n) = (16usize, 1024usize);
        let x = rng.normal_vec(rows * n);
        let mut got = x.clone();
        engine.run_f32(KernelKind::HadaCore, &mut got, n, &FwhtOptions::raw());
        let mut want = x;
        crate::hadamard::fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::raw(),
        );
        assert_eq!(got, want);
        assert_eq!(engine.stats().jobs, 0);
    }

    #[test]
    fn scratch_allocation_is_bounded_in_steady_state() {
        let engine = pooled();
        let mut rng = Rng::new(4);
        let (rows, n) = (32usize, 1024usize);
        let base: Vec<F16> = rng
            .normal_vec(rows * n)
            .iter()
            .map(|&v| F16::from_f32(v))
            .collect();
        let opts = FwhtOptions::normalized(n);
        for _ in 0..20 {
            let mut batch = base.clone();
            engine.run(KernelKind::HadaCore, &mut batch, n, &opts);
        }
        let s = engine.stats();
        // every worker grows its workspace at most once for a fixed batch
        // shape; everything after warmup reuses it
        assert!(
            s.scratch_grows <= engine.threads() as u64,
            "scratch grew {} times across {} chunks — not reusing workspaces",
            s.scratch_grows,
            s.chunks,
        );
        assert!(s.chunks > s.scratch_grows, "chunks must vastly outnumber grows");
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let engine = std::sync::Arc::new(pooled());
        let mut rng = Rng::new(5);
        let n = 512;
        let inputs: Vec<Vec<f32>> =
            (0..8).map(|_| rng.normal_vec(16 * n)).collect();
        let outputs: Vec<Vec<f32>> = std::thread::scope(|s| {
            inputs
                .iter()
                .map(|x| {
                    let engine = std::sync::Arc::clone(&engine);
                    s.spawn(move || {
                        let mut data = x.clone();
                        engine.run_f32(
                            KernelKind::HadaCore,
                            &mut data,
                            n,
                            &FwhtOptions::normalized(n),
                        );
                        data
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (x, got) in inputs.iter().zip(outputs.iter()) {
            let mut want = x.clone();
            crate::hadamard::fwht_f32(
                KernelKind::HadaCore,
                &mut want,
                n,
                &FwhtOptions::normalized(n),
            );
            assert_eq!(&want, got);
        }
    }

    #[test]
    fn chunk_rows_policy() {
        let engine = ExecEngine::new(ExecConfig {
            threads: 8,
            chunks_per_thread: 4,
            min_chunk_elems: 1 << 14,
        });
        // balance: 256 rows over 32 target chunks
        assert_eq!(engine.chunk_rows_for(256, 4096), 8);
        // floor: chunks never smaller than min_chunk_elems
        assert_eq!(engine.chunk_rows_for(256, 256), 64);
        // tiny batches: one chunk
        assert_eq!(engine.chunk_rows_for(1, 256), 64);
    }
}
