//! The std-thread worker pool behind [`super::ExecEngine`].
//!
//! Persistent workers block on a condvar-guarded FIFO of jobs. A *job* is
//! one batch buffer sharded into fixed-height row chunks; workers claim
//! chunk indices one at a time under the queue lock (work stealing at
//! chunk granularity — a fast worker takes more chunks, so uneven chunk
//! costs still balance). The submitting thread blocks on the job's
//! completion latch, which is also the synchronisation edge that makes
//! the workers' writes visible to the submitter.
//!
//! What a chunk *does* is the job's [`ChunkStage`]: the plain transform,
//! a fused transform + amax / grouped-quant epilogue pass, or the
//! per-tensor scale+round phase. The engine's two-phase epilogue jobs
//! submit two specs back to back over the same chunk geometry — the
//! latch of phase 1 is the barrier that makes the global amax valid
//! before phase 2 starts claiming.
//!
//! Buffers cross the thread boundary as tagged raw base pointers
//! ([`super::Payload`]): the submitter holds the `&mut` borrow for the
//! whole call, chunk claims are unique by construction, and distinct
//! chunk indices address disjoint row ranges — so no two threads ever
//! touch the same element. Worker panics are caught and re-raised on the
//! submitting thread instead of deadlocking the latch.
//!
//! Each worker owns a reusable f32 scratch buffer for the 16-bit
//! widen-compute-narrow path; after the first few batches of a given
//! shape it never allocates again (steady-state zero-allocation — see
//! [`super::ExecStats::scratch_grows`]).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::hadamard::{FwhtOptions, KernelKind};

use super::plan::ExecPlan;
use super::{
    execute_regions_range, execute_stage, ChunkStage, ExecStats, Payload,
    RegionsRef,
};

/// Everything a worker needs to run one chunk or the submitter needs to
/// enqueue a batch.
pub(crate) struct JobSpec {
    /// Tagged base pointer of the batch buffer.
    pub payload: Payload,
    /// Total rows in the batch.
    pub rows: usize,
    /// Row length (Hadamard size).
    pub n: usize,
    /// Rows per chunk (last chunk may be short).
    pub chunk_rows: usize,
    /// Kernel to run.
    pub kind: KernelKind,
    /// Transform options.
    pub opts: FwhtOptions,
    /// Cached plan for `(kind, n)`.
    pub plan: Arc<ExecPlan>,
    /// Autotuned round-fusion depth for the HadaCore planned path
    /// (1 = unfused; see [`crate::exec::tune`]).
    pub fusion_depth: usize,
    /// Fused sign-flip prologue vector (length `n`), shared by all
    /// chunks; `None` for a plain transform.
    pub signs: Option<Arc<Vec<f32>>>,
    /// What each chunk executes (plain rotate or an epilogue stage).
    pub stage: ChunkStage,
    /// Scatter-gather view: when set, row indices address the logical
    /// concatenation of these regions instead of `payload` (which is
    /// then ignored). Regions-jobs only support [`ChunkStage::Rotate`].
    pub regions: Option<RegionsRef>,
    /// Span-trace id of the request this batch serves (0 = untraced;
    /// workers record per-chunk exec-start/end spans against it).
    pub trace: u64,
}

struct Job {
    spec: JobSpec,
    chunks: usize,
    next_chunk: usize,
    done: Arc<Latch>,
}

/// Completion latch: counts outstanding chunks, records worker panics.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(chunks: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: chunks, panicked: false }),
            cv: Condvar::new(),
        }
    }

    /// Re-arm a drained latch for the next job on this submitter. Safe
    /// because `wait` only returns once every chunk has called
    /// `finish_one` — a stale worker may still hold the `Arc`, but it
    /// never touches the latch again after its own `finish_one`.
    fn reset(&self, chunks: usize) {
        let mut st = self.state.lock().unwrap();
        debug_assert_eq!(st.remaining, 0, "latch reset while a job is in flight");
        st.remaining = chunks;
        st.panicked = false;
    }

    fn finish_one(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        st.panicked |= panicked;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        if st.panicked {
            panic!("exec worker panicked while executing a batch chunk");
        }
    }
}

thread_local! {
    // One reusable completion latch per submitting thread (const-init:
    // no destructor-ordering hazards; the Arc is freed at thread exit).
    static SUBMIT_LATCH: RefCell<Option<Arc<Latch>>> = const { RefCell::new(None) };
}

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// A claimed chunk, copied out of the job under the queue lock.
struct Claim {
    payload: Payload,
    rows: usize,
    n: usize,
    chunk_rows: usize,
    index: usize,
    kind: KernelKind,
    opts: FwhtOptions,
    plan: Arc<ExecPlan>,
    fusion_depth: usize,
    signs: Option<Arc<Vec<f32>>>,
    stage: ChunkStage,
    regions: Option<RegionsRef>,
    trace: u64,
    done: Arc<Latch>,
}

/// Persistent worker pool (see the module doc for the threading model).
pub(crate) struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (callers guarantee `threads >= 1`).
    pub fn new(threads: usize, stats: Arc<ExecStats>) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                let stats = Arc::clone(&stats);
                std::thread::Builder::new()
                    .name(format!("hadacore-exec-{wid}"))
                    .spawn(move || worker_loop(&shared, &stats))
                    .expect("spawn exec worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Enqueue one sharded batch and block until every chunk has executed.
    ///
    /// # Safety
    ///
    /// `spec.payload` must point at a buffer of at least `rows * n`
    /// elements of the tagged dtype, and the caller must hold the
    /// exclusive (`&mut`) borrow of that buffer for the full duration of
    /// this call. Both hold trivially when the payload is taken from a
    /// `&mut` slice argument immediately before calling.
    pub unsafe fn submit_and_wait(&self, spec: JobSpec) {
        debug_assert!(spec.chunk_rows >= 1 && spec.rows >= 1);
        let chunks = (spec.rows + spec.chunk_rows - 1) / spec.chunk_rows;
        // reuse this submitter's latch across jobs: `submit_and_wait`
        // blocks until the latch drains, so by the next call it is idle
        // and re-armable — no per-job Arc allocation in steady state
        let done = SUBMIT_LATCH.with(|cell| {
            let mut slot = cell.borrow_mut();
            match slot.as_ref() {
                Some(latch) => {
                    latch.reset(chunks);
                    Arc::clone(latch)
                }
                None => {
                    let latch = Arc::new(Latch::new(chunks));
                    *slot = Some(Arc::clone(&latch));
                    latch
                }
            }
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.queue.push_back(Job {
                spec,
                chunks,
                next_chunk: 0,
                done: Arc::clone(&done),
            });
        }
        self.shared.work_cv.notify_all();
        done.wait();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, stats: &ExecStats) {
    // exec workers execute serving batches: count their allocations
    // when the count-alloc gate is measuring (no-op otherwise)
    crate::util::alloc::track_current_thread(true);
    // the per-thread reusable f32 workspace for the 16-bit path
    let mut scratch: Vec<f32> = Vec::new();
    loop {
        let claim = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(front) = st.queue.front_mut() {
                    let claim = Claim {
                        payload: front.spec.payload,
                        rows: front.spec.rows,
                        n: front.spec.n,
                        chunk_rows: front.spec.chunk_rows,
                        index: front.next_chunk,
                        kind: front.spec.kind,
                        opts: front.spec.opts,
                        plan: Arc::clone(&front.spec.plan),
                        fusion_depth: front.spec.fusion_depth,
                        signs: front.spec.signs.clone(),
                        stage: front.spec.stage.clone(),
                        regions: front.spec.regions,
                        trace: front.spec.trace,
                        done: Arc::clone(&front.done),
                    };
                    front.next_chunk += 1;
                    if front.next_chunk == front.chunks {
                        // fully claimed; completion is tracked by the latch
                        st.queue.pop_front();
                    }
                    break claim;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let trace = crate::obs::TraceCtx(claim.trace);
        crate::obs::trace::event(trace, crate::obs::Stage::ExecStart, claim.index as u32);
        let chunk_start = std::time::Instant::now();
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            let start_row = claim.index * claim.chunk_rows;
            let rows_here = claim.chunk_rows.min(claim.rows - start_row);
            // SAFETY: chunk indices are claimed uniquely under the queue
            // lock and map to disjoint row (and scale-slot) ranges; the
            // submitter keeps the buffer(s) exclusively borrowed until
            // the latch opens (the contract of `submit_and_wait` /
            // `ExecEngine::run_f32_regions`).
            unsafe {
                match claim.regions {
                    Some(regions) => {
                        debug_assert!(
                            matches!(claim.stage, ChunkStage::Rotate),
                            "regions jobs only support the plain rotate stage"
                        );
                        execute_regions_range(
                            regions.as_slice(),
                            start_row,
                            rows_here,
                            claim.n,
                            claim.kind,
                            &claim.opts,
                            &claim.plan,
                            claim.fusion_depth,
                            claim.signs.as_deref().map(Vec::as_slice),
                            stats,
                        );
                    }
                    None => execute_stage(
                        &claim.stage,
                        claim.payload,
                        start_row,
                        rows_here,
                        claim.n,
                        claim.kind,
                        &claim.opts,
                        &claim.plan,
                        claim.fusion_depth,
                        claim.signs.as_deref().map(Vec::as_slice),
                        &mut scratch,
                        stats,
                    ),
                }
            }
        }))
        .is_err();
        // stage-level measurement (the paper's per-stage claim): every
        // chunk lands in the hadacore_exec_chunk_us histogram — atomics
        // only, so the zero-alloc steady state holds
        stats
            .chunk_us
            .record(chunk_start.elapsed().as_micros() as u64);
        crate::obs::trace::event(trace, crate::obs::Stage::ExecEnd, claim.index as u32);
        claim.done.finish_one(panicked);
    }
}
