//! Experiment harness: workload generation + table/figure regeneration.
//!
//! Everything the bench binaries and `examples/paper_tables.rs` need to
//! print the paper's tables: grid formatting in the paper's layout
//! (sizes down, element counts across; runtime in µs, speedup in %),
//! CSV export for plotting, serving workload generators, and the
//! quantised-pipeline accuracy study behind `TABLES_PR6.json`
//! (`examples/accuracy_study.rs`).

pub mod accuracy;
pub mod tables;
pub mod workload;

pub use accuracy::{outlier_activations, run_study, StudyConfig};
pub use tables::{format_runtime_table, format_speedup_table, to_csv, Table};
pub use workload::{ServingWorkload, WorkloadConfig};
