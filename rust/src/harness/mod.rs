//! Experiment harness: workload generation + table/figure regeneration.
//!
//! Everything the bench binaries and `examples/paper_tables.rs` need to
//! print the paper's tables: grid formatting in the paper's layout
//! (sizes down, element counts across; runtime in µs, speedup in %),
//! CSV export for plotting, and serving workload generators.

pub mod tables;
pub mod workload;

pub use tables::{format_runtime_table, format_speedup_table, to_csv, Table};
pub use workload::{ServingWorkload, WorkloadConfig};
