//! Paper-layout table formatting.
//!
//! The paper's appendix tables put Hadamard sizes down the rows and
//! element counts across the columns; runtimes in µs, speedups as
//! percentages (Fig 6/7 style). These helpers render any grid of cells in
//! that layout for terminal output and CSV export.

use std::collections::BTreeSet;
use std::fmt::Write as _;

/// A generic (row, col) -> value table with the paper's axes.
#[derive(Clone, Debug)]
pub struct Table {
    /// Title printed above the table.
    pub title: String,
    /// (n, elems, value) triples.
    pub cells: Vec<(usize, usize, f64)>,
}

impl Table {
    /// Build from triples.
    pub fn new(title: impl Into<String>, cells: Vec<(usize, usize, f64)>) -> Table {
        Table { title: title.into(), cells }
    }

    fn axes(&self) -> (Vec<usize>, Vec<usize>) {
        let rows: BTreeSet<usize> = self.cells.iter().map(|c| c.0).collect();
        let cols: BTreeSet<usize> = self.cells.iter().map(|c| c.1).collect();
        (rows.into_iter().collect(), cols.into_iter().collect())
    }

    fn get(&self, n: usize, e: usize) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.0 == n && c.1 == e)
            .map(|c| c.2)
    }

    /// Render with a per-cell formatter.
    pub fn render(&self, fmt: impl Fn(f64) -> String) -> String {
        let (rows, cols) = self.axes();
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let _ = write!(out, "{:>9} |", "size\\elems");
        for c in &cols {
            let _ = write!(out, "{:>10}", human_count(*c));
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "{}", "-".repeat(12 + 10 * cols.len()));
        for r in &rows {
            let _ = write!(out, "{:>9} |", r);
            for c in &cols {
                match self.get(*r, *c) {
                    Some(v) => {
                        let _ = write!(out, "{:>10}", fmt(v));
                    }
                    None => {
                        let _ = write!(out, "{:>10}", "");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }
}

/// 33554432 -> "32M", 512 -> "512".
pub fn human_count(v: usize) -> String {
    if v >= 1 << 20 && v % (1 << 20) == 0 {
        format!("{}M", v >> 20)
    } else if v >= 1 << 10 && v % (1 << 10) == 0 {
        format!("{}K", v >> 10)
    } else {
        format!("{v}")
    }
}

/// Runtime table in µs (paper Fig 6a/7a style).
pub fn format_runtime_table(title: &str, cells: Vec<(usize, usize, f64)>) -> String {
    Table::new(title, cells).render(|v| format!("{v:.2}"))
}

/// Speedup table in percent (paper Fig 6b/7b style).
pub fn format_speedup_table(title: &str, cells: Vec<(usize, usize, f64)>) -> String {
    Table::new(title, cells).render(|v| format!("{:.0}%", v * 100.0))
}

/// CSV export: `n,elems,value` lines with a header.
pub fn to_csv(header: &str, cells: &[(usize, usize, f64)]) -> String {
    let mut out = format!("n,elems,{header}\n");
    for (n, e, v) in cells {
        let _ = writeln!(out, "{n},{e},{v:.6}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<(usize, usize, f64)> {
        vec![
            (128, 512, 1.65),
            (128, 1024, 2.05),
            (256, 1024, 2.05),
            (256, 33554432, 86.93),
        ]
    }

    #[test]
    fn renders_paper_layout() {
        let s = format_runtime_table("A100 runtime (µs)", sample());
        assert!(s.contains("## A100 runtime"));
        assert!(s.contains("128"));
        assert!(s.contains("86.93"));
        assert!(s.contains("32M"));
        // empty cell for (128, 33M): row 128 line must end without a value
        let row128 = s.lines().find(|l| l.trim_start().starts_with("128")).unwrap();
        assert!(!row128.contains("86.93"));
    }

    #[test]
    fn speedup_format_percent() {
        let s = format_speedup_table("x", vec![(128, 512, 1.2621)]);
        assert!(s.contains("126%"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let csv = to_csv("us", &sample());
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("n,elems,us\n"));
        assert!(csv.contains("256,33554432,86.93"));
    }

    #[test]
    fn human_counts() {
        assert_eq!(human_count(512), "512");
        assert_eq!(human_count(2048), "2K");
        assert_eq!(human_count(33554432), "32M");
        assert_eq!(human_count(1000), "1000");
    }
}
