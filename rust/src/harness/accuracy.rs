//! End-to-end quantised-pipeline accuracy study (the paper's §4
//! accuracy story, run through the native stack).
//!
//! The paper's accuracy claim is not about the transform in isolation:
//! it is that a *randomized* Hadamard rotation, inserted around a
//! low-precision matmul, flattens activation outliers so FP8/INT8
//! quantisation loses less signal. This module reproduces that claim
//! as a measurable pipeline:
//!
//! ```text
//! per layer:  x ← rotate(x)        fused sign-flip prologue + FWHT
//!             x ← quantize(x)      per-row FP8/INT8 fake-quantise
//!             x ← matmul_proxy(x)  deterministic channel-mixing map
//!             x ← unrotate(x)      FWHT + same sign flip
//! ```
//!
//! Every configuration runs twice — with the quantiser (the lossy
//! pipeline) and without it (the exact twin) — and the error between
//! the two outputs is summarised as quantisation SNR (dB) and
//! max-error-relative-to-amax ([`crate::quant::quant_snr`],
//! [`crate::quant::rel_to_amax`]). The with/without-**rotation** axis
//! then shows the paper's effect: on outlier-heavy activations the
//! rotated pipeline keeps more signal at the same precision.
//!
//! The rotation path is the production code path: the engine's fused
//! [`Prologue::SignFlip`] (not a reference premultiply), so this study
//! also exercises the prologue plumbing end to end. Results are
//! collected as [`TableRecord`]s for the `hadacore-tables-v1` document
//! (`TABLES_PR6.json`) that `examples/accuracy_study.rs` emits and CI
//! validates.

use crate::exec::{ExecElement, ExecEngine};
use crate::hadamard::{sign_vector, FwhtOptions, KernelKind, Prologue};
use crate::quant::{fake_quantize, quant_snr, rel_to_amax, Epilogue, Scheme};
use crate::util::bench::TableRecord;
use crate::util::f16::{DType, Element, BF16, F16};
use crate::util::rng::Rng;

/// SNR ceiling written into tables: `quant_snr` returns `+inf` for an
/// exact reconstruction, but the `hadacore-tables-v1` schema requires
/// finite values, so the study clamps here. 300 dB is far beyond any
/// reachable f32 measurement (~150 dB), so the clamp never masks a
/// real difference.
pub const SNR_CLAMP_DB: f64 = 300.0;

/// Outlier channel indices (mirrors the scale-invariant outlier
/// injection of `examples/accuracy_study.rs`): these columns of the
/// activation matrix carry the migrated scale that real LLMs develop.
pub const OUTLIER_CHANNELS: [usize; 6] = [3, 17, 40, 77, 129, 513];

/// One sweep configuration for [`run_study`].
#[derive(Clone, Debug)]
pub struct StudyConfig {
    /// Transform sizes (each a supported `B·2^k`).
    pub sizes: Vec<usize>,
    /// Activation rows per measured batch.
    pub rows: usize,
    /// Pipeline depth (rotate→quantize→matmul layers).
    pub layers: usize,
    /// Kernels to sweep.
    pub kernels: Vec<KernelKind>,
    /// Storage dtypes to sweep.
    pub dtypes: Vec<DType>,
    /// Quantisation schemes to sweep.
    pub schemes: Vec<Scheme>,
    /// Scale factor applied to the [`OUTLIER_CHANNELS`] of the input
    /// activations (the severity of the outlier regime).
    pub outlier_scale: f32,
    /// Base seed: derives the input activations and the per-layer
    /// rotation seeds.
    pub seed: u64,
}

impl StudyConfig {
    /// The full paper grid: every kernel × dtype × scheme at the
    /// Llama-family sizes the paper reports (4096 hidden, 14336 FFN,
    /// 28672 = 2×FFN) plus a small power of two.
    pub fn paper() -> StudyConfig {
        StudyConfig {
            sizes: vec![1024, 4096, 14336, 28672],
            rows: 16,
            layers: 3,
            kernels: vec![KernelKind::Scalar, KernelKind::Dao, KernelKind::HadaCore],
            dtypes: vec![DType::F32, DType::F16, DType::BF16],
            schemes: vec![Scheme::Fp8E4m3, Scheme::Fp8E5m2, Scheme::Int8],
            outlier_scale: 48.0,
            seed: 0x5EED_0006,
        }
    }

    /// CI smoke grid: one kernel, but still wide enough to satisfy the
    /// table contract — ≥ 3 sizes including the 14336 Llama-FFN dim,
    /// ≥ 2 dtypes, and both an FP8 format and INT8.
    pub fn smoke() -> StudyConfig {
        StudyConfig {
            sizes: vec![256, 4096, 14336],
            rows: 4,
            layers: 2,
            kernels: vec![KernelKind::HadaCore],
            dtypes: vec![DType::F32, DType::BF16],
            schemes: vec![Scheme::Fp8E4m3, Scheme::Int8],
            outlier_scale: 48.0,
            seed: 0x5EED_0006,
        }
    }
}

/// Per-layer rotation seed: decorrelated from the base seed so stacked
/// layers do not share a sign vector (QuaRot rotates each block with an
/// independent diagonal).
pub fn layer_seed(base: u64, layer: usize) -> u64 {
    base.wrapping_add(0xA076_1D64_78BD_642F_u64.wrapping_mul(layer as u64 + 1))
}

/// Synthetic outlier-heavy activations: unit normals with the
/// [`OUTLIER_CHANNELS`] scaled up — the channel-outlier structure that
/// per-tensor quantisers handle worst and rotations flatten best.
pub fn outlier_activations(rng: &mut Rng, rows: usize, n: usize, scale: f32) -> Vec<f32> {
    let mut x: Vec<f32> = (0..rows * n).map(|_| rng.normal_f32()).collect();
    for row in x.chunks_exact_mut(n) {
        for &j in OUTLIER_CHANNELS.iter().filter(|&&j| j < n) {
            row[j] *= scale;
        }
    }
    x
}

/// The deterministic "matmul" stage: a layer-indexed circulant mixing
/// map `y[i] = 0.8·x[i] + 0.6·x[(i+stride) mod n]`. It stands in for
/// the downstream linear layer of a transformer block — it mixes
/// channels (so per-layer errors compound realistically) while being
/// exactly reproducible on both the lossy pipeline and its exact twin,
/// which is what makes the SNR comparison well defined.
fn matmul_proxy<E: Element>(state: &mut [E], n: usize, layer: usize) {
    let stride = (7 * layer + 1) % n.max(2) + 1;
    let mut src = vec![0.0f32; n];
    for row in state.chunks_exact_mut(n) {
        for (s, v) in src.iter_mut().zip(row.iter()) {
            *s = v.to_f32();
        }
        for (i, v) in row.iter_mut().enumerate() {
            *v = E::from_f32(0.8 * src[i] + 0.6 * src[(i + stride) % n]);
        }
    }
}

/// Run the multi-layer pipeline over `x0` and return the final state
/// widened to f32. `scheme: None` is the exact twin (no quantiser);
/// `rotated` controls the rotate/unrotate wrapping. The rotate step
/// goes through the engine's fused prologue — the code path under test.
fn pipeline<E: ExecElement>(
    engine: &ExecEngine,
    kernel: KernelKind,
    x0: &[f32],
    n: usize,
    layers: usize,
    scheme: Option<Scheme>,
    rotated: bool,
    seed: u64,
) -> Vec<f32> {
    let opts = FwhtOptions::normalized(n);
    let mut state: Vec<E> = x0.iter().map(|&v| E::from_f32(v)).collect();
    for layer in 0..layers {
        let rot_seed = layer_seed(seed, layer);
        if rotated {
            engine.run_with_stages(
                kernel,
                &mut state,
                n,
                &opts,
                Prologue::SignFlip { seed: rot_seed },
                Epilogue::None,
            );
        }
        if let Some(s) = scheme {
            // per-row fake-quantise in the f32 domain (per-token scales,
            // the serving-side granularity)
            let mut wide: Vec<f32> = state.iter().map(|v| v.to_f32()).collect();
            for row in wide.chunks_exact_mut(n) {
                fake_quantize(row, s);
            }
            for (dst, v) in state.iter_mut().zip(wide.iter()) {
                *dst = E::from_f32(*v);
            }
        }
        if rotated {
            // unrotate: H is symmetric and (with the orthonormal scale)
            // an involution, so the inverse is the transform again
            // followed by the same sign flip (docs/KERNEL_MATH.md §4)
            engine.run(kernel, &mut state, n, &opts);
            let signs = sign_vector(rot_seed, n);
            for row in state.chunks_exact_mut(n) {
                for (v, sg) in row.iter_mut().zip(signs.iter()) {
                    *v = E::from_f32(v.to_f32() * sg);
                }
            }
        }
        matmul_proxy(&mut state, n, layer);
    }
    state.iter().map(|v| v.to_f32()).collect()
}

/// Measure one (kernel, dtype-as-`E`, scheme, size) cell: runs the
/// lossy pipeline and its exact twin, with and without rotation, and
/// returns the `(plain, rotated)` record pair.
fn run_cell<E: ExecElement>(
    engine: &ExecEngine,
    kernel: KernelKind,
    scheme: Scheme,
    n: usize,
    cfg: &StudyConfig,
) -> (TableRecord, TableRecord) {
    let mut rng = Rng::new(cfg.seed ^ (n as u64).rotate_left(17));
    let x0 = outlier_activations(&mut rng, cfg.rows, n, cfg.outlier_scale);
    let mu_in = crate::quant::incoherence(&x0);

    let mut measure = |rotated: bool| -> (f64, f64) {
        let exact = pipeline::<E>(
            engine, kernel, &x0, n, cfg.layers, None, rotated, cfg.seed,
        );
        let lossy = pipeline::<E>(
            engine, kernel, &x0, n, cfg.layers, Some(scheme), rotated, cfg.seed,
        );
        (
            quant_snr(&exact, &lossy).min(SNR_CLAMP_DB),
            rel_to_amax(&exact, &lossy),
        )
    };
    let (snr_plain, rel_plain) = measure(false);
    let (snr_rot, rel_rot) = measure(true);

    let record = |rotated: bool, snr: f64, rel: f64| {
        TableRecord::new(
            "quant_pipeline",
            kernel.name(),
            n,
            cfg.rows,
            E::DTYPE.name(),
            scheme.name(),
            rotated,
            cfg.layers,
            snr,
            rel,
        )
        .with_extra("incoherence_in", mu_in)
    };
    (
        record(false, snr_plain, rel_plain),
        record(true, snr_rot, rel_rot).with_extra("snr_gain_db", snr_rot - snr_plain),
    )
}

/// Run the full study grid and return one [`TableRecord`] per
/// (kernel × dtype × scheme × size × rotation) cell — plain and rotated
/// records adjacent, plain first.
pub fn run_study(engine: &ExecEngine, cfg: &StudyConfig) -> Vec<TableRecord> {
    let mut out = Vec::new();
    for &kernel in &cfg.kernels {
        for &dtype in &cfg.dtypes {
            for &scheme in &cfg.schemes {
                for &n in &cfg.sizes {
                    let (plain, rotated) = match dtype {
                        DType::F32 => run_cell::<f32>(engine, kernel, scheme, n, cfg),
                        DType::F16 => run_cell::<F16>(engine, kernel, scheme, n, cfg),
                        DType::BF16 => run_cell::<BF16>(engine, kernel, scheme, n, cfg),
                    };
                    out.push(plain);
                    out.push(rotated);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> StudyConfig {
        StudyConfig {
            sizes: vec![256, 768],
            rows: 3,
            layers: 2,
            kernels: vec![KernelKind::HadaCore],
            dtypes: vec![DType::F32],
            schemes: vec![Scheme::Fp8E4m3],
            outlier_scale: 48.0,
            seed: 0x5EED_0006,
        }
    }

    #[test]
    fn study_covers_both_rotation_sides_with_finite_metrics() {
        let engine = ExecEngine::default();
        let records = run_study(&engine, &tiny_cfg());
        assert_eq!(records.len(), 4); // 2 sizes x {plain, rotated}
        for r in &records {
            assert!(r.snr_db.is_finite(), "{}: snr must be finite", r.line());
            assert!(
                r.rel_to_amax.is_finite() && r.rel_to_amax >= 0.0,
                "{}: rel_to_amax must be finite and non-negative",
                r.line()
            );
            assert_eq!(r.layers, 2);
        }
        assert!(records.iter().any(|r| r.rotated));
        assert!(records.iter().any(|r| !r.rotated));
        // records come in (plain, rotated) pairs over the same cell
        for pair in records.chunks_exact(2) {
            assert!(!pair[0].rotated && pair[1].rotated);
            assert_eq!(pair[0].n, pair[1].n);
        }
    }

    #[test]
    fn rotation_raises_pipeline_snr_on_outlier_activations() {
        // the end-to-end form of the paper's claim: through a full
        // multi-layer quantised pipeline, rotation still wins on
        // channel-outlier activations
        let engine = ExecEngine::default();
        let records = run_study(&engine, &tiny_cfg());
        for pair in records.chunks_exact(2) {
            assert!(
                pair[1].snr_db > pair[0].snr_db,
                "rotated must beat plain:\n  {}\n  {}",
                pair[0].line(),
                pair[1].line()
            );
        }
    }

    #[test]
    fn exact_twin_pipeline_is_deterministic_across_runs() {
        let engine = ExecEngine::default();
        let mut rng = Rng::new(1);
        let n = 512;
        let x0 = outlier_activations(&mut rng, 2, n, 10.0);
        let a = pipeline::<f32>(&engine, KernelKind::Dao, &x0, n, 2, None, true, 9);
        let b = pipeline::<f32>(&engine, KernelKind::Dao, &x0, n, 2, None, true, 9);
        assert_eq!(a, b);
        // and the lossy path too (fake-quantise is deterministic)
        let qa =
            pipeline::<f32>(&engine, KernelKind::Dao, &x0, n, 2, Some(Scheme::Int8), true, 9);
        let qb =
            pipeline::<f32>(&engine, KernelKind::Dao, &x0, n, 2, Some(Scheme::Int8), true, 9);
        assert_eq!(qa, qb);
    }

    #[test]
    fn layer_seeds_are_distinct_and_stable() {
        let s: Vec<u64> = (0..8).map(|l| layer_seed(42, l)).collect();
        let uniq: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(uniq.len(), s.len());
        assert_eq!(layer_seed(42, 3), layer_seed(42, 3));
        assert_ne!(layer_seed(42, 0), layer_seed(43, 0));
    }

    #[test]
    fn outlier_activations_carry_heavy_channels() {
        let mut rng = Rng::new(7);
        let n = 1024;
        let x = outlier_activations(&mut rng, 4, n, 48.0);
        let (mut amax_outlier, mut amax_rest) = (0.0f32, 0.0f32);
        for row in x.chunks_exact(n) {
            for (i, v) in row.iter().enumerate() {
                if OUTLIER_CHANNELS.contains(&i) {
                    amax_outlier = amax_outlier.max(v.abs());
                } else {
                    amax_rest = amax_rest.max(v.abs());
                }
            }
        }
        assert!(
            amax_outlier > amax_rest * 2.0,
            "outlier channels must dominate: {amax_outlier} vs {amax_rest}"
        );
    }

    #[test]
    fn smoke_grid_meets_the_table_contract() {
        // the CI grid must keep satisfying the acceptance floor:
        // >= 3 sizes including 14336, >= 2 dtypes, fp8 + int8
        let cfg = StudyConfig::smoke();
        assert!(cfg.sizes.len() >= 3);
        assert!(cfg.sizes.contains(&14336));
        assert!(cfg.dtypes.len() >= 2);
        assert!(cfg
            .schemes
            .iter()
            .any(|s| matches!(s, Scheme::Fp8E4m3 | Scheme::Fp8E5m2)));
        assert!(cfg.schemes.contains(&Scheme::Int8));
        let paper = StudyConfig::paper();
        assert!(paper.sizes.contains(&14336) && paper.sizes.contains(&28672));
        assert_eq!(paper.kernels.len(), 3);
    }
}
