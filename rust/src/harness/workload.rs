//! Serving workload generation for the coordinator benchmarks.
//!
//! Produces deterministic streams of transform requests with a
//! configurable size mix and payload distribution — the serving-side
//! analogue of the paper's element-count axis. Used by the e2e example
//! and the coordinator benches.

use crate::coordinator::TransformRequest;
use crate::hadamard::KernelKind;
use crate::quant::Epilogue;
use crate::util::rng::Rng;

/// Workload configuration.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Hadamard sizes to draw from (uniform mix). Any size the router
    /// admits is valid — the full `B * 2^k` family, so a workload can
    /// mix powers of two with Llama-dim sizes like 14336 = 28·512 (the
    /// `quarot_attention` example serves exactly that mix).
    pub sizes: Vec<usize>,
    /// Rows per request: uniform in [min, max].
    pub rows_min: usize,
    /// Upper bound (inclusive).
    pub rows_max: usize,
    /// Kernel to request.
    pub kernel: KernelKind,
    /// Probability a payload is heavy-tailed (outlier-bearing), the
    /// activation regime the paper's rotations target.
    pub outlier_fraction: f64,
    /// Fused rotate→quantize epilogue attached to every request — the
    /// quantised-serving workload (FP8 KV/activations). Does not consume
    /// randomness, so streams with and without an epilogue share the
    /// same payloads for a given seed.
    pub epilogue: Epilogue,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            sizes: vec![128, 256, 1024, 4096],
            rows_min: 1,
            rows_max: 8,
            kernel: KernelKind::HadaCore,
            outlier_fraction: 0.2,
            epilogue: Epilogue::None,
            seed: 0xBEEF,
        }
    }
}

/// Named traffic models for the serving layer's load generator: each is
/// a [`WorkloadConfig`] preset describing one regime of the paper's
/// serving story, so `hadacore loadgen --mixes interactive,llama-ffn`
/// reuses exactly the request distributions the in-process benches
/// measure.
pub const TRAFFIC_MIXES: [&str; 6] =
    ["interactive", "batch", "llama-ffn", "quantized", "int8-grouped", "mixed"];

/// Resolve a named traffic mix (see [`TRAFFIC_MIXES`]); `None` for an
/// unknown name.
///
/// * `interactive` — small sizes, 1–2 rows: latency-bound chat traffic.
/// * `batch` — large sizes, deep rows: throughput-bound prefill.
/// * `llama-ffn` — n = 14336 (28·512, the Llama-3 8B FFN dim): the
///   non-power-of-two production shape.
/// * `quantized` — FP8 rotate→quantize epilogue on attention-sized rows
///   (the paper's FP8-attention setting).
/// * `int8-grouped` — grouped-INT8 rotate→quantize epilogue (QuaRot's
///   weight/activation format): exercises the per-response scale
///   vector, which must come from the recycler — this mix is in the
///   `--assert-zero-alloc` gate precisely so that stays true.
/// * `mixed` — everything at once, the general-traffic soak.
pub fn traffic_mix(name: &str) -> Option<WorkloadConfig> {
    let base = WorkloadConfig::default();
    match name {
        "interactive" => Some(WorkloadConfig {
            sizes: vec![128, 256, 512],
            rows_min: 1,
            rows_max: 2,
            ..base
        }),
        "batch" => Some(WorkloadConfig {
            sizes: vec![1024, 4096, 8192],
            rows_min: 4,
            rows_max: 16,
            ..base
        }),
        "llama-ffn" => Some(WorkloadConfig {
            sizes: vec![14336],
            rows_min: 1,
            rows_max: 4,
            ..base
        }),
        "quantized" => Some(WorkloadConfig {
            sizes: vec![1024, 4096],
            rows_min: 1,
            rows_max: 8,
            epilogue: Epilogue::QuantFp8 { fmt: crate::quant::Fp8Format::E4M3 },
            ..base
        }),
        "int8-grouped" => Some(WorkloadConfig {
            sizes: vec![1024, 4096],
            rows_min: 1,
            rows_max: 8,
            epilogue: Epilogue::QuantInt8 { group: 64 },
            ..base
        }),
        "mixed" => Some(WorkloadConfig {
            sizes: vec![256, 1024, 4096, 14336],
            rows_min: 1,
            rows_max: 8,
            ..base
        }),
        _ => None,
    }
}

/// Deterministic request stream.
pub struct ServingWorkload {
    cfg: WorkloadConfig,
    rng: Rng,
    next_id: u64,
}

impl ServingWorkload {
    /// New stream from a config.
    pub fn new(cfg: WorkloadConfig) -> ServingWorkload {
        let rng = Rng::new(cfg.seed);
        ServingWorkload { cfg, rng, next_id: 0 }
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> TransformRequest {
        let n = self.cfg.sizes[self.rng.below(self.cfg.sizes.len())];
        let rows = self.rng.range(self.cfg.rows_min, self.cfg.rows_max);
        let heavy = self.rng.chance(self.cfg.outlier_fraction);
        let mut data = vec![0.0f32; rows * n];
        for v in data.iter_mut() {
            *v = if heavy {
                self.rng.outlier_normal(0.02, 30.0)
            } else {
                self.rng.normal_f32()
            };
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut req = TransformRequest::new(id, n, data);
        req.kernel = self.cfg.kernel;
        req.epilogue = self.cfg.epilogue;
        req
    }

    /// Generate a batch of requests.
    pub fn take(&mut self, count: usize) -> Vec<TransformRequest> {
        (0..count).map(|_| self.next_request()).collect()
    }

    /// Generate one dense `rows x n` batch payload with the configured
    /// outlier mix — the coordinator-free view of the same distribution,
    /// used by the [`crate::exec`] engine benches to feed batches
    /// directly without request framing.
    pub fn next_matrix(&mut self, rows: usize, n: usize) -> Vec<f32> {
        let heavy = self.rng.chance(self.cfg.outlier_fraction);
        let mut data = vec![0.0f32; rows * n];
        for v in data.iter_mut() {
            *v = if heavy {
                self.rng.outlier_normal(0.02, 30.0)
            } else {
                self.rng.normal_f32()
            };
        }
        data
    }

    /// [`ServingWorkload::next_matrix`] narrowed to a 16-bit (or `f32`)
    /// storage dtype — the payload shape the autotuner benches and the
    /// accuracy studies sweep. Consumes exactly the randomness of one
    /// `next_matrix` call, so an f32 stream and its narrowed twin stay
    /// in lockstep for a given seed.
    pub fn next_matrix_as<E: crate::util::f16::Element>(
        &mut self,
        rows: usize,
        n: usize,
    ) -> Vec<E> {
        self.next_matrix(rows, n)
            .into_iter()
            .map(E::from_f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = ServingWorkload::new(WorkloadConfig::default());
        let mut b = ServingWorkload::new(WorkloadConfig::default());
        for _ in 0..10 {
            let ra = a.next_request();
            let rb = b.next_request();
            assert_eq!(ra.n, rb.n);
            assert_eq!(ra.data, rb.data);
        }
    }

    #[test]
    fn requests_well_formed() {
        let mut w = ServingWorkload::new(WorkloadConfig::default());
        for req in w.take(100) {
            assert!(req.data.len() == req.rows * req.n);
            assert!(WorkloadConfig::default().sizes.contains(&req.n));
            assert!(req.rows >= 1 && req.rows <= 8);
        }
    }

    #[test]
    fn matrix_payloads_are_deterministic_and_shaped() {
        let mut a = ServingWorkload::new(WorkloadConfig::default());
        let mut b = ServingWorkload::new(WorkloadConfig::default());
        let ma = a.next_matrix(7, 128);
        let mb = b.next_matrix(7, 128);
        assert_eq!(ma.len(), 7 * 128);
        assert_eq!(ma, mb);
        assert!(ma.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn matrix_dtype_twins_stay_in_lockstep() {
        use crate::util::f16::{Element, F16};
        let mut a = ServingWorkload::new(WorkloadConfig::default());
        let mut b = ServingWorkload::new(WorkloadConfig::default());
        let m32 = a.next_matrix(5, 128);
        let m16: Vec<F16> = b.next_matrix_as(5, 128);
        for (x, h) in m32.iter().zip(m16.iter()) {
            assert_eq!(F16::from_f32(*x), *h);
        }
        // both streams consumed the same randomness: next draws agree
        assert_eq!(a.next_matrix(2, 64), b.next_matrix(2, 64));
    }

    #[test]
    fn epilogue_propagates_without_perturbing_the_stream() {
        use crate::quant::Fp8Format;
        let mut plain = ServingWorkload::new(WorkloadConfig::default());
        let mut fused = ServingWorkload::new(WorkloadConfig {
            epilogue: Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
            ..Default::default()
        });
        for _ in 0..10 {
            let a = plain.next_request();
            let b = fused.next_request();
            assert_eq!(a.epilogue, Epilogue::None);
            assert_eq!(b.epilogue, Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 });
            // same seed, same payloads — the epilogue is orthogonal
            assert_eq!(a.n, b.n);
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn non_pow2_sizes_flow_through_the_stream() {
        let cfg = WorkloadConfig {
            sizes: vec![768, 14336],
            ..Default::default()
        };
        let mut w = ServingWorkload::new(cfg);
        let mut saw = std::collections::HashSet::new();
        for req in w.take(40) {
            assert!(req.data.len() == req.rows * req.n);
            assert!(req.n == 768 || req.n == 14336);
            saw.insert(req.n);
        }
        assert_eq!(saw.len(), 2, "both sizes must appear in 40 draws");
    }

    #[test]
    fn every_traffic_mix_generates_admissible_requests() {
        use crate::coordinator::{Router, RouterConfig};
        let router = Router::new(None, RouterConfig::default());
        for name in TRAFFIC_MIXES {
            let cfg = traffic_mix(name).expect(name);
            let mut w = ServingWorkload::new(cfg);
            for req in w.take(25) {
                assert!(
                    router.admit(&req).is_ok(),
                    "mix {name}: n={} rows={} must be admissible",
                    req.n,
                    req.rows
                );
            }
        }
        assert!(traffic_mix("nope").is_none());
    }

    #[test]
    fn quantized_mix_carries_the_fp8_epilogue() {
        use crate::quant::Fp8Format;
        let cfg = traffic_mix("quantized").unwrap();
        assert_eq!(cfg.epilogue, Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 });
        let cfg = traffic_mix("llama-ffn").unwrap();
        assert_eq!(cfg.sizes, vec![14336]);
        // the grouped-INT8 mix must carry a group that divides every
        // size it generates, or admission would reject the traffic
        let cfg = traffic_mix("int8-grouped").unwrap();
        assert_eq!(cfg.epilogue, Epilogue::QuantInt8 { group: 64 });
        assert!(cfg.sizes.iter().all(|n| n % 64 == 0));
    }

    #[test]
    fn ids_are_sequential() {
        let mut w = ServingWorkload::new(WorkloadConfig::default());
        let reqs = w.take(5);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }
}
