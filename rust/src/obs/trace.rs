//! Request-scoped span tracing into per-thread flight-recorder rings.
//!
//! A [`TraceCtx`] is a u64 trace id; zero means *unsampled* and every
//! recording call early-returns on it, so the default configuration
//! (`HADACORE_TRACE_SAMPLE` unset → sample rate 0) costs one branch per
//! call site and allocates nothing — the `--assert-zero-alloc` loadgen
//! gate runs with tracing in exactly this state.
//!
//! Sampled requests record [`SpanEvent`]s (stage + small argument +
//! microsecond timestamp) into a fixed-capacity ring owned by the
//! recording thread. Rings overwrite oldest: a recorder that nobody
//! drains stays O(1) memory forever, and a postmortem drain sees the
//! most recent `CAPACITY` events per thread. Each slot is a tiny seqlock
//! (all-atomic fields guarded by a sequence word) so [`drain_all`] can
//! snapshot live rings from another thread without stopping writers;
//! a slot caught mid-write is simply skipped — flight recorders prefer
//! dropping one event over blocking the hot path.
//!
//! Rings are allocated lazily, once, on a thread's *first sampled*
//! event (leaked to `'static` and registered in a global list), never
//! on the steady-state path. Timestamps are microseconds since this
//! process's [`now_us`] epoch: totally ordered within a process, only
//! indicative across processes.

use std::cell::Cell;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::lazy::Lazy;

/// Events retained per recording thread before overwrite-oldest kicks
/// in. 1024 × 32 B = 32 KiB per thread that ever recorded a span.
pub const RING_CAPACITY: usize = 1024;

/// A request's trace identity: a u64 id where zero means "not sampled".
///
/// Stamped at conn-reader admission (or adopted from the wire when a
/// proxy or tracing client forwarded one) and carried by value through
/// `TransformRequest` → batch → `JobSpec` → chunk execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx(pub u64);

impl TraceCtx {
    /// The unsampled context; recording against it is a no-op.
    pub const NONE: TraceCtx = TraceCtx(0);

    /// Whether span events for this request are recorded.
    #[inline]
    pub fn is_sampled(self) -> bool {
        self.0 != 0
    }
}

/// Where in the request lifecycle a span event was recorded.
///
/// The discriminants are the wire encoding (`TraceDump` frame), so they
/// are append-only: new stages take fresh numbers at the end.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// Cluster proxy accepted the request and chose a backend leg.
    ProxyAdmit = 0,
    /// Server conn-reader finished decoding the request frame.
    Decode = 1,
    /// Router admission accepted the request (`arg` = rows).
    Admitted = 2,
    /// Request entered its batcher bucket.
    Enqueued = 3,
    /// Batch sealed for dispatch (`arg` = batch rows).
    BatchSealed = 4,
    /// Engine chunk began executing (`arg` = chunk index).
    ExecStart = 5,
    /// Engine chunk finished (`arg` = chunk index).
    ExecEnd = 6,
    /// Response frame assembled (`arg` = payload bytes, saturated).
    Framed = 7,
    /// Response bytes handed to the socket writer.
    Written = 8,
}

impl Stage {
    /// Stable lowercase name used in text renderings (`hadacore stats
    /// --trace`, test assertions).
    pub fn name(self) -> &'static str {
        match self {
            Stage::ProxyAdmit => "proxy-admit",
            Stage::Decode => "decode",
            Stage::Admitted => "admitted",
            Stage::Enqueued => "enqueued",
            Stage::BatchSealed => "batch-sealed",
            Stage::ExecStart => "exec-start",
            Stage::ExecEnd => "exec-end",
            Stage::Framed => "framed",
            Stage::Written => "written",
        }
    }

    /// Wire decoding; `None` for discriminants from a newer peer.
    pub fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::ProxyAdmit,
            1 => Stage::Decode,
            2 => Stage::Admitted,
            3 => Stage::Enqueued,
            4 => Stage::BatchSealed,
            5 => Stage::ExecStart,
            6 => Stage::ExecEnd,
            7 => Stage::Framed,
            8 => Stage::Written,
            _ => return None,
        })
    }
}

/// One recorded span event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Trace id this event belongs to (never zero in a drained event).
    pub trace: u64,
    /// Lifecycle stage.
    pub stage: Stage,
    /// Small per-stage argument (rows, chunk index, bytes).
    pub arg: u32,
    /// Microseconds since the recording process's epoch.
    pub t_us: u64,
}

/// Microseconds since this process's trace epoch (first use).
pub fn now_us() -> u64 {
    static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);
    EPOCH.elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------

/// `HADACORE_TRACE_SAMPLE` parsed once: a rate in `0.0..=1.0` mapped to
/// a threshold over the low 32 bits of the mixed admission counter.
static SAMPLE_THRESHOLD: Lazy<u64> = Lazy::new(|| {
    let rate = std::env::var("HADACORE_TRACE_SAMPLE")
        .ok()
        .and_then(|s| parse_rate(&s))
        .unwrap_or(0.0);
    (rate * (1u64 << 32) as f64) as u64
});

/// Parse a sample rate, clamped to `0.0..=1.0`; `None` if malformed.
pub fn parse_rate(s: &str) -> Option<f64> {
    let f = s.trim().parse::<f64>().ok()?;
    if f.is_nan() {
        return None;
    }
    Some(f.clamp(0.0, 1.0))
}

static NEXT_SEED: AtomicU64 = AtomicU64::new(0);

/// SplitMix64 finalizer: cheap, well-distributed id from a counter.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A fresh trace id, unconditionally sampled. Used when the caller has
/// already decided to trace (loadgen `--trace-every`, `stats --trace`).
pub fn next_trace_id() -> u64 {
    let h = mix(NEXT_SEED.fetch_add(1, Ordering::Relaxed));
    if h == 0 {
        1
    } else {
        h
    }
}

/// Admission-time sampling decision: a sampled [`TraceCtx`] with
/// probability `HADACORE_TRACE_SAMPLE`, else [`TraceCtx::NONE`].
pub fn sample() -> TraceCtx {
    let threshold = *SAMPLE_THRESHOLD;
    if threshold == 0 {
        return TraceCtx::NONE;
    }
    let h = mix(NEXT_SEED.fetch_add(1, Ordering::Relaxed));
    if (h & 0xffff_ffff) < threshold {
        TraceCtx(if h == 0 { 1 } else { h })
    } else {
        TraceCtx::NONE
    }
}

// ---------------------------------------------------------------------
// Flight-recorder ring
// ---------------------------------------------------------------------

/// One ring slot: a seqlock over three payload words.
///
/// The writer (the owning thread) stores `seq = 0`, the payload, then
/// `seq = write_index + 1` (Release). A concurrent drainer reads `seq`
/// (Acquire), the payload, fences, re-reads `seq`, and discards the
/// slot if the two reads disagree or are zero. All fields are atomics,
/// so a torn read is merely stale data, never UB.
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    t_us: AtomicU64,
    /// Stage in bits 0..8, arg in bits 8..40.
    meta: AtomicU64,
}

/// A per-thread flight-recorder ring: single writer, any-thread reader.
struct Ring {
    slots: Vec<Slot>,
    /// Total events ever written to this ring (monotonic).
    written: AtomicU64,
}

impl Ring {
    fn new() -> Ring {
        Ring {
            slots: (0..RING_CAPACITY)
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    trace: AtomicU64::new(0),
                    t_us: AtomicU64::new(0),
                    meta: AtomicU64::new(0),
                })
                .collect(),
            written: AtomicU64::new(0),
        }
    }

    /// Record one event; only ever called by the owning thread.
    fn push(&self, trace: u64, stage: Stage, arg: u32, t_us: u64) {
        let n = self.written.load(Ordering::Relaxed);
        let slot = &self.slots[(n as usize) % RING_CAPACITY];
        slot.seq.store(0, Ordering::Release);
        slot.trace.store(trace, Ordering::Relaxed);
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.meta
            .store(stage as u64 | ((arg as u64) << 8), Ordering::Relaxed);
        slot.seq.store(n + 1, Ordering::Release);
        self.written.store(n + 1, Ordering::Relaxed);
    }

    /// Snapshot every consistent slot into `out`.
    fn drain_into(&self, out: &mut Vec<SpanEvent>) {
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 {
                continue; // never written
            }
            let trace = slot.trace.load(Ordering::Relaxed);
            let t_us = slot.t_us.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 != s2 {
                continue; // torn: writer lapped us mid-read
            }
            let stage = match Stage::from_u8((meta & 0xff) as u8) {
                Some(s) => s,
                None => continue,
            };
            out.push(SpanEvent {
                trace,
                stage,
                arg: ((meta >> 8) & 0xffff_ffff) as u32,
                t_us,
            });
        }
    }
}

/// Every ring ever created, for [`drain_all`]. Rings are leaked to
/// `'static` (bounded: one per recording thread for process lifetime).
static RINGS: Lazy<Mutex<Vec<&'static Ring>>> = Lazy::new(|| Mutex::new(Vec::new()));

thread_local! {
    /// This thread's ring, if it ever recorded a sampled event.
    static THREAD_RING: Cell<Option<&'static Ring>> = const { Cell::new(None) };

    /// The trace context of the work this thread is currently executing.
    /// The coordinator sets it around engine calls so the exec pool can
    /// attribute chunk spans without threading a parameter through every
    /// public `run_*` signature (the engine is also a direct library
    /// API, where no trace exists).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

/// Set the calling thread's current trace context; returns the previous
/// one so nested scopes can restore it.
pub fn set_current(trace: TraceCtx) -> TraceCtx {
    CURRENT.with(|c| TraceCtx(c.replace(trace.0)))
}

/// The calling thread's current trace context ([`TraceCtx::NONE`] when
/// outside any traced scope).
pub fn current() -> TraceCtx {
    TraceCtx(CURRENT.with(|c| c.get()))
}

fn thread_ring() -> &'static Ring {
    THREAD_RING.with(|cell| match cell.get() {
        Some(r) => r,
        None => {
            let ring: &'static Ring = Box::leak(Box::new(Ring::new()));
            RINGS.lock().unwrap().push(ring);
            cell.set(Some(ring));
            ring
        }
    })
}

/// Record a span event for `trace`; no-op when unsampled.
#[inline]
pub fn event(trace: TraceCtx, stage: Stage, arg: u32) {
    if !trace.is_sampled() {
        return;
    }
    thread_ring().push(trace.0, stage, arg, now_us());
}

/// Snapshot every thread's ring into one list, sorted by timestamp
/// (ties broken by stage order so same-microsecond chains stay in
/// lifecycle order).
pub fn drain_all() -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for ring in RINGS.lock().unwrap().iter() {
        ring.drain_into(&mut out);
    }
    out.sort_by_key(|e| (e.t_us, e.stage));
    out
}

/// [`drain_all`] filtered to one trace id; `trace == 0` keeps all.
pub fn drain_trace(trace: u64) -> Vec<SpanEvent> {
    let mut events = drain_all();
    if trace != 0 {
        events.retain(|e| e.trace == trace);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsampled_records_nothing() {
        event(TraceCtx::NONE, Stage::Decode, 0);
        // No assertion on ring contents (other tests share the global
        // rings) — this is a does-not-allocate/does-not-crash check;
        // the zero-alloc property itself is gated by loadgen.
        assert!(!TraceCtx::NONE.is_sampled());
    }

    #[test]
    fn events_round_trip_through_the_ring() {
        let trace = next_trace_id();
        event(TraceCtx(trace), Stage::Decode, 7);
        event(TraceCtx(trace), Stage::Admitted, 64);
        event(TraceCtx(trace), Stage::Written, 0);
        let got = drain_trace(trace);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].stage, Stage::Decode);
        assert_eq!(got[0].arg, 7);
        assert_eq!(got[1].stage, Stage::Admitted);
        assert_eq!(got[1].arg, 64);
        assert_eq!(got[2].stage, Stage::Written);
        assert!(got[0].t_us <= got[1].t_us && got[1].t_us <= got[2].t_us);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let trace = next_trace_id();
        // Overfill one thread's ring; only the newest CAPACITY survive.
        for i in 0..(RING_CAPACITY as u32 + 10) {
            event(TraceCtx(trace), Stage::ExecStart, i);
        }
        let got = drain_trace(trace);
        assert!(got.len() <= RING_CAPACITY);
        let args: Vec<u32> = got.iter().map(|e| e.arg).collect();
        // The very first events must have been overwritten...
        assert!(!args.contains(&0));
        // ...and the newest must still be present.
        assert!(args.contains(&(RING_CAPACITY as u32 + 9)));
    }

    #[test]
    fn stage_names_and_wire_codes_round_trip() {
        for v in 0u8..=8 {
            let s = Stage::from_u8(v).unwrap();
            assert_eq!(s as u8, v);
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u8(9), None);
        assert_eq!(Stage::ProxyAdmit.name(), "proxy-admit");
        assert_eq!(Stage::Written.name(), "written");
    }

    #[test]
    fn rate_parsing_clamps_and_rejects_garbage() {
        assert_eq!(parse_rate("0"), Some(0.0));
        assert_eq!(parse_rate("1"), Some(1.0));
        assert_eq!(parse_rate(" 0.25 "), Some(0.25));
        assert_eq!(parse_rate("7"), Some(1.0));
        assert_eq!(parse_rate("-1"), Some(0.0));
        assert_eq!(parse_rate("lots"), None);
        assert_eq!(parse_rate("NaN"), None);
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }
}
