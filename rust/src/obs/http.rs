//! Minimal read-only HTTP listener for `GET /metrics`.
//!
//! `hadacore serve --metrics-addr 127.0.0.1:9100` (and the cluster
//! proxy's equivalent) binds this next to the binary wire listener so
//! any Prometheus-compatible scraper — or plain `curl` — can read the
//! process-wide [`crate::obs::registry`] exposition without speaking
//! the hadacore protocol. It is deliberately not a web server: one
//! accept thread, blocking I/O, `GET /metrics` → `200 text/plain`,
//! anything else → `404`, connection closed after every response.
//! Requests are bounded (header read capped, short read timeout) so a
//! stuck scraper cannot pin the thread forever.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::error as anyhow;
use crate::util::error::Context;

/// Cap on the request head we are willing to read before answering.
const MAX_REQUEST_BYTES: usize = 4096;

/// Handle to a running metrics listener; shuts it down on drop.
pub struct MetricsHandle {
    /// Actual bound address (useful when the caller asked for port 0).
    addr: String,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsHandle {
    /// The address the listener actually bound.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop the listener and join its thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway self-connection.
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsHandle {
    fn drop(&mut self) {
        if self.thread.is_some() {
            self.shutdown_inner();
        }
    }
}

/// Bind `addr` and serve `GET /metrics` from the process registry until
/// the returned handle is shut down or dropped.
pub fn serve_metrics(addr: &str) -> anyhow::Result<MetricsHandle> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("bind metrics listener on {addr}"))?;
    let bound = listener
        .local_addr()
        .context("metrics listener local_addr")?
        .to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let thread = std::thread::Builder::new()
        .name("hadacore-metrics".into())
        .spawn(move || accept_loop(listener, stop2))
        .context("spawn metrics listener thread")?;
    Ok(MetricsHandle {
        addr: bound,
        stop,
        thread: Some(thread),
    })
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    loop {
        let conn = match listener.accept() {
            Ok((conn, _)) => conn,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // Scrapers are rare and sequential; serving inline on the accept
        // thread keeps this a single extra thread per process.
        let _ = serve_one(conn);
    }
}

fn serve_one(mut conn: TcpStream) -> std::io::Result<()> {
    conn.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut head = [0u8; MAX_REQUEST_BYTES];
    let mut filled = 0;
    // Read until the end of the request head (blank line) or the cap.
    while filled < head.len() {
        let n = conn.read(&mut head[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
        if head[..filled].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let request_line = head[..filled]
        .split(|&b| b == b'\r' || b == b'\n')
        .next()
        .unwrap_or(&[]);
    let response = if is_get_metrics(request_line) {
        let body = crate::obs::registry().render();
        format!(
            "HTTP/1.1 200 OK\r\ncontent-type: text/plain; version=0.0.4\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else {
        let body = "not found; try GET /metrics\n";
        format!(
            "HTTP/1.1 404 Not Found\r\ncontent-type: text/plain\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    };
    conn.write_all(response.as_bytes())?;
    let _ = conn.shutdown(Shutdown::Both);
    Ok(())
}

fn is_get_metrics(request_line: &[u8]) -> bool {
    // "GET /metrics HTTP/1.1" — accept any (or no) HTTP version suffix.
    let Ok(line) = std::str::from_utf8(request_line) else {
        return false;
    };
    let mut parts = line.split_whitespace();
    parts.next() == Some("GET")
        && matches!(parts.next(), Some(p) if p == "/metrics" || p.starts_with("/metrics?"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: &str, path: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(format!("GET {path} HTTP/1.1\r\nhost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_registry_exposition_on_get_metrics() {
        let c = crate::obs::registry().counter("hadacore_http_test_total", "test series");
        c.fetch_add(3, Ordering::Relaxed);
        let handle = serve_metrics("127.0.0.1:0").unwrap();
        let resp = http_get(handle.addr(), "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("hadacore_http_test_total"), "{resp}");
        let resp = http_get(handle.addr(), "/other");
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        handle.shutdown();
    }

    #[test]
    fn request_line_matching() {
        assert!(is_get_metrics(b"GET /metrics HTTP/1.1"));
        assert!(is_get_metrics(b"GET /metrics?ts=1 HTTP/1.0"));
        assert!(is_get_metrics(b"GET /metrics"));
        assert!(!is_get_metrics(b"POST /metrics HTTP/1.1"));
        assert!(!is_get_metrics(b"GET /metricsx HTTP/1.1"));
        assert!(!is_get_metrics(b"GET / HTTP/1.1"));
    }
}
