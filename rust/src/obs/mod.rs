//! Unified observability layer: metrics registry, request-scoped span
//! tracing, and the flight-recorder event ring (ISSUE 10).
//!
//! The reproduction spans four layers (kernel → engine → serve →
//! cluster) and, before this module, each kept its own telemetry:
//! [`crate::coordinator::metrics::Metrics`] counters, the engine's
//! `ExecStatsSnapshot`, ad-hoc `proxy.*`/`backend{i}.*` strings in the
//! `Stats` wire frame, and loadgen-side percentiles. Nobody could answer
//! "where did request #4711 spend its 2 ms" or scrape the fleet with one
//! tool. This module unifies all of it, zero-dep and with the same
//! hot-path discipline as the serve path (atomics only; **zero
//! steady-state allocation** with tracing at the default sample rate):
//!
//! * [`registry`] — the process-wide metrics registry. Counters, gauges
//!   and log-spaced histograms are registered once by name (subsystems
//!   keep `Arc` handles and bump plain atomics on the hot path) and
//!   rendered as Prometheus-style text exposition, served through the
//!   `StatsText` wire frame, the read-only HTTP `GET /metrics` listener
//!   ([`http`]), and the `hadacore stats` CLI. The pre-existing
//!   per-subsystem structs (`Metrics`, `ExecStats`, `ServeCounters`,
//!   `ClusterCounters`) are thin views over registry handles, not a
//!   parallel system.
//! * [`trace`] — request-scoped span tracing. A [`trace::TraceCtx`]
//!   (u64 trace id; zero = unsampled) is stamped at conn-reader
//!   admission (or adopted from the wire when the cluster proxy — or a
//!   tracing client — forwarded one), carried through
//!   `TransformRequest` → batcher bucket → `JobSpec` → chunk execution,
//!   and span events (decode, admitted, enqueued, batch-sealed,
//!   exec-start/end per chunk, framed, written) land in lock-free
//!   per-thread flight-recorder rings: fixed capacity, overwrite-oldest,
//!   snapshot-drained on demand via the `TraceDump` wire frame. Slow
//!   requests are reconstructable postmortem without a logging pipeline.
//! * [`http`] — the minimal read-only HTTP listener for `GET /metrics`
//!   (`hadacore serve --metrics-addr`), so any Prometheus-compatible
//!   scraper can watch a backend or the cluster proxy without speaking
//!   the binary wire protocol.
//!
//! Cross-process: the proxy forwards the trace id in a flag-gated wire
//! extension (`FLAG_HAS_TRACE`, the same backward-compatible trick as
//! `prologue_seed`) and merges backend span dumps into its own on a
//! `TraceDump` request, so one request is traceable proxy → backend →
//! engine chunk. Span timestamps are microseconds since *that process's*
//! epoch: ordering is exact within a process and merely indicative
//! across machines (the e2e gate runs the whole fleet in one process,
//! where the chain is strictly ordered).

pub mod http;
pub mod registry;
pub mod trace;

pub use http::{serve_metrics, MetricsHandle};
pub use registry::{registry, Registry};
pub use trace::{SpanEvent, Stage, TraceCtx};
