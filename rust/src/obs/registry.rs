//! The process-wide metrics registry + Prometheus-style text exposition.
//!
//! Design (mirrors the serve path's zero-alloc discipline):
//!
//! * **Registration is cold, observation is hot.** A subsystem registers
//!   each metric once at construction ([`Registry::counter`] /
//!   [`Registry::gauge`] / [`Registry::histogram_us`]) and keeps the
//!   returned `Arc` handle. The handles are plain [`AtomicU64`]s and
//!   [`Histogram`]s — the hot path bumps them directly (through `Deref`,
//!   so pre-registry call sites like `self.submitted.fetch_add(1, _)`
//!   compile unchanged); the registry is only locked at registration and
//!   exposition time.
//! * **Instances, not uniqueness.** Registering the same name twice
//!   returns a *new* instance appended to that name's family (one
//!   process can run several coordinators — the self-hosted cluster
//!   fleet does). Per-instance reads stay exact (each owner holds its
//!   own handle); the exposition **sums instances per label set**, which
//!   for counters is the process-lifetime total (instances are
//!   monotone and never removed — dropped owners stop bumping, their
//!   contribution remains, exactly a cumulative counter's contract) and
//!   for histograms is the bucket-wise merge ([`Histogram::merge_from`],
//!   whose merge-equals-union property is pinned by tests here).
//! * **Computed series.** Metrics whose source of truth predates the
//!   registry (SIMD dispatch counters, the counting allocator) register
//!   a closure ([`Registry::counter_fn`]) sampled at render time — no
//!   rewiring of their hot paths.
//!
//! The exposition format is the Prometheus text format restricted to
//! what this crate emits: `# HELP`/`# TYPE` headers, optional single
//! `key="value"` label, `_bucket{le="..."}`/`_sum`/`_count` histogram
//! series with **microsecond** bounds (latency unit of the whole crate;
//! the `_us` name suffix makes the unit explicit — deliberately not the
//! base-unit-seconds convention, which would put every bucket bound in
//! the 1e-6 decade for no information gain). [`parse_exposition`] reads
//! the subset back — the round-trip gate for remote percentile
//! reconstruction (`hadacore stats` of a live server must agree with
//! the in-process `Histogram::report`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Histogram;
use crate::util::lazy::Lazy;

/// Metric family kind; fixes the `# TYPE` line and the render shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn type_name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// One registered handle (or computed closure) within a family.
enum Instance {
    Value(Arc<AtomicU64>),
    Hist(Arc<Histogram>),
    Computed(Box<dyn Fn() -> u64 + Send + Sync>),
}

struct Member {
    /// Rendered label, e.g. `backend="2"`; empty = unlabeled series.
    label: String,
    instance: Instance,
}

struct Family {
    name: String,
    help: &'static str,
    kind: Kind,
    members: Vec<Member>,
}

/// The process-wide registry; obtain it via [`registry`].
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

static REGISTRY: Lazy<Registry> = Lazy::new(|| {
    let r = Registry { families: Mutex::new(Vec::new()) };
    // the counting allocator predates the registry; sample it at render
    // time (plain zeros on builds without --features count-alloc)
    r.counter_fn(
        "hadacore_tracked_allocs_total",
        "heap allocation calls observed on tracked serving threads \
         (count-alloc builds; 0 otherwise)",
        || crate::util::alloc::tracked().allocs,
    );
    r.counter_fn(
        "hadacore_tracked_alloc_bytes_total",
        "bytes requested by tracked-thread allocation calls \
         (count-alloc builds; 0 otherwise)",
        || crate::util::alloc::tracked().bytes,
    );
    r
});

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    &REGISTRY
}

/// Render one `key="value"` label pair (values are escaped per the
/// exposition format: backslash, double-quote, newline).
fn format_label(key: &str, value: &str) -> String {
    let mut escaped = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => escaped.push_str("\\\\"),
            '"' => escaped.push_str("\\\""),
            '\n' => escaped.push_str("\\n"),
            c => escaped.push(c),
        }
    }
    format!("{key}=\"{escaped}\"")
}

impl Registry {
    fn register(
        &self,
        name: &str,
        help: &'static str,
        kind: Kind,
        label: String,
        instance: Instance,
    ) {
        let mut families = self.families.lock().unwrap();
        match families.iter_mut().find(|f| f.name == name) {
            Some(family) => {
                assert_eq!(
                    family.kind, kind,
                    "metric {name:?} registered as both {:?} and {kind:?}",
                    family.kind
                );
                family.members.push(Member { label, instance });
            }
            None => families.push(Family {
                name: name.to_string(),
                help,
                kind,
                members: vec![Member { label, instance }],
            }),
        }
    }

    /// Register a counter (monotone) and return its handle.
    pub fn counter(&self, name: &str, help: &'static str) -> Arc<AtomicU64> {
        let c = Arc::new(AtomicU64::new(0));
        self.register(name, help, Kind::Counter, String::new(), Instance::Value(Arc::clone(&c)));
        c
    }

    /// Register a labeled counter (one `key="value"` pair).
    pub fn labeled_counter(
        &self,
        name: &str,
        help: &'static str,
        key: &'static str,
        value: &str,
    ) -> Arc<AtomicU64> {
        let c = Arc::new(AtomicU64::new(0));
        self.register(
            name,
            help,
            Kind::Counter,
            format_label(key, value),
            Instance::Value(Arc::clone(&c)),
        );
        c
    }

    /// Register a gauge (goes up and down) and return its handle.
    pub fn gauge(&self, name: &str, help: &'static str) -> Arc<AtomicU64> {
        let g = Arc::new(AtomicU64::new(0));
        self.register(name, help, Kind::Gauge, String::new(), Instance::Value(Arc::clone(&g)));
        g
    }

    /// Register a labeled gauge (one `key="value"` pair).
    pub fn labeled_gauge(
        &self,
        name: &str,
        help: &'static str,
        key: &'static str,
        value: &str,
    ) -> Arc<AtomicU64> {
        let g = Arc::new(AtomicU64::new(0));
        self.register(
            name,
            help,
            Kind::Gauge,
            format_label(key, value),
            Instance::Value(Arc::clone(&g)),
        );
        g
    }

    /// Register a log-spaced microsecond histogram and return its handle.
    pub fn histogram_us(&self, name: &str, help: &'static str) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register(
            name,
            help,
            Kind::Histogram,
            String::new(),
            Instance::Hist(Arc::clone(&h)),
        );
        h
    }

    /// Register a labeled microsecond histogram (one `key="value"` pair).
    pub fn labeled_histogram_us(
        &self,
        name: &str,
        help: &'static str,
        key: &'static str,
        value: &str,
    ) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register(
            name,
            help,
            Kind::Histogram,
            format_label(key, value),
            Instance::Hist(Arc::clone(&h)),
        );
        h
    }

    /// Register a computed counter: `f` is sampled at render time. For
    /// sources of truth that predate the registry (SIMD dispatch tables,
    /// the counting allocator) — their hot paths stay untouched.
    pub fn counter_fn(
        &self,
        name: &str,
        help: &'static str,
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.register(name, help, Kind::Counter, String::new(), Instance::Computed(Box::new(f)));
    }

    /// [`Registry::counter_fn`] with one `key="value"` label pair.
    pub fn labeled_counter_fn(
        &self,
        name: &str,
        help: &'static str,
        key: &'static str,
        value: &str,
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.register(
            name,
            help,
            Kind::Counter,
            format_label(key, value),
            Instance::Computed(Box::new(f)),
        );
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format. Instances sharing a family and label set are summed
    /// (counters/gauges) or bucket-merged (histograms); families render
    /// in registration order, label sets in first-seen order.
    pub fn render(&self) -> String {
        let families = self.families.lock().unwrap();
        let mut out = String::with_capacity(4096);
        for family in families.iter() {
            out.push_str(&format!("# HELP {} {}\n", family.name, family.help));
            out.push_str(&format!("# TYPE {} {}\n", family.name, family.kind.type_name()));
            // group members by label set, preserving first-seen order
            let mut label_order: Vec<&str> = Vec::new();
            for m in &family.members {
                if !label_order.iter().any(|&l| l == m.label) {
                    label_order.push(&m.label);
                }
            }
            for label in label_order {
                let members = family.members.iter().filter(|m| m.label == label);
                match family.kind {
                    Kind::Counter | Kind::Gauge => {
                        let total: u64 = members
                            .map(|m| match &m.instance {
                                Instance::Value(v) => v.load(Ordering::Relaxed),
                                Instance::Computed(f) => f(),
                                Instance::Hist(_) => unreachable!("kind checked at register"),
                            })
                            .sum();
                        out.push_str(&render_sample(&family.name, label, total));
                    }
                    Kind::Histogram => {
                        let merged = Histogram::new();
                        for m in members {
                            if let Instance::Hist(h) = &m.instance {
                                merged.merge_from(h);
                            }
                        }
                        render_histogram(&mut out, &family.name, label, &merged);
                    }
                }
            }
        }
        out
    }
}

fn render_sample(name: &str, label: &str, value: u64) -> String {
    if label.is_empty() {
        format!("{name} {value}\n")
    } else {
        format!("{name}{{{label}}} {value}\n")
    }
}

/// Histogram series: cumulative `_bucket{le="<upper-µs>"}` samples over
/// the log-spaced bounds, the standard `+Inf` bucket, `_sum` (µs) and
/// `_count`.
fn render_histogram(out: &mut String, name: &str, label: &str, h: &Histogram) {
    let sep = if label.is_empty() { "" } else { "," };
    let mut cumulative = 0u64;
    for (upper_us, count) in h.bucket_bounds_counts() {
        cumulative += count;
        out.push_str(&format!(
            "{name}_bucket{{{label}{sep}le=\"{upper_us}\"}} {cumulative}\n"
        ));
    }
    out.push_str(&format!("{name}_bucket{{{label}{sep}le=\"+Inf\"}} {cumulative}\n"));
    out.push_str(&render_sample(&format!("{name}_sum"), label, h.sum_us()));
    out.push_str(&render_sample(&format!("{name}_count"), label, h.count()));
}

/// One series parsed back from the exposition text.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedSample {
    /// Metric name (including any `_bucket`/`_sum`/`_count` suffix).
    pub name: String,
    /// Raw label block without braces (`backend="2",le="32"`); empty
    /// when the sample has no labels.
    pub labels: String,
    pub value: f64,
}

/// Parse the subset of the text exposition format this registry emits:
/// comment lines are skipped, every other line is
/// `name[{labels}] value`. Malformed lines are skipped rather than
/// failing the whole scrape (the CLI renders best-effort).
pub fn parse_exposition(text: &str) -> Vec<ParsedSample> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = match line.rsplit_once(' ') {
            Some(parts) => parts,
            None => continue,
        };
        let value: f64 = match value.parse() {
            Ok(v) => v,
            Err(_) => continue,
        };
        let (name, labels) = match series.split_once('{') {
            Some((n, rest)) => match rest.strip_suffix('}') {
                Some(l) => (n, l),
                None => continue,
            },
            None => (series, ""),
        };
        out.push(ParsedSample {
            name: name.to_string(),
            labels: labels.to_string(),
            value,
        });
    }
    out
}

/// Reconstruct a [`Histogram`] for `name` (and an optional label
/// substring filter) from parsed exposition samples — the remote side of
/// the percentile round-trip. Returns `None` when no `_bucket` series
/// for `name` is present.
pub fn parse_histogram(samples: &[ParsedSample], name: &str, label: &str) -> Option<Histogram> {
    let bucket_name = format!("{name}_bucket");
    let mut bounds: Vec<(u64, u64)> = Vec::new(); // (upper_us, cumulative)
    for s in samples {
        if s.name != bucket_name || !s.labels.contains(label) {
            continue;
        }
        let le = s
            .labels
            .split(',')
            .find_map(|l| l.trim().strip_prefix("le=\""))
            .and_then(|v| v.strip_suffix('"'))?;
        if le == "+Inf" {
            continue; // always equals the last finite cumulative bucket here
        }
        bounds.push((le.parse().ok()?, s.value as u64));
    }
    if bounds.is_empty() {
        return None;
    }
    bounds.sort_unstable();
    let h = Histogram::new();
    let mut prev = 0u64;
    for (upper_us, cumulative) in bounds {
        let here = cumulative.saturating_sub(prev);
        prev = cumulative;
        if here > 0 {
            // `upper - 1` lands back in exactly the bucket whose upper
            // bound is `upper` (pinned by the round-trip test below)
            h.record_n(upper_us - 1, here);
        }
    }
    Some(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn counters_sum_instances_and_render_labels() {
        let a = registry().counter("obs_test_family_total", "test counter");
        let b = registry().counter("obs_test_family_total", "test counter");
        a.fetch_add(3, Ordering::Relaxed);
        b.fetch_add(4, Ordering::Relaxed);
        let l = registry().labeled_counter("obs_test_labeled_total", "t", "shard", "2");
        l.fetch_add(9, Ordering::Relaxed);
        let text = registry().render();
        assert!(text.contains("# TYPE obs_test_family_total counter"), "{text}");
        assert!(text.contains("obs_test_family_total 7"), "{text}");
        assert!(text.contains("obs_test_labeled_total{shard=\"2\"} 9"), "{text}");
    }

    #[test]
    fn computed_counters_sample_at_render_time() {
        use std::sync::atomic::AtomicU64;
        static SOURCE: AtomicU64 = AtomicU64::new(0);
        registry().counter_fn("obs_test_computed_total", "t", || {
            SOURCE.load(Ordering::Relaxed)
        });
        SOURCE.store(41, Ordering::Relaxed);
        assert!(registry().render().contains("obs_test_computed_total 41"));
        SOURCE.store(42, Ordering::Relaxed);
        assert!(registry().render().contains("obs_test_computed_total 42"));
    }

    #[test]
    fn exposition_round_trip_reconstructs_percentiles() {
        // the satellite gate: render a histogram, parse the text back,
        // and the reconstructed p50/p90/p99 must equal the in-process
        // Histogram's — for a distribution spanning the linear and the
        // geometric bucket regions
        let h = registry().histogram_us("obs_test_roundtrip_us", "t");
        let mut rng = Rng::new(0x0B5E_0B5E);
        for _ in 0..500 {
            h.record(rng.next_u64() % 14); // linear region
        }
        for _ in 0..400 {
            h.record(100 + rng.next_u64() % 4000); // geometric region
        }
        for _ in 0..7 {
            h.record(2_000_000); // far tail
        }
        let text = registry().render();
        let samples = parse_exposition(&text);
        let parsed = parse_histogram(&samples, "obs_test_roundtrip_us", "")
            .expect("bucket series present");
        assert_eq!(parsed.count(), h.count());
        for p in [50.0, 90.0, 99.0] {
            assert_eq!(
                parsed.percentile_us(p),
                h.percentile_us(p),
                "p{p} must survive the text round-trip"
            );
        }
    }

    #[test]
    fn merged_instances_equal_histogram_of_the_union() {
        // the satellite gate: the exposition merges N per-backend
        // histograms; the merge must equal one histogram fed the union
        // of the samples, bucket for bucket
        // same label set on every instance => the exposition merges them
        let shards: Vec<_> = (0..3)
            .map(|_| {
                registry().labeled_histogram_us("obs_test_merge_us", "t", "kind", "all")
            })
            .collect();
        let union = Histogram::new();
        let mut rng = Rng::new(0x3E27_11AA);
        for (i, shard) in shards.iter().enumerate() {
            for _ in 0..(50 + i * 37) {
                let us = rng.next_u64() % 1_000_000;
                shard.record(us);
                union.record(us);
            }
        }
        let samples = parse_exposition(&registry().render());
        let merged = parse_histogram(&samples, "obs_test_merge_us", "kind=\"all\"")
            .expect("merged series present");
        assert_eq!(merged.count(), union.count());
        for p in [50.0, 75.0, 90.0, 99.0, 99.9] {
            assert_eq!(merged.percentile_us(p), union.percentile_us(p), "p{p}");
        }
        assert_eq!(
            merged.bucket_bounds_counts(),
            union.bucket_bounds_counts(),
            "merge must equal the union bucket-for-bucket, not just at \
             the reported percentiles"
        );
    }

    #[test]
    fn parser_skips_malformed_lines() {
        let text = "# HELP x y\nbad line with spaces but no value x\n\
                    ok_metric 5\nok_labeled{a=\"b\"} 6.5\n";
        let samples = parse_exposition(text);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].name, "ok_metric");
        assert_eq!(samples[1].labels, "a=\"b\"");
        assert_eq!(samples[1].value, 6.5);
    }

    #[test]
    #[should_panic(expected = "registered as both")]
    fn kind_conflicts_are_programming_errors() {
        registry().counter("obs_test_kind_conflict", "t");
        registry().gauge("obs_test_kind_conflict", "t");
    }
}
