//! Layer 4: the network serving front-end over the [`crate::coordinator`].
//!
//! Everything below this module is an in-process function call; this
//! module is where HadaCore becomes a *service* — the deployment shape
//! the paper's rotate→quantize primitive actually runs in on an
//! inference hot path. Zero external dependencies: `std::net` TCP, a
//! purpose-built binary frame protocol, and `std` threads.
//!
//! * [`wire`] — the length-prefixed, versioned frame protocol
//!   (request/response/error/busy/ping/stats), with strict decode limits
//!   and bit-exact f32 payloads.
//! * [`server`] — the TCP acceptor + bounded connection-handler pool:
//!   decodes each request payload **directly into a pooled buffer**
//!   ([`crate::util::pool`]), applies admission control (global
//!   in-flight cap, per-connection pipelining cap, batcher queue-depth
//!   shedding — all answered with a retriable [`wire::Frame::Busy`]
//!   rather than unbounded queueing), forwards to
//!   [`Coordinator::submit_to`](crate::coordinator::Coordinator::submit_to)
//!   over a pre-reserved per-connection reply ring, and streams
//!   responses back out of order by request id — framing the *same*
//!   buffer the transform ran in (vectored header + payload write, no
//!   gather or encode copy).
//! * [`client`] — the sync pipelining client (tests, examples, loadgen),
//!   with the typed retriable/fatal error split ([`client::ClientError`])
//!   the failover logic above it branches on.
//! * [`cluster`] — the scale-out tier: a routing proxy over N backend
//!   serve processes. Routes on the batcher's bucket coordinates
//!   `(n, dtype, epilogue, prologue)` via rendezvous hashing so shard
//!   batches stay homogeneous, health-checks backends over `Ping`,
//!   fails retriable outcomes (`Busy`, `Draining`, dead upstream) over
//!   to another shard, and drains/restarts individual backends without
//!   dropping traffic.
//! * [`loadgen`] — the open-loop QPS load generator over the traffic
//!   mixes of [`crate::harness::workload`], feeding the
//!   `BENCH_PR7.json` perf trajectory; with the `count-alloc` feature it
//!   also measures server-side heap allocations per request.
//!
//! The acceptance contract (enforced by `rust/tests/serve_e2e.rs` and
//! `rust/tests/zero_alloc_pool.rs`): responses through this layer are
//! **bit-identical** to direct `Coordinator::submit` for every kernel ×
//! dtype × epilogue combination; overload answers `Busy` — no hangs, no
//! dropped connections; and the steady-state request path performs zero
//! heap allocations end to end.

pub mod client;
pub mod cluster;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use client::{Client, ClientError, PendingReply, Reply};
pub use cluster::{
    cluster, supervise, BackendSnapshot, ClusterConfig, ClusterCounters, ClusterHandle,
    RouteKey, SupervisorHandle,
};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use server::{serve, ServeConfig, ServeCounters, ServeHandle};
pub use wire::{Frame, WireRequest, WireResponse, WireStats};
