//! Layer 4: the network serving front-end over the [`crate::coordinator`].
//!
//! Everything below this module is an in-process function call; this
//! module is where HadaCore becomes a *service* — the deployment shape
//! the paper's rotate→quantize primitive actually runs in on an
//! inference hot path. Zero external dependencies: `std::net` TCP, a
//! purpose-built binary frame protocol, and `std` threads.
//!
//! * [`wire`] — the length-prefixed, versioned frame protocol
//!   (request/response/error/busy/ping/stats), with strict decode limits
//!   and bit-exact f32 payloads.
//! * [`server`] — the TCP acceptor + bounded connection-handler pool:
//!   decodes frames, applies admission control (global in-flight cap,
//!   per-connection pipelining cap, batcher queue-depth shedding — all
//!   answered with a retriable [`wire::Frame::Busy`] rather than
//!   unbounded queueing), forwards to
//!   [`Coordinator::submit_with`](crate::coordinator::Coordinator::submit_with),
//!   and streams responses back out of order by request id.
//! * [`client`] — the sync pipelining client (tests, examples, loadgen).
//! * [`loadgen`] — the open-loop QPS load generator over the traffic
//!   mixes of [`crate::harness::workload`], feeding the
//!   `BENCH_PR5.json` perf trajectory.
//!
//! The acceptance contract (enforced by `rust/tests/serve_e2e.rs`):
//! responses through this layer are **bit-identical** to direct
//! `Coordinator::submit` for every kernel × dtype × epilogue
//! combination, and overload answers `Busy` — no hangs, no dropped
//! connections.

pub mod client;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use client::{Client, PendingReply, Reply};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use server::{serve, ServeConfig, ServeCounters, ServeHandle};
pub use wire::{Frame, WireRequest, WireResponse, WireStats};
